//! Serving-side latency/throughput sweep: the dynamic micro-batching
//! queue under concurrent single-row clients, across batch caps and
//! client counts. `benches/native_perf.rs` carries a two-point version
//! of this into `BENCH_native.json` for the CI ratchet; this bench is
//! the standalone deep sweep for characterizing the latency/throughput
//! trade-off — how much p50/p99 degrades as coalescing windows grow,
//! and how much throughput coalescing buys back.
//!
//! ```text
//! cargo bench --bench serve_bench                    # full sweep
//! cargo bench --bench serve_bench -- --quick         # CI smoke
//! cargo bench --bench serve_bench -- --caps 1,8 --clients 2,16
//! ```
//!
//! Writes `BENCH_serve.json` (`spngd-bench-serve/1`): `{schema, model,
//! batch, quick, forward: [{rows, ns, ns_per_row}, ...], sweep:
//! [{max_batch, clients, requests, batches, rows, full_flushes,
//! timeout_flushes, p50_ns, p99_ns, throughput_rps}, ...]}`. The
//! `forward` entries are the raw `Predictor::logits` cost at 1 row vs
//! the full static batch (the amortization ceiling the queue is chasing);
//! each `sweep` entry is one (batch cap × client count) cell.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use spngd::harness::{self, bench};
use spngd::optim;
use spngd::serve::queue::{BatchQueue, QueueCfg};
use spngd::serve::Predictor;
use spngd::util::cli::Args;
use spngd::util::json::{obj, Json};
use spngd::util::obs;
use spngd::util::stats::Summary;

/// One (batch cap × client count) cell: `clients` threads each push
/// single-row requests through a fresh queue and block on their tickets;
/// the batcher thread coalesces into `Predictor::logits` forwards.
struct Cell {
    max_batch: usize,
    clients: usize,
    requests: usize,
    batches: u64,
    rows: u64,
    full_flushes: u64,
    timeout_flushes: u64,
    p50_ns: f64,
    p99_ns: f64,
    throughput_rps: f64,
}

impl Cell {
    fn json(&self) -> Json {
        obj(vec![
            ("max_batch", Json::from(self.max_batch)),
            ("clients", Json::from(self.clients)),
            ("requests", Json::from(self.requests)),
            ("batches", Json::from(self.batches as f64)),
            ("rows", Json::from(self.rows as f64)),
            ("full_flushes", Json::from(self.full_flushes as f64)),
            ("timeout_flushes", Json::from(self.timeout_flushes as f64)),
            ("p50_ns", Json::from(self.p50_ns)),
            ("p99_ns", Json::from(self.p99_ns)),
            ("throughput_rps", Json::from(self.throughput_rps)),
        ])
    }
}

fn run_cell(
    predictor: &Arc<Predictor>,
    max_batch: usize,
    clients: usize,
    n_requests: usize,
    max_wait_us: u64,
) -> Cell {
    let (b, dim) = (predictor.batch(), predictor.in_dim());
    let queue = BatchQueue::new(QueueCfg { max_batch, max_wait_us });
    let qb = queue.clone();
    let pb = predictor.clone();
    let batcher = std::thread::Builder::new()
        .name("serve-bench-batch".to_string())
        .spawn(move || qb.run(|rows| pb.logits(rows).map_err(|e| e.to_string())))
        .expect("spawn batcher");

    let t_wall = Instant::now();
    let per_client = n_requests.max(clients) / clients;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let q = queue.clone();
            let row: Vec<f32> =
                (0..dim).map(|i| ((i * 31 + (c % b) * 7) % 17) as f32 / 17.0).collect();
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t0 = Instant::now();
                    q.enqueue(vec![row.clone()]).expect("enqueue").wait().expect("predict");
                    lat.push(t0.elapsed().as_secs_f64());
                }
                lat
            })
        })
        .collect();
    let mut lat = Summary::new();
    for h in handles {
        for l in h.join().expect("client thread") {
            lat.push(l);
        }
    }
    let wall = t_wall.elapsed().as_secs_f64();
    queue.shutdown();
    batcher.join().expect("batcher thread");

    let rows = queue.stats.rows.load(Ordering::Relaxed);
    Cell {
        max_batch,
        clients,
        requests: lat.len(),
        batches: queue.stats.batches.load(Ordering::Relaxed),
        rows,
        full_flushes: queue.stats.full_flushes.load(Ordering::Relaxed),
        timeout_flushes: queue.stats.timeout_flushes.load(Ordering::Relaxed),
        p50_ns: lat.percentile(50.0) * 1e9,
        p99_ns: lat.percentile(99.0) * 1e9,
        throughput_rps: rows as f64 / wall.max(1e-9),
    }
}

fn main() {
    let parsed = Args::new("serve_bench", "micro-batching latency/throughput sweep")
        .opt("model", "convnet_tiny", "model to serve (must define predict_exe)")
        .opt("caps", "1,4,8", "batch caps to sweep (clamped to the model's static batch)")
        .opt("clients", "1,4,8", "concurrent client counts to sweep")
        .opt("requests", "256", "total requests per sweep cell")
        .opt("max-wait-us", "500", "queue deadline: oldest-row wait before a timeout flush")
        .opt("out", "BENCH_serve.json", "output path for the JSON report")
        .flag("quick", "smoke mode: tiny request counts, 2-point sweep")
        .flag("bench", "ignored (cargo bench passes it)")
        .parse_env(1)
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2);
        });
    let quick = parsed.get_bool("quick");

    // bench determinism: tracing off, same as native_perf
    obs::init_from_env();
    obs::set_enabled(false);

    let model_name = parsed.get("model").to_string();
    let (manifest, engine) = harness::load_runtime_native().expect("native runtime");
    let mut tr = harness::builder(&model_name, optim::sgd())
        .expect("runtime")
        .workers(1)
        .dataset_len(2048)
        .data_seed(7)
        .build()
        .expect("bench trainer");
    let ck = tr.checkpoint().expect("bench checkpoint");
    drop(tr);
    let predictor = Arc::new(
        Predictor::from_checkpoint(&manifest, engine, &model_name, &ck).expect("predictor"),
    );
    let b = predictor.batch();
    println!("serve_bench: model={model_name} batch={b} quick={quick}");

    // ---- forward amortization: the queue-free floor and ceiling
    let (wu, it) = if quick { (1, 2) } else { (2, 16) };
    let dim = predictor.in_dim();
    let rows_full: Vec<Vec<f32>> = (0..b)
        .map(|r| (0..dim).map(|i| ((i * 31 + r * 7) % 17) as f32 / 17.0).collect())
        .collect();
    let one = bench("predict 1 row", wu, it, || {
        predictor.logits(&rows_full[..1]).expect("predict");
    });
    let full = bench(&format!("predict {b} rows"), wu, it, || {
        predictor.logits(&rows_full).expect("predict");
    });
    let (one_ns, full_ns) = (one.median() * 1e9, full.median() * 1e9);
    println!(
        "forward: 1 row {:.0} ns, {b} rows {:.0} ns ({:.0} ns/row, {:.1}x amortization)",
        one_ns,
        full_ns,
        full_ns / b as f64,
        one_ns / (full_ns / b as f64).max(1e-9)
    );
    let forward = vec![
        obj(vec![
            ("rows", Json::from(1usize)),
            ("ns", Json::from(one_ns)),
            ("ns_per_row", Json::from(one_ns)),
        ]),
        obj(vec![
            ("rows", Json::from(b)),
            ("ns", Json::from(full_ns)),
            ("ns_per_row", Json::from(full_ns / b as f64)),
        ]),
    ];

    // ---- the sweep: batch caps × client counts
    let mut caps: Vec<usize> = parsed
        .get_usize_list("caps")
        .into_iter()
        .map(|c| c.clamp(1, b))
        .collect();
    caps.dedup();
    let mut clients_axis = parsed.get_usize_list("clients");
    clients_axis.retain(|&c| c >= 1);
    let n_requests = if quick { 32 } else { parsed.get_usize("requests") };
    if quick {
        caps = vec![1, b];
        caps.dedup();
        clients_axis = vec![4];
    }
    let max_wait_us = parsed.get_usize("max-wait-us") as u64;

    println!(
        "\n{:>9} {:>8} {:>9} {:>8} {:>6} {:>12} {:>12} {:>12}",
        "max_batch", "clients", "requests", "batches", "rows", "p50_ns", "p99_ns", "rows/s"
    );
    let mut sweep: Vec<Json> = Vec::new();
    for &cap in &caps {
        for &nc in &clients_axis {
            let cell = run_cell(&predictor, cap, nc, n_requests, max_wait_us);
            println!(
                "{:>9} {:>8} {:>9} {:>8} {:>6} {:>12.0} {:>12.0} {:>12.0}",
                cell.max_batch,
                cell.clients,
                cell.requests,
                cell.batches,
                cell.rows,
                cell.p50_ns,
                cell.p99_ns,
                cell.throughput_rps
            );
            sweep.push(cell.json());
        }
    }

    let report = obj(vec![
        ("schema", Json::from("spngd-bench-serve/1")),
        ("model", Json::from(model_name)),
        ("batch", Json::from(b)),
        ("quick", Json::from(quick)),
        ("forward", Json::Arr(forward)),
        ("sweep", Json::Arr(sweep)),
    ]);
    let out_path = parsed.get("out");
    std::fs::write(out_path, report.to_string_pretty()).expect("write bench report");
    println!("\nwrote {out_path}");
}
