//! Table 1 + Fig. 1 bench: steps/time-to-accuracy, SP-NGD vs SGD, with
//! the paper's published rows as reference constants.
//!
//! The paper's Table 1 compares optimizers by (a) steps to target top-1
//! accuracy and (b) wall time given the cluster. Our reproduction trains
//! both optimizers on the synthetic corpus to a fixed validation accuracy,
//! reports measured steps, and converts steps → cluster time with the
//! α-β model at the paper's GPU counts. Absolute ImageNet numbers are out
//! of reach (see DESIGN.md §4); the *shape* — NGD needs roughly half the
//! steps of SGD at the same batch size — is the reproduction target.

use std::sync::Arc;

use spngd::collectives::cost::{predict_step_time, ClusterModel};
use spngd::harness;
use spngd::optim::{Preconditioner, SpNgd};

/// Paper Table 1 rows (reference constants for the printed comparison).
const PAPER_ROWS: &[(&str, usize, &str, usize, f64)] = &[
    // (who, batch, optimizer, steps, accuracy)
    ("Goyal et al. [6]", 8_192, "SGD", 14_076, 76.3),
    ("Akiba et al. [7]", 32_768, "RMS/SGD", 3_519, 74.9),
    ("You et al. [8]", 32_768, "SGD", 2_503, 74.9),
    ("Ying et al. [13]", 32_768, "SGD", 3_519, 76.3),
    ("This work (paper)", 32_768, "SP-NGD", 1_760, 75.4),
    ("This work (paper)", 131_072, "SP-NGD", 873, 74.9),
];

fn run(
    optimizer: Arc<dyn Preconditioner>,
    target_acc: f32,
    max_steps: usize,
) -> (Option<u64>, f32, f64) {
    let mut tr = harness::builder("convnet_small", optimizer)
        .expect("runtime")
        .workers(2)
        .dataset_len(8192)
        .data_seed(11)
        .build()
        .expect("trainer");
    let mut steps_to = None;
    let mut final_acc = 0.0f32;
    for i in 1..=max_steps {
        tr.step().unwrap();
        if i % 4 == 0 {
            let (_, acc) = tr.evaluate(8).unwrap();
            final_acc = acc;
            if steps_to.is_none() && acc >= target_acc {
                steps_to = Some(i as u64);
                break;
            }
        }
    }
    let prof = tr.profile();
    (steps_to, final_acc, predict_step_time(&prof, 1024, &ClusterModel::default()))
}

fn main() {
    println!("=== Table 1 (paper reference rows) ===");
    println!("{:<22} {:>8} {:>9} {:>8} {:>9}", "work", "batch", "optim", "steps", "top-1");
    for (who, bs, opt, steps, acc) in PAPER_ROWS {
        println!("{who:<22} {bs:>8} {opt:>9} {steps:>8} {acc:>8.1}%");
    }

    let target = 0.93f32;
    println!("\n=== This reproduction (synthetic corpus, target {:.0}% val acc) ===", target * 100.0);
    let t0 = std::time::Instant::now();
    let (sgd_steps, sgd_acc, sgd_tstep) = run(spngd::optim::sgd(), target, 256);
    let ngd = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
    let (ngd_steps, ngd_acc, ngd_tstep) = run(ngd, target, 256);
    println!(
        "{:<22} {:>8} {:>9} {:>8} {:>9}  t/step@1024GPU {:.0}ms",
        "SGD baseline",
        128,
        "SGD",
        sgd_steps.map(|s| s.to_string()).unwrap_or(">256".into()),
        format!("{:.1}%", sgd_acc * 100.0),
        sgd_tstep * 1e3,
    );
    println!(
        "{:<22} {:>8} {:>9} {:>8} {:>9}  t/step@1024GPU {:.0}ms",
        "SP-NGD (this repo)",
        128,
        "SP-NGD",
        ngd_steps.map(|s| s.to_string()).unwrap_or(">256".into()),
        format!("{:.1}%", ngd_acc * 100.0),
        ngd_tstep * 1e3,
    );
    if let (Some(a), Some(b)) = (ngd_steps, sgd_steps) {
        let ratio = a as f64 / b as f64;
        println!("\nFig. 1 shape: SP-NGD steps / SGD steps = {ratio:.2} (paper: ~0.5)");
        assert!(
            ratio < 1.2,
            "SP-NGD should not need more steps than SGD (got {ratio:.2})"
        );
    }
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
