//! Fig. 6 bench: per-step communication bytes for the statistics
//! (A vs G/F stacked) over training with the adaptive stale scheduler.
//!
//! Paper Fig. 6 shows the ReduceScatterV payload per step shrinking as
//! intervals grow, with larger batch sizes reaching lower floors
//! (5.4-23.6% of the always-refresh volume). This bench reproduces the
//! series at two accumulation levels and prints the stacked A and G/F
//! byte columns for representative steps.

use std::sync::Arc;

use spngd::harness;
use spngd::optim::SpNgd;
use spngd::util::stats::fmt_bytes;

fn main() {
    for &(accum, steps) in &[(1usize, 50usize), (4, 30)] {
        let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
        let mut tr = harness::builder("convnet_small", opt)
            .expect("runtime")
            .workers(2)
            .grad_accum(accum)
            .dataset_len(8192)
            .data_seed(17)
            .build()
            .expect("trainer");

        let mut series: Vec<(u64, u64, u64)> = Vec::new(); // (step, A bytes, G/F bytes)
        for _ in 0..steps {
            let rec = tr.step().unwrap();
            series.push((rec.step, rec.comm.rs_stats_a, rec.comm.rs_stats_g));
        }
        let full_a: u64 = series[0].1;
        let full_g: u64 = series[0].2;
        println!("\n=== Fig. 6: statistics comm per step (effective BS {}) ===", 2 * accum * 32);
        println!("{:>6} {:>12} {:>12} {:>8}", "step", "A bytes", "G/F bytes", "% full");
        for &(s, a, g) in series.iter() {
            if s <= 3 || s % 10 == 0 || s as usize == steps {
                let pct = 100.0 * (a + g) as f64 / (full_a + full_g).max(1) as f64;
                println!("{s:>6} {:>12} {:>12} {pct:>7.1}%", fmt_bytes(a as f64), fmt_bytes(g as f64));
            }
        }
        let total: u64 = series.iter().map(|&(_, a, g)| a + g).sum();
        let always: u64 = (full_a + full_g) * steps as u64;
        let reduction = 100.0 * total as f64 / always as f64;
        println!(
            "reduction over the run: {reduction:.1}% of always-refresh (paper: 5.4-23.6%)"
        );
        // shape: late steps must communicate less than step 1
        let tail: u64 = series.iter().rev().take(5).map(|&(_, a, g)| a + g).sum::<u64>() / 5;
        assert!(
            tail < full_a + full_g,
            "per-step stats bytes should shrink: tail {tail} vs full {}",
            full_a + full_g
        );
    }
    println!("\nfig6 shape checks PASSED");
}
