//! Table 2 bench: the stale-statistics scheduler's communication
//! reduction and speedup across batch sizes (emp+unitBN vs
//! emp+unitBN+stale), plus the accuracy-preservation check.
//!
//! Paper Table 2 reports, per batch size: reduction↓ (communication kept,
//! 5.4-23.6%) and speedup↑ (×1.32-1.68), with accuracy changing by ≤0.4%.
//! Here batch size grows via gradient/statistics accumulation (the
//! paper's own method for BS≥65K) and both variants train the same number
//! of updates.

use std::sync::Arc;

use spngd::harness;
use spngd::optim::SpNgd;
use spngd::util::stats::fmt_duration;

/// paper's Table 2 stale-statistics columns (reference)
const PAPER: &[(usize, f64, f64)] = &[
    (4_096, 23.6, 1.33),
    (8_192, 15.1, 1.32),
    (16_384, 5.4, 1.68),
    (32_768, 7.8, 1.40),
];

fn run(accum: usize, stale: bool, steps: usize) -> (f64, f64, f32) {
    let opt = Arc::new(SpNgd { stale, stale_alpha: 0.3, ..SpNgd::default() });
    let mut tr = harness::builder("convnet_small", opt)
        .expect("runtime")
        .workers(2)
        .grad_accum(accum)
        .dataset_len(8192)
        .data_seed(13)
        .build()
        .expect("trainer");
    for _ in 0..steps {
        tr.step().unwrap();
    }
    let (_, acc) = tr.evaluate(8).unwrap();
    (tr.log.mean_step_time(2), tr.comm_reduction(), acc)
}

fn main() {
    println!("=== Table 2 (paper): stale-statistics columns ===");
    println!("{:>8} {:>12} {:>9}", "BS", "reduction↓", "speedup↑");
    for (bs, red, sp) in PAPER {
        println!("{bs:>8} {red:>11.1}% {sp:>8.2}x");
    }

    println!("\n=== This reproduction (effective BS via accumulation) ===");
    println!(
        "{:>6} {:>7} {:>14} {:>14} {:>12} {:>9} {:>10} {:>10}",
        "BS", "accum", "t/step (full)", "t/step (stale)", "reduction↓", "speedup↑", "acc full", "acc stale"
    );
    for &(accum, steps) in &[(1usize, 40usize), (2, 30), (4, 20)] {
        let (t_full, _, acc_full) = run(accum, false, steps);
        let (t_stale, reduction, acc_stale) = run(accum, true, steps);
        let speedup = t_full / t_stale;
        let bs = 2 * accum * 32;
        println!(
            "{:>6} {:>7} {:>14} {:>14} {:>11.1}% {:>8.2}x {:>9.3} {:>9.3}",
            bs,
            accum,
            fmt_duration(t_full),
            fmt_duration(t_stale),
            reduction * 100.0,
            speedup,
            acc_full,
            acc_stale
        );
        // paper shape: stale reduces communication and does not hurt
        // accuracy by more than noise at this scale
        assert!(reduction < 1.0, "stale must reduce communication");
        assert!(
            acc_stale > acc_full - 0.15,
            "stale must not collapse accuracy: {acc_full} -> {acc_stale}"
        );
    }
    println!("\ntable2 shape checks PASSED (reduction < 100%, accuracy preserved)");
}
