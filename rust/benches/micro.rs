//! Micro-benchmarks + ablations: per-executable costs (the Stage-1/4 hot
//! paths), collective op costs, symmetric-packing savings, and the
//! unitBN-vs-fullBN inversion ablation (§4.2).

use spngd::collectives::comm::{SimComm, StatClass};
use spngd::harness::{self, bench};
use spngd::kfac::bn::{BnFisher, BnFullFisher};
use spngd::linalg::{pack_upper, solve, unpack_upper, Mat};
use spngd::runtime::{Executor, HostTensor};
use spngd::util::rng::Rng;

fn main() {
    let (manifest, engine) = harness::load_runtime().expect("artifacts");
    let model = manifest.model("convnet_small").unwrap();
    let params = manifest.load_init_params(model).unwrap();
    let mut rng = Rng::new(1);

    // ---- Stage 1+2: the step executable (fwd/bwd + taps)
    let n_in: usize = model.input_shape.iter().product();
    let x = HostTensor::new(model.input_shape.clone(), (0..n_in).map(|_| rng.f32()).collect());
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes] = 1.0;
    }
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    bench("L2 step_emp fwd/bwd+taps", 2, 10, || {
        engine.execute(&model.step_emp, &inputs).unwrap();
    });
    bench("L2 step_1mc (extra backward)", 2, 10, || {
        engine.execute_seeded(&model.step_1mc, &inputs, Some(3)).unwrap();
    });
    bench("L2 eval", 2, 10, || {
        let mut ev: Vec<&HostTensor> = params.iter().collect();
        ev.push(&x);
        ev.push(&t);
        let bn: Vec<HostTensor> = model
            .bn_order
            .iter()
            .map(|nm| HostTensor::zeros(vec![model.layer(nm).unwrap().channels]))
            .collect();
        let bnv: Vec<HostTensor> = model
            .bn_order
            .iter()
            .map(|nm| {
                let c = model.layer(nm).unwrap().channels;
                HostTensor::new(vec![c], vec![1.0; c])
            })
            .collect();
        for b in &bn {
            ev.push(b);
        }
        for v in &bnv {
            ev.push(v);
        }
        engine.execute(&model.eval_exe, &ev).unwrap();
    });

    // ---- Stage 1: factor construction kernels (L1 Pallas)
    for l in model.kfac_layers.iter().filter(|l| !l.is_bn()).take(3) {
        let a_shape = manifest
            .models
            .get("convnet_small")
            .unwrap()
            .step_outputs
            .iter()
            .find(|o| o.role == "a_tap" && o.layer.as_deref() == Some(&l.name))
            .unwrap()
            .shape
            .clone();
        let n: usize = a_shape.iter().product();
        let tap = HostTensor::new(a_shape, (0..n).map(|_| rng.f32()).collect());
        bench(&format!("L1 factor_a {}", l.name), 2, 10, || {
            engine.execute(&l.factor_a, &[&tap]).unwrap();
        });
    }

    // ---- Stage 4: inversion buckets (L1 Newton-Schulz)
    let mut buckets: Vec<usize> = manifest
        .executables
        .keys()
        .filter_map(|k| k.strip_prefix("invert_").and_then(|s| s.parse().ok()))
        .collect();
    buckets.sort();
    for n in buckets {
        let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let bm = Mat::from_vec(n, n, b);
        let mut m = bm.transpose().matmul(&bm).scale(1.0 / n as f32);
        m.symmetrize();
        let mt = HostTensor::from_mat(&m);
        let damp = HostTensor::scalar(0.05);
        bench(&format!("L1 invert_{n} (Newton-Schulz)"), 1, 6, || {
            engine.execute(&format!("invert_{n}"), &[&mt, &damp]).unwrap();
        });
        // host-side Gauss-Jordan comparison (the non-MXU alternative)
        let mut md = m.clone();
        md.add_diag(0.05);
        bench(&format!("L3 gauss_jordan_{n} (host)"), 1, 6, || {
            solve::gauss_jordan_inverse(&md).unwrap();
        });
    }

    // ---- ablation: unitBN vs fullBN (§4.2)
    let c = 32;
    let bsz = 32;
    let gg: Vec<f32> = (0..bsz * c).map(|_| rng.normal() as f32).collect();
    let gb: Vec<f32> = (0..bsz * c).map(|_| rng.normal() as f32).collect();
    bench("BN unit fisher + closed-form inverse (C=32)", 5, 50, || {
        let f = BnFisher::from_taps(&gg, &gb, bsz, c);
        let grads = vec![0.1f32; c];
        let _ = f.precondition(&grads, &grads, 0.01);
    });
    bench("BN full fisher (2C)^2 + GJ inverse (C=32)", 2, 10, || {
        let f = BnFullFisher::from_taps(&gg, &gb, bsz, c);
        let mut fd = f.fisher.clone();
        fd.add_diag(0.01);
        let _ = solve::gauss_jordan_inverse(&fd).unwrap();
    });

    // ---- collectives: packed vs dense ReduceScatterV
    let comm = SimComm::new(8);
    let mats: Vec<Vec<Mat>> = (0..8).map(|_| vec![Mat::eye(288); 4]).collect();
    bench("RS-V 8 workers, 4x 288^2 stats (packed acct)", 2, 10, || {
        comm.reduce_scatter_v(&mats, &[StatClass::A; 4]);
    });
    bench("pack+unpack 288^2 symmetric", 5, 50, || {
        let p = pack_upper(&mats[0][0]);
        let _ = unpack_upper(&p, 288);
    });
    let mut grads: Vec<Vec<f32>> = (0..8).map(|_| vec![0.5f32; 43216]).collect();
    bench("AllReduce 8 workers, 43k-param grads", 2, 10, || {
        comm.all_reduce_mean(&mut grads);
    });
    println!("\nmicro bench done");
}
