//! Throughput of the multi-process framed wire protocol's hot path:
//! gradient/statistic job encoding (the coordinator's per-step serialize
//! cost), frame parsing + payload decoding (the worker side), and the
//! FNV-1a checksum that guards every payload — on both the f32 wire and
//! the real-f16 mixed wire.

use spngd::collectives::comm::Precision;
use spngd::collectives::wire::{self, Frame};
use spngd::harness::bench;
use spngd::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    // 4 lanes x 64k elements ~ a mid-size model's gradient AllReduce
    let lanes: Vec<Vec<f32>> =
        (0..4).map(|_| (0..65_536).map(|_| rng.normal() as f32).collect()).collect();
    let slices: Vec<&[f32]> = lanes.iter().map(|l| l.as_slice()).collect();

    for p in [Precision::F32, Precision::Mixed] {
        let tag = match p {
            Precision::F32 => "f32",
            Precision::Mixed => "f16",
        };
        bench(&format!("wire encode grad job 4x64k {tag}"), 2, 20, || {
            let _ = wire::encode_grad_job(p, 0, &slices);
        });
        let frame = wire::encode_grad_job(p, 0, &slices);
        let bytes = frame.encode();
        bench(&format!("wire parse+decode grad job 4x64k {tag}"), 2, 20, || {
            let (f, used) = Frame::parse(&bytes).unwrap().unwrap();
            assert_eq!(used, bytes.len());
            let job = wire::decode_grad_job(&f).unwrap();
            assert_eq!(job.seg_len, 65_536);
        });
        let reply = wire::encode_grad_seg(p, 0, &lanes[0]);
        let reply_bytes = reply.encode();
        bench(&format!("wire parse+decode grad seg 64k {tag}"), 2, 20, || {
            let (f, _) = Frame::parse(&reply_bytes).unwrap().unwrap();
            let (_, seg) = wire::decode_grad_seg(&f).unwrap();
            assert_eq!(seg.len(), 65_536);
        });
        let mats: Vec<Vec<f32>> = (0..4).map(|_| lanes[0][..288 * 288].to_vec()).collect();
        let mat_slices: Vec<&[f32]> = mats.iter().map(|m| m.as_slice()).collect();
        bench(&format!("wire encode stat job 4x288^2 {tag}"), 2, 20, || {
            let _ = wire::encode_stat_job(p, 0, 288, 288, &mat_slices);
        });
    }

    let payload: Vec<u8> = (0..4 * 65_536).map(|_| (rng.next_u64() & 0xff) as u8).collect();
    bench("wire fnv1a checksum 256 KiB", 5, 50, || {
        let _ = wire::checksum(&payload);
    });
    println!("\nproc wire bench done");
}
