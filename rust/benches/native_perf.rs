//! Native-backend perf tracker: times the parallel/blocked kernels and
//! the end-to-end native step against their single-threaded naive
//! references and writes machine-readable `BENCH_native.json`, so the
//! perf trajectory is tracked from PR to PR (CI uploads it as an
//! artifact).
//!
//! ```text
//! cargo bench --bench native_perf                    # full run
//! cargo bench --bench native_perf -- --quick         # CI smoke: 1 warmup / 1 iter
//! SPNGD_THREADS=4 cargo bench --bench native_perf    # pin the pool size
//! ```
//!
//! JSON schema (`spngd-bench-native/6`): `{schema, model, threads, quick,
//! step: {name, ns, naive_ns, speedup}, kernels: [{name, ns, naive_ns,
//! speedup}, ...], workers: [...], optimizers: [{name, step_ns}, ...],
//! data: [...], simd: [...], precision: [...], obs: {...}}` — `ns` is the median
//! per-iteration wall time of the parallel kernel, `naive_ns` the same
//! measurement with `linalg::set_reference_kernels(true)` routing every
//! product to the pre-refactor naive loops, `speedup` their ratio.
//! `optimizers` is the end-to-end trainer step time once per registered
//! optimizer (spngd | sgd | lars), so optimizer-level perf is tracked
//! per PR. `data` measures the input pipeline per prefetch mode:
//! per-global-batch prep time (sampling + transforms), how long the
//! trainer actually waited for it, and the fraction of prep hidden
//! behind the step (`hidden_fraction` — 0 with prefetch off by
//! construction, ideally → 1 with prefetch on). `simd` (new in /4) times
//! the blocked kernels under the forced-scalar vs native vector dispatch
//! (`{name, kernel, ns, scalar_ns, speedup}` — bit-identical outputs,
//! different speed), and `precision` (new in /4) records the threaded
//! step time plus the per-step comm bytes for each wire precision
//! (`{precision, step_ns, grad_bytes_per_step, stats_bytes_per_step,
//! param_bytes_per_step}` — mixed must move ~half the grad/stat bytes,
//! which `bench_gate.py` asserts structurally). `obs` (new in /5) gates
//! the tracing layer: the per-call cost of a disabled span (one relaxed
//! atomic load — `disabled_span_ns`), the threaded step time with
//! tracing off vs on (`step_ns` / `step_ns_traced` /
//! `trace_overhead_ratio`), and the overlap accountant's view of the
//! traced run (`comm_ns`, `compute_ns`, `hidden_ns`, `hidden_fraction`,
//! `critical_path_ns`, `events`). `serve` (new in /6) tracks the
//! inference side: the predict executable's per-row amortization
//! (`forward`: 1 row vs a full static batch through `serve::Predictor`)
//! and the micro-batching queue under concurrent single-row clients
//! across batch caps (`queue`: `{max_batch, requests, batches, rows,
//! p50_ns, p99_ns, throughput_rps}` — per-request latency percentiles vs
//! coalesced throughput; `benches/serve_bench.rs` is the deeper
//! standalone sweep).

use spngd::collectives::Precision;
use spngd::coordinator::DistMode;
use spngd::harness::{self, bench};
use spngd::optim;
use spngd::linalg::{self, Mat};
use spngd::runtime::native::kernels;
use spngd::runtime::{Executor, HostTensor};
use spngd::serve::queue::{BatchQueue, QueueCfg};
use spngd::serve::Predictor;
use spngd::util::cli::Args;
use spngd::util::json::{obj, Json};
use spngd::util::obs::{self, Cat};
use spngd::util::pool;
use spngd::util::rng::Rng;
use spngd::util::simd;

struct Entry {
    name: String,
    ns: f64,
    naive_ns: f64,
}

impl Entry {
    fn speedup(&self) -> f64 {
        self.naive_ns / self.ns.max(1e-9)
    }

    fn json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.clone())),
            ("ns", Json::from(self.ns)),
            ("naive_ns", Json::from(self.naive_ns)),
            ("speedup", Json::from(self.speedup())),
        ])
    }
}

/// Time `f` twice — on the parallel/blocked kernels, then with the naive
/// reference routing — and record both medians.
fn timed<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Entry {
    let fast = bench(name, warmup, iters, &mut f);
    linalg::set_reference_kernels(true);
    let naive = bench(&format!("{name} (naive)"), warmup, iters, &mut f);
    linalg::set_reference_kernels(false);
    Entry { name: name.to_string(), ns: fast.median() * 1e9, naive_ns: naive.median() * 1e9 }
}

fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

fn main() {
    let parsed = Args::new("native_perf", "native-backend bench runner (BENCH_native.json)")
        .opt("model", "convnet_small", "model for the end-to-end step")
        .opt("workers", "1,4", "dist-engine worker counts for the trainer-step sweep")
        .opt("out", "BENCH_native.json", "output path for the JSON report")
        .flag("quick", "smoke mode: 1 warmup / 1 timed iteration")
        .flag("bench", "ignored (cargo bench passes it)")
        .parse_env(1)
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2);
        });
    let quick = parsed.get_bool("quick");
    let (wu, it) = if quick { (1, 1) } else { (2, 8) };
    let threads = pool::global().size();
    println!("native_perf: {threads} threads (set SPNGD_THREADS to override), quick={quick}");

    // bench determinism: consume any ambient SPNGD_TRACE/SPNGD_EVENTS here
    // (the registry is Once-guarded), then force tracing off — the obs
    // section below toggles it around its own measurements
    obs::init_from_env();
    obs::set_enabled(false);

    let (manifest, engine) = harness::load_runtime_native().expect("native runtime");
    let model_name = parsed.get("model").to_string();
    let model = manifest.model(&model_name).expect("model in manifest");
    let params = manifest.load_init_params(model).expect("init params");
    let mut rng = Rng::new(1);
    let n_in: usize = model.input_shape.iter().product();
    let x = HostTensor::new(model.input_shape.clone(), (0..n_in).map(|_| rng.f32()).collect());
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes] = 1.0;
    }
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);

    // ---- end-to-end: the full native step executable (fwd/bwd + taps)
    let step = timed(&format!("step_emp {model_name}"), wu, it, || {
        engine.execute(&model.step_emp, &inputs).unwrap();
    });

    // ---- hot kernels on the model's stem-conv geometry
    let [ib, ic, ih, iw] = [
        model.input_shape[0],
        model.input_shape[1],
        model.input_shape[2],
        model.input_shape[3],
    ];
    let mut entries: Vec<Entry> = Vec::new();
    let (patches, ho, wo) = kernels::im2col(&x, 3, 1, 1);
    entries.push(timed("im2col k3 s1 p1", wu, it, || {
        let _ = kernels::im2col(&x, 3, 1, 1);
    }));
    let dpatches = rand_mat(&mut rng, patches.rows, patches.cols);
    let xshape = [ib, ic, ih, iw];
    entries.push(timed("col2im k3 s1 p1", wu, it, || {
        let _ = kernels::col2im(&dpatches, &xshape, 3, 1, 1, ho, wo);
    }));
    entries.push(timed(&format!("syrk {}x{}", patches.rows, patches.cols), wu, it, || {
        let _ = kernels::syrk(&patches, 0.01);
    }));
    let gtap = rand_mat(&mut rng, patches.rows, 64);
    entries.push(timed(&format!("syrk {}x64", gtap.rows), wu, it, || {
        let _ = kernels::syrk(&gtap, 0.01);
    }));
    let wmat = rand_mat(&mut rng, patches.cols, 64);
    entries.push(timed(&format!("matmul {}x{}x64", patches.rows, patches.cols), wu, it, || {
        let _ = patches.matmul(&wmat);
    }));
    let wt = rand_mat(&mut rng, 64, patches.cols);
    let mm_t_name = format!("matmul_transposed {}x{}x64", patches.rows, patches.cols);
    entries.push(timed(&mm_t_name, wu, it, || {
        let _ = patches.matmul_transposed(&wt);
    }));
    let nmax = manifest
        .executables
        .keys()
        .filter_map(|k| k.strip_prefix("invert_").and_then(|s| s.parse::<usize>().ok()))
        .max()
        .unwrap_or(64);
    let bm = rand_mat(&mut rng, nmax, nmax);
    let mut spd = bm.transpose().matmul(&bm).scale(1.0 / nmax as f32);
    spd.symmetrize();
    entries.push(timed(&format!("ns_inverse {nmax} (20 iters)"), wu, it, || {
        let _ = kernels::ns_inverse(&spd, 0.05, 20);
    }));

    // ---- dist engine: end-to-end trainer step across worker counts.
    // `speedup_vs_serialized` compares against workers × the 1-worker
    // step time (what the sequential coordinator's fan-out would cost);
    // > 1 means worker threads + comm/compute overlap are engaged.
    let mut workers_list = parsed.get_usize_list("workers");
    if !workers_list.contains(&1) {
        workers_list.push(1);
    }
    // the serialized baseline is defined against a real 1-worker
    // measurement, so it must run first — never extrapolate it
    workers_list.sort_unstable();
    workers_list.dedup();
    let mut base_ns = 0.0f64;
    let mut dist_entries: Vec<Json> = Vec::new();
    for &wk in &workers_list {
        let mut tr = harness::builder("convnet_tiny", optim::spngd())
            .expect("runtime")
            .workers(wk)
            .grad_accum(1)
            .dist(DistMode::Threaded)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("dist trainer");
        let s = bench(&format!("dist step convnet_tiny workers={wk}"), wu, it, || {
            tr.step().expect("dist step");
        });
        let ns = s.median() * 1e9;
        if wk == 1 {
            base_ns = ns;
        }
        let serialized = base_ns * wk as f64;
        dist_entries.push(obj(vec![
            ("workers", Json::from(wk)),
            ("threads", Json::from(threads)),
            ("step_ns", Json::from(ns)),
            ("speedup_vs_serialized", Json::from(serialized / ns.max(1e-9))),
        ]));
    }

    // ---- data pipeline: per-batch prep cost and how much of it the
    // double-buffered prefetch hides behind the step (augment on so the
    // transform chain is part of the measured prep, like a real run)
    let mut data_entries: Vec<Json> = Vec::new();
    for prefetch in [false, true] {
        let mut tr = harness::builder("convnet_tiny", optim::spngd())
            .expect("runtime")
            .workers(2)
            .augment(spngd::data::AugmentCfg::default())
            .prefetch(prefetch)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("data trainer");
        let steps = if quick { 3 } else { 12 };
        for _ in 0..steps {
            tr.step().expect("data step");
        }
        let s = tr.data_stats();
        let prep_ns = s.prep_per_batch() * 1e9;
        let wait_ns = s.wait_per_batch() * 1e9;
        println!(
            "data prep (prefetch={prefetch}): {prep_ns:.0} ns/batch, waited {wait_ns:.0} ns \
             ({:.0}% hidden)",
            s.hidden_fraction() * 100.0
        );
        data_entries.push(obj(vec![
            ("prefetch", Json::from(prefetch)),
            ("source", Json::from(tr.loader().source().name())),
            ("prep_ns_per_batch", Json::from(prep_ns)),
            ("wait_ns_per_batch", Json::from(wait_ns)),
            ("hidden_fraction", Json::from(s.hidden_fraction())),
        ]));
    }

    // ---- SIMD dispatch: the same blocked kernels under forced-scalar
    // vs the native vector path — identical bits (the dispatch test pins
    // that), so this is purely the vectorization speedup
    let mut simd_entries: Vec<Json> = Vec::new();
    {
        let mut simd_bench = |name: &str, f: &mut dyn FnMut()| {
            simd::force("scalar");
            let s = bench(&format!("{name} [scalar]"), wu, it, &mut *f);
            simd::force("native");
            let kernel = simd::kernel_name();
            let v = bench(&format!("{name} [{kernel}]"), wu, it, &mut *f);
            let scalar_ns = s.median() * 1e9;
            let ns = v.median() * 1e9;
            simd_entries.push(obj(vec![
                ("name", Json::from(name)),
                ("kernel", Json::from(kernel)),
                ("ns", Json::from(ns)),
                ("scalar_ns", Json::from(scalar_ns)),
                ("speedup", Json::from(scalar_ns / ns.max(1e-9))),
            ]));
        };
        let mm_name = format!("matmul {}x{}x64", patches.rows, patches.cols);
        simd_bench(&mm_name, &mut || {
            let _ = patches.matmul(&wmat);
        });
        simd_bench(&mm_t_name, &mut || {
            let _ = patches.matmul_transposed(&wt);
        });
        let syrk_name = format!("syrk {}x{}", patches.rows, patches.cols);
        simd_bench(&syrk_name, &mut || {
            let _ = kernels::syrk(&patches, 0.01);
        });
        simd::force("auto"); // back to runtime detection
    }

    // ---- wire precision: threaded end-to-end step + per-step comm
    // bytes for each precision (grad/stat payloads halve under mixed;
    // parameters stay f32 — bench_gate.py asserts the ratio)
    let mut precision_entries: Vec<Json> = Vec::new();
    for prec in [Precision::F32, Precision::Mixed] {
        let mut tr = harness::builder("convnet_tiny", optim::spngd())
            .expect("runtime")
            .workers(2)
            .precision(prec)
            .dist(DistMode::Threaded)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("precision trainer");
        // counters from the first step: full statistics refresh, so the
        // byte mix is identical across precisions
        let rec = tr.step().expect("precision step");
        let s = bench(&format!("dist step convnet_tiny precision={}", prec.name()), wu, it, || {
            tr.step().expect("precision step");
        });
        precision_entries.push(obj(vec![
            ("precision", Json::from(prec.name())),
            ("step_ns", Json::from(s.median() * 1e9)),
            ("grad_bytes_per_step", Json::from(rec.comm.ar_grads as f64)),
            ("stats_bytes_per_step", Json::from(rec.comm.stats_total() as f64)),
            ("param_bytes_per_step", Json::from(rec.comm.ag_params as f64)),
        ]));
    }

    // ---- per-optimizer end-to-end step time (same model/shape for all,
    // resolved through the registry so new optimizers appear here free)
    let mut optim_entries: Vec<Json> = Vec::new();
    for &name in optim::OPTIMIZER_NAMES {
        let opt = optim::by_name(name).expect("registered optimizer");
        let mut tr = harness::builder("convnet_tiny", opt)
            .expect("runtime")
            .workers(2)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("optimizer trainer");
        let s = bench(&format!("step convnet_tiny optim={name}"), wu, it, || {
            tr.step().expect("optimizer step");
        });
        optim_entries.push(obj(vec![
            ("name", Json::from(name)),
            ("step_ns", Json::from(s.median() * 1e9)),
        ]));
    }

    // ---- obs: tracing overhead and comm/compute overlap accounting.
    // Three measurements: the disabled-span cost every instrumented
    // callsite pays on an untraced run (one relaxed load + branch), the
    // threaded step with tracing off vs on, and the overlap accountant's
    // summary of the traced run's spans.
    let obs_json = {
        let spins: usize = if quick { 100_000 } else { 1_000_000 };
        let d = bench("obs disabled span", wu, it, || {
            for _ in 0..spins {
                let s = obs::span("bench_noop", Cat::Compute);
                std::hint::black_box(&s);
            }
        });
        let disabled_span_ns = d.median() * 1e9 / spins as f64;

        let mut tr = harness::builder("convnet_tiny", optim::spngd())
            .expect("runtime")
            .workers(2)
            .dist(DistMode::Threaded)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("obs trainer");
        let off = bench("dist step convnet_tiny [tracing off]", wu, it, || {
            tr.step().expect("obs step");
        });
        let _ = obs::drain(); // discard anything recorded before this point
        obs::set_enabled(true);
        let on = bench("dist step convnet_tiny [tracing on]", wu, it, || {
            tr.step().expect("obs step");
        });
        obs::set_enabled(false);
        let trace = obs::drain();
        let ov = obs::overlap(&trace);
        let step_ns = off.median() * 1e9;
        let step_ns_traced = on.median() * 1e9;
        println!(
            "obs: disabled span {disabled_span_ns:.1} ns/call, traced/untraced step \
             {:.3}x, comm hidden {:.0}%",
            step_ns_traced / step_ns.max(1e-9),
            ov.hidden_fraction * 100.0
        );
        obj(vec![
            ("disabled_span_ns", Json::from(disabled_span_ns)),
            ("step_ns", Json::from(step_ns)),
            ("step_ns_traced", Json::from(step_ns_traced)),
            ("trace_overhead_ratio", Json::from(step_ns_traced / step_ns.max(1e-9))),
            ("events", Json::from(trace.events.len())),
            ("dropped", Json::from(trace.dropped as f64)),
            ("comm_ns", Json::from(ov.comm_ns as f64)),
            ("compute_ns", Json::from(ov.compute_ns as f64)),
            ("hidden_ns", Json::from(ov.hidden_ns as f64)),
            ("hidden_fraction", Json::from(ov.hidden_fraction)),
            ("critical_path_ns", Json::from(ov.critical_path_ns as f64)),
        ])
    };

    // ---- serve: inference-side tracking. Per-row amortization of the
    // predict executable (1 row pays the full static batch; a full batch
    // amortizes it B-fold), then the micro-batching queue under
    // concurrent single-row clients at two batch caps — cap 1 is the
    // no-coalescing baseline, the model's static batch the served
    // configuration.
    let serve_json = {
        let mut tr = harness::builder("convnet_tiny", optim::sgd())
            .expect("runtime")
            .workers(1)
            .dataset_len(2048)
            .data_seed(7)
            .build()
            .expect("serve trainer");
        let ck = tr.checkpoint().expect("serve checkpoint");
        drop(tr);
        let predictor = std::sync::Arc::new(
            Predictor::from_checkpoint(&manifest, engine.clone(), "convnet_tiny", &ck)
                .expect("predictor"),
        );
        let (b, dim) = (predictor.batch(), predictor.in_dim());
        let rows_full: Vec<Vec<f32>> = (0..b)
            .map(|r| (0..dim).map(|i| ((i * 31 + r * 7) % 17) as f32 / 17.0).collect())
            .collect();

        let one = bench("serve predict 1 row", wu, it, || {
            predictor.logits(&rows_full[..1]).expect("predict");
        });
        let full = bench(&format!("serve predict {b} rows"), wu, it, || {
            predictor.logits(&rows_full).expect("predict");
        });
        let (one_ns, full_ns) = (one.median() * 1e9, full.median() * 1e9);
        let forward = vec![
            obj(vec![
                ("rows", Json::from(1usize)),
                ("ns", Json::from(one_ns)),
                ("ns_per_row", Json::from(one_ns)),
            ]),
            obj(vec![
                ("rows", Json::from(b)),
                ("ns", Json::from(full_ns)),
                ("ns_per_row", Json::from(full_ns / b as f64)),
            ]),
        ];

        let n_requests = if quick { 16 } else { 128 };
        let mut queue_entries: Vec<Json> = Vec::new();
        for max_batch in [1usize, b] {
            let queue = BatchQueue::new(QueueCfg { max_batch, max_wait_us: 500 });
            let qb = queue.clone();
            let pb = predictor.clone();
            let batcher = std::thread::spawn(move || {
                qb.run(|rows| pb.logits(rows).map_err(|e| e.to_string()))
            });
            let t_wall = std::time::Instant::now();
            let clients = 4usize;
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let q = queue.clone();
                    let row = rows_full[c % b].clone();
                    let per_client = n_requests / clients;
                    std::thread::spawn(move || {
                        let mut lat = Vec::with_capacity(per_client);
                        for _ in 0..per_client {
                            let t0 = std::time::Instant::now();
                            q.enqueue(vec![row.clone()])
                                .expect("enqueue")
                                .wait()
                                .expect("predict");
                            lat.push(t0.elapsed().as_secs_f64());
                        }
                        lat
                    })
                })
                .collect();
            let mut lat = spngd::util::stats::Summary::new();
            for h in handles {
                for l in h.join().expect("client") {
                    lat.push(l);
                }
            }
            let wall = t_wall.elapsed().as_secs_f64();
            queue.shutdown();
            batcher.join().expect("batcher");
            use std::sync::atomic::Ordering;
            let batches = queue.stats.batches.load(Ordering::Relaxed);
            let rows = queue.stats.rows.load(Ordering::Relaxed);
            println!(
                "serve queue max_batch={max_batch}: {rows} rows in {batches} batches, \
                 p50 {:.0} ns, p99 {:.0} ns, {:.0} rows/s",
                lat.percentile(50.0) * 1e9,
                lat.percentile(99.0) * 1e9,
                rows as f64 / wall.max(1e-9)
            );
            queue_entries.push(obj(vec![
                ("max_batch", Json::from(max_batch)),
                ("requests", Json::from(lat.len())),
                ("batches", Json::from(batches as f64)),
                ("rows", Json::from(rows as f64)),
                ("p50_ns", Json::from(lat.percentile(50.0) * 1e9)),
                ("p99_ns", Json::from(lat.percentile(99.0) * 1e9)),
                ("throughput_rps", Json::from(rows as f64 / wall.max(1e-9))),
            ]));
        }
        obj(vec![
            ("model", Json::from("convnet_tiny")),
            ("batch", Json::from(b)),
            ("forward", Json::Arr(forward)),
            ("queue", Json::Arr(queue_entries)),
        ])
    };

    let report = obj(vec![
        ("schema", Json::from("spngd-bench-native/6")),
        ("model", Json::from(model_name.clone())),
        ("threads", Json::from(threads)),
        ("quick", Json::from(quick)),
        ("step", step.json()),
        ("kernels", Json::Arr(entries.iter().map(Entry::json).collect())),
        ("workers", Json::Arr(dist_entries)),
        ("optimizers", Json::Arr(optim_entries)),
        ("data", Json::Arr(data_entries)),
        ("simd", Json::Arr(simd_entries)),
        ("precision", Json::Arr(precision_entries)),
        ("obs", obs_json),
        ("serve", serve_json),
    ]);
    let out_path = parsed.get("out");
    std::fs::write(out_path, report.to_string_pretty()).expect("write bench report");
    println!("\nwrote {out_path}: step {:.2}x vs naive at {threads} threads", step.speedup());
}
