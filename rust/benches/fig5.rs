//! Fig. 5 bench: time/step vs #GPUs for every practical-NGD technique
//! combination (1mc/emp × fullBN/unitBN × ±stale).
//!
//! Measures the real coordinator profile on this machine, then replays it
//! through the α-β cluster model (V100/IB constants). The paper's claims
//! checked here: superlinear scaling to 64 GPUs from model-parallel
//! inversion, near-ideal 128→1024 scaling for emp+unitBN+stale, and the
//! technique ordering (1mc+fullBN slowest … emp+unitBN+stale fastest).

use std::sync::Arc;

use spngd::collectives::cost::ClusterModel;
use spngd::harness;
use spngd::optim::{Fisher, SpNgd};
use spngd::simulator;

fn main() {
    let mut tr = harness::builder("convnet_small", Arc::new(SpNgd::default()))
        .expect("runtime")
        .workers(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()
        .expect("trainer");
    for _ in 0..6 {
        tr.step().unwrap();
    }
    let base = tr.profile();

    let opt1 = Arc::new(SpNgd { fisher: Fisher::OneMc, ..SpNgd::default() });
    let mut tr1 = harness::builder("convnet_small", opt1)
        .expect("runtime")
        .workers(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()
        .expect("trainer");
    for _ in 0..6 {
        tr1.step().unwrap();
    }
    let p1 = tr1.profile();
    let extra_bwd = ((p1.t_forward + p1.t_backward) - (base.t_forward + base.t_backward)).max(0.0);

    // stale fraction from a longer accumulation run (statistics at our
    // batch scale need α=0.3; the paper's α=0.1 applies at BS≥4K)
    let opt_s = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
    let mut tr_s = harness::builder("convnet_small", opt_s)
        .expect("runtime")
        .workers(2)
        .grad_accum(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()
        .expect("trainer");
    for _ in 0..30 {
        tr_s.step().unwrap();
    }
    let stale_fraction = tr_s.comm_reduction();

    let deltas = simulator::TechniqueDeltas {
        t_extra_bwd_1mc: extra_bwd,
        t_full_bn_extra: base.t_inverse * 0.5,
        full_bn_extra_bytes: base.stats_bytes * 0.25,
        stale_fraction,
    };
    let variants: Vec<simulator::Variant> = simulator::fig5_techniques()
        .iter()
        .map(|&t| simulator::derive(&base, &deltas, t))
        .collect();
    let gpus = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
    let rows = simulator::sweep(&variants, &gpus, &ClusterModel::default());

    println!("\n=== Fig. 5: time/step (ms) vs #GPUs, 32 images/GPU ===");
    print!("{:>20}", "technique");
    for g in &gpus {
        print!("{g:>8}");
    }
    println!();
    for row in &rows {
        print!("{:>20}", row.label);
        for (_, t) in &row.points {
            print!("{:>8.1}", t * 1e3);
        }
        println!();
    }

    // paper-shape assertions
    let at = |row: &simulator::SweepRow, g: usize| {
        row.points.iter().find(|&&(p, _)| p == g).unwrap().1
    };
    let best = rows.last().unwrap();
    let sup = at(best, 1) / at(best, 64);
    let ideal = at(best, 1024) / at(best, 128);
    println!("\nsuperlinear 1→64: {sup:.2}x speedup (paper: ~3-4x; >1 required)");
    println!("near-ideal 128→1024: {ideal:.2}x (paper ≈1)");
    assert!(sup > 1.0, "superlinear region missing");
    assert!(ideal < 1.5, "128→1024 should be near-ideal");
    for g in [1usize, 64, 1024] {
        assert!(at(&rows[0], g) >= at(&rows[3], g), "1mc+fullBN >= emp+unitBN at {g}");
        assert!(at(&rows[4], g) <= at(&rows[3], g), "stale fastest at {g}");
    }
    println!("fig5 shape checks PASSED (stale fraction measured: {:.1}%)", stale_fraction * 100.0);
}
