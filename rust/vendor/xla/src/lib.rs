//! Stub of the `xla` crate surface `spngd`'s PJRT engine compiles against.
//!
//! The container image that runs the tier-1 verify has no network and no
//! XLA toolchain, so the real PJRT bindings cannot be a registry
//! dependency. This stub keeps `--features pjrt` *compiling* everywhere
//! (CI builds it to prevent bitrot); every entry point that would touch
//! PJRT returns an error at runtime. To actually execute HLO artifacts,
//! point the `xla` path dependency in `rust/Cargo.toml` at a build of the
//! real bindings — the API below matches the calls `runtime/engine.rs`
//! and `runtime/tensor.rs` make.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type returned by every stub entry point.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: this binary was built against the vendored `xla` stub; \
         point the `xla` path dependency at real PJRT bindings to run HLO artifacts"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Element types the engine can decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    U32,
    F32,
    F64,
}

/// Array shape view (stub).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn scalar(_value: u32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        stub("Literal::ty")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }
}
