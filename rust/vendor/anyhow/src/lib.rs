//! Vendored stand-in for the `anyhow` crate.
//!
//! The build must work from a clean checkout with no network and no cargo
//! registry cache (see ROADMAP: tier-1 verify), so the crate dependency
//! closure has to be path-only. This module implements the subset of
//! anyhow's API the workspace uses: [`Error`] as a context chain,
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. `{:#}` formatting prints
//! the full `outer: ...: root` chain, as anyhow does.

use std::fmt;

/// An error made of a context chain: outermost context first, root cause
/// last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, [])) => f.write_str(head),
            Some((head, rest)) => {
                writeln!(f, "{head}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, cause) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {cause}")?;
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`; that
// keeps this blanket conversion coherent with the reflexive `From<Error>`
// (the same trick real anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(cause) = src {
            chain.push(cause.to_string());
            src = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err()
            .context("loading runtime");
        assert_eq!(format!("{e}"), "loading runtime");
        assert_eq!(format!("{e:#}"), "loading runtime: reading manifest: missing");
        assert_eq!(e.root_cause(), "missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("index lookup").unwrap_err();
        assert_eq!(format!("{e:#}"), "index lookup");
        assert_eq!(Some(3).context("present").unwrap(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(1);
        let v = ok.with_context(|| panic!("must not evaluate")).unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{:#}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{:#}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("custom {}", 7);
        assert_eq!(format!("{e}"), "custom 7");
    }
}
