//! Differential suite for the `dist` ring collectives: the concurrent
//! shared-memory ReduceScatterV / AllGatherV / AllReduce must be
//! bit-identical to single-threaded reference reductions (canonical lane
//! order, f64 accumulators) across worker counts and odd chunk sizes,
//! and their byte accounting must match `SimComm` exactly.

use std::sync::Arc;

use spngd::collectives::comm::{Collective, Precision, SimComm, StatClass};
use spngd::dist::RingComm;
use spngd::linalg::Mat;
use spngd::util::rng::Rng;

fn rand_lanes(rng: &mut Rng, lanes: usize, n: usize) -> Vec<Vec<f32>> {
    (0..lanes)
        .map(|_| (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * 3.0).collect())
        .collect()
}

/// Single-threaded reference: mean over lanes in canonical order, f64.
fn reference_mean(lanes: &[Vec<f32>]) -> Vec<f32> {
    let n = lanes[0].len();
    let inv = 1.0 / lanes.len() as f64;
    (0..n)
        .map(|i| {
            let mut acc = 0.0f64;
            for l in lanes {
                acc += l[i] as f64;
            }
            (acc * inv) as f32
        })
        .collect()
}

fn rand_mats(rng: &mut Rng, lanes: usize, dims: &[(usize, usize)]) -> Vec<Vec<Mat>> {
    (0..lanes)
        .map(|_| {
            dims.iter()
                .map(|&(r, c)| {
                    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
                })
                .collect()
        })
        .collect()
}

#[test]
fn all_reduce_matches_reference_across_workers_and_chunks() {
    let mut rng = Rng::new(11);
    // odd element counts × odd chunk sizes × worker counts 1/2/3/8
    for &n in &[1usize, 17, 257, 1031] {
        for &chunk in &[1usize, 7, 129, 100_000] {
            for &p in &[1usize, 2, 3, 8] {
                let lanes_n = p * 2; // two micro-lanes per worker
                let lanes = rand_lanes(&mut rng, lanes_n, n);
                let want = reference_mean(&lanes);
                let mut got = lanes.clone();
                let mut ring = RingComm::new(p);
                ring.chunk_elems = chunk;
                Collective::all_reduce_mean(&ring, &mut got);
                for lane in &got {
                    assert_eq!(lane, &want, "n={n} chunk={chunk} p={p}");
                }
            }
        }
    }
}

#[test]
fn all_reduce_matches_simcomm_bitwise_and_bytewise() {
    let mut rng = Rng::new(23);
    for &p in &[1usize, 2, 3, 8] {
        let lanes = rand_lanes(&mut rng, p * 3, 401);
        let sim = SimComm::new(p);
        let mut ring = RingComm::new(p);
        ring.chunk_elems = 13;
        let mut a = lanes.clone();
        let mut b = lanes.clone();
        sim.all_reduce_mean(&mut a);
        Collective::all_reduce_mean(&ring, &mut b);
        assert_eq!(a, b, "p={p}");
        let ss = Collective::stats(&sim);
        let rs = Collective::stats(&ring);
        assert_eq!(ss.ar_grads, rs.ar_grads, "p={p}");
        assert_eq!(ss.num_ops, rs.num_ops, "p={p}");
    }
}

#[test]
fn reduce_scatter_v_matches_simcomm_bitwise_and_bytewise() {
    let mut rng = Rng::new(31);
    // odd square dims (packed accounting) + one non-square (dense)
    let dims = [(5, 5), (3, 3), (17, 17), (4, 3)];
    let classes = [StatClass::A, StatClass::GorF, StatClass::A, StatClass::GorF];
    for &p in &[1usize, 2, 3, 8] {
        let lanes = rand_mats(&mut rng, p * 2, &dims);
        let sim = SimComm::new(p);
        let ring = RingComm::new(p);
        let want = sim.reduce_scatter_v(&lanes, &classes);
        let got = Collective::reduce_scatter_v(&ring, &lanes, &classes);
        assert_eq!(want.len(), got.len());
        for (wm, gm) in want.iter().zip(got.iter()) {
            assert_eq!(wm.data, gm.data, "p={p}");
        }
        let ss = Collective::stats(&sim);
        let rs = Collective::stats(&ring);
        assert_eq!(ss.rs_stats_a, rs.rs_stats_a, "p={p}");
        assert_eq!(ss.rs_stats_g, rs.rs_stats_g, "p={p}");
        assert_eq!(ss.num_ops, rs.num_ops, "p={p}");
    }
}

#[test]
fn reduce_scatter_v_concurrent_publish_out_of_order() {
    // workers publish their statistics in reverse item order and at
    // different times; owners must still reduce every item correctly
    let p = 4;
    let lanes_n = 4;
    let n_items = 6;
    let mut rng = Rng::new(41);
    let dims: Vec<(usize, usize)> = (0..n_items).map(|i| (i + 2, i + 2)).collect();
    let lanes = rand_mats(&mut rng, lanes_n, &dims);
    // reference through SimComm (canonical semantics)
    let classes = vec![StatClass::A; n_items];
    let want = SimComm::new(p).reduce_scatter_v(&lanes, &classes);

    let ring = Arc::new(RingComm::new(p));
    ring.begin_stats(n_items, lanes_n);
    let results: Vec<std::sync::Mutex<Option<Mat>>> =
        (0..n_items).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for rank in 0..p {
            let ring = ring.clone();
            let lanes = &lanes;
            let results = &results;
            s.spawn(move || {
                // publish own lane's items in reverse order
                for (i, m) in lanes[rank].iter().enumerate().rev() {
                    ring.publish_stat(i, rank, m.clone());
                }
                // reduce owned items (round-robin)
                let mut i = rank;
                while i < n_items {
                    let m = ring.reduce_stat(i, StatClass::A);
                    *results[i].lock().unwrap() = Some(m);
                    i += p;
                }
            });
        }
    });
    for (i, w) in want.iter().enumerate() {
        let got = results[i].lock().unwrap().take().expect("item reduced");
        assert_eq!(w.data, got.data, "item {i}");
    }
}

#[test]
fn all_gather_v_moves_owner_segments() {
    let p = 3;
    let owner_of: Vec<usize> = (0..7).map(|i| i % p).collect();
    let ring = Arc::new(RingComm::new(p));
    // each rank starts with authoritative data only for its own segments
    let make_segs = |rank: usize| -> Vec<Vec<f32>> {
        owner_of
            .iter()
            .enumerate()
            .map(|(i, &o)| {
                if o == rank {
                    vec![(i * 10 + o) as f32; i + 1]
                } else {
                    vec![0.0; i + 1]
                }
            })
            .collect()
    };
    let mut all: Vec<Vec<Vec<f32>>> = (0..p).map(make_segs).collect();
    std::thread::scope(|s| {
        for (rank, segs) in all.iter_mut().enumerate() {
            let ring = ring.clone();
            let owner_of = &owner_of;
            s.spawn(move || {
                ring.all_gather_v(rank, segs, owner_of);
            });
        }
    });
    // every rank now holds every owner's segment
    for rank in 0..p {
        for (i, &o) in owner_of.iter().enumerate() {
            assert_eq!(all[rank][i], vec![(i * 10 + o) as f32; i + 1], "rank {rank} seg {i}");
        }
    }
    // bytes: total elems 1+2+..+7 = 28, ring factor 2/3, f32 wire
    let total: usize = (1..=7).sum();
    let want_bytes = (total as f64 * (2.0 / 3.0) * 4.0).round() as u64;
    assert_eq!(Collective::stats(ring.as_ref()).ag_params, want_bytes);
}

#[test]
fn all_gather_accounting_matches_simcomm() {
    for &p in &[1usize, 2, 5] {
        let sim = SimComm::new(p);
        let ring = RingComm::new(p);
        sim.all_gather_v_params(12_345);
        Collective::all_gather_v_params(&ring, 12_345);
        assert_eq!(
            Collective::stats(&sim).ag_params,
            Collective::stats(&ring).ag_params,
            "p={p}"
        );
    }
}

/// The rank-level post-by-move entry points: each rank moves its lanes
/// in and takes one mean copy out. Results must match the reference
/// reduction and the byte accounting must be unchanged from `SimComm`
/// (post-by-move is a memcpy optimization, not a protocol change).
#[test]
fn grad_post_by_move_per_rank_drain_matches_reference_and_bytes() {
    let mut rng = Rng::new(61);
    for &p in &[1usize, 2, 3, 8] {
        for &chunk in &[1usize, 13, 100_000] {
            let total = p * 2;
            let n = 257;
            let lanes = rand_lanes(&mut rng, total, n);
            let want = reference_mean(&lanes);
            let mut ring = RingComm::new(p);
            ring.chunk_elems = chunk;
            let ring = Arc::new(ring);
            let means: Vec<std::sync::Mutex<Vec<f32>>> =
                (0..p).map(|_| std::sync::Mutex::new(Vec::new())).collect();
            std::thread::scope(|s| {
                for rank in 0..p {
                    let ring = ring.clone();
                    let lanes = &lanes;
                    let means = &means;
                    s.spawn(move || {
                        let my: Vec<(usize, Vec<f32>)> = (0..total)
                            .filter(|g| g % p == rank)
                            .map(|g| (g, lanes[g].clone()))
                            .collect();
                        ring.grad_post(my, total);
                        *means[rank].lock().unwrap() = ring.grad_finish();
                    });
                }
            });
            for (rank, m) in means.iter().enumerate() {
                assert_eq!(*m.lock().unwrap(), want, "rank {rank} p={p} chunk={chunk}");
            }
            // byte accounting identical to SimComm's AllReduce formula
            let sim = SimComm::new(p);
            sim.all_reduce_mean(&mut lanes.clone());
            assert_eq!(
                Collective::stats(ring.as_ref()).ar_grads,
                Collective::stats(&sim).ar_grads,
                "p={p} chunk={chunk}"
            );
            assert_eq!(Collective::stats(ring.as_ref()).num_ops, 1, "one AllReduce op");
        }
    }
}

/// Post/finish rounds must be reusable back-to-back (the trainer runs
/// one per step) with the per-rank drain count resetting each round.
#[test]
fn grad_rounds_reusable_with_rank_drains() {
    let p = 3;
    let ring = Arc::new(RingComm::new(p));
    let mut rng = Rng::new(67);
    for _ in 0..5 {
        let total = p;
        let lanes = rand_lanes(&mut rng, total, 41);
        let want = reference_mean(&lanes);
        std::thread::scope(|s| {
            for rank in 0..p {
                let ring = ring.clone();
                let lanes = &lanes;
                let want = &want;
                s.spawn(move || {
                    ring.grad_post(vec![(rank, lanes[rank].clone())], total);
                    assert_eq!(ring.grad_finish(), *want, "rank {rank}");
                });
            }
        });
    }
}

#[test]
fn mixed_wire_halves_ring_bytes() {
    let mut lanes = rand_lanes(&mut Rng::new(7), 4, 100);
    let mut ring16 = RingComm::new(2);
    ring16.precision = Precision::Mixed;
    let ring32 = RingComm::new(2);
    Collective::all_reduce_mean(&ring16, &mut lanes);
    let mut lanes2 = rand_lanes(&mut Rng::new(7), 4, 100);
    Collective::all_reduce_mean(&ring32, &mut lanes2);
    assert_eq!(
        2 * Collective::stats(&ring16).ar_grads,
        Collective::stats(&ring32).ar_grads
    );
}

/// Under `Mixed`, RingComm quantizes at its serialization points
/// (publish / post / chunked AG) while SimComm quantizes whole lanes up
/// front — the per-element op sequence is identical, so the engines must
/// stay bitwise- and bytewise-identical in mixed mode too, across worker
/// counts and odd chunk sizes.
#[test]
fn mixed_mode_ring_matches_simcomm_bitwise_and_bytewise() {
    let mut rng = Rng::new(97);
    let dims = [(5, 5), (3, 3), (9, 9), (4, 3)];
    let classes = [StatClass::A, StatClass::GorF, StatClass::A, StatClass::GorF];
    for &p in &[1usize, 2, 3, 8] {
        for &chunk in &[1usize, 13, 100_000] {
            let mut sim = SimComm::new(p);
            sim.precision = Precision::Mixed;
            let mut ring = RingComm::new(p);
            ring.precision = Precision::Mixed;
            ring.chunk_elems = chunk;

            let lanes = rand_lanes(&mut rng, p * 2, 401);
            let mut a = lanes.clone();
            let mut b = lanes;
            sim.all_reduce_mean(&mut a);
            Collective::all_reduce_mean(&ring, &mut b);
            assert_eq!(a, b, "AR p={p} chunk={chunk}");

            let mats = rand_mats(&mut rng, p * 2, &dims);
            let want = sim.reduce_scatter_v(&mats, &classes);
            let got = Collective::reduce_scatter_v(&ring, &mats, &classes);
            for (wm, gm) in want.iter().zip(got.iter()) {
                assert_eq!(wm.data, gm.data, "RS p={p} chunk={chunk}");
            }

            sim.all_gather_v_params(12_345);
            Collective::all_gather_v_params(&ring, 12_345);
            let ss = Collective::stats(&sim);
            let rs = Collective::stats(&ring);
            assert_eq!(ss.ar_grads, rs.ar_grads, "p={p} chunk={chunk}");
            assert_eq!(ss.rs_stats_a, rs.rs_stats_a, "p={p} chunk={chunk}");
            assert_eq!(ss.rs_stats_g, rs.rs_stats_g, "p={p} chunk={chunk}");
            assert_eq!(ss.ag_params, rs.ag_params, "p={p} chunk={chunk}");
        }
    }
}

/// Mixed-mode results are invariant to the AllReduce chunk size: the
/// mean is quantized per element, so where the chunk boundaries fall
/// cannot change any value.
#[test]
fn mixed_mode_chunk_invariant() {
    let lanes = rand_lanes(&mut Rng::new(101), 6, 257);
    let mut base: Option<Vec<Vec<f32>>> = None;
    for &chunk in &[1usize, 7, 129, 100_000] {
        let mut ring = RingComm::new(3);
        ring.precision = Precision::Mixed;
        ring.chunk_elems = chunk;
        let mut got = lanes.clone();
        Collective::all_reduce_mean(&ring, &mut got);
        match &base {
            None => base = Some(got),
            Some(b) => assert_eq!(&got, b, "chunk={chunk}"),
        }
    }
}

#[test]
fn rounds_are_reusable_across_steps() {
    let p = 3;
    let ring = RingComm::new(p);
    let mut rng = Rng::new(53);
    for _ in 0..5 {
        let lanes = rand_lanes(&mut rng, p, 37);
        let want = reference_mean(&lanes);
        let mut got = lanes.clone();
        Collective::all_reduce_mean(&ring, &mut got);
        assert_eq!(got[0], want);
        let mats = rand_mats(&mut rng, p, &[(4, 4), (6, 6)]);
        let classes = [StatClass::A, StatClass::GorF];
        let want_m = SimComm::new(p).reduce_scatter_v(&mats, &classes);
        let got_m = Collective::reduce_scatter_v(&ring, &mats, &classes);
        for (a, b) in want_m.iter().zip(got_m.iter()) {
            assert_eq!(a.data, b.data);
        }
        assert!(Collective::take_step_stats(&ring).total() > 0);
    }
}
