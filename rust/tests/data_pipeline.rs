//! Differential + end-to-end suite for the first-class data pipeline:
//!
//! (a) **pin**: `--data synth` training through the new
//!     `DataSource`/`TransformChain`/`Loader` stack is bit-identical with
//!     prefetch on and off, under both dist engines, and the registry
//!     default is bit-identical to naming `synth` explicitly (together
//!     with `tests/optim_api.rs`'s frozen pre-refactor `RefTrainer` —
//!     which pins the same composition against the pre-refactor step
//!     math — this proves the redesign changed no numerics);
//! (b) the transform chain reproduces the legacy fixed `Augment`
//!     pipeline bit-for-bit at a fixed seed (frozen in-test copy);
//! (c) loader sharding invariance: fixed lane total, varying worker
//!     count / engine, bitwise-equal training for the new sources too;
//! (d) the CIFAR-10-binary reader round-trips and the in-repo fixture
//!     trains end to end (32×32 auto-downsampled onto the 8×8 model);
//! (e) every registered source trains through `TrainerBuilder`, and
//!     unknown `--data` names are a hard registry error listing choices.

use std::path::PathBuf;
use std::sync::Arc;

use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::data::{self, AugmentCfg, Batch, CifarBin, DataSource, SynthDataset, TransformChain};
use spngd::optim::{self, HyperParams, Preconditioner};
use spngd::util::rng::Rng;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/cifar10_tiny.bin")
}

fn base_builder(model: &str, opt: Arc<dyn Preconditioner>) -> TrainerBuilder {
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0,
        e_end: 200.0,
        eta0: 0.02,
        m0: 0.018,
        lambda: 2.5e-3,
    };
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

// ------------------------------------------------------------------
// (a) pin: prefetch is bitwise-neutral, registry default == synth

#[test]
fn prefetch_on_equals_off_bitwise_both_engines() {
    for dist in [DistMode::Sequential, DistMode::Threaded] {
        let mut on =
            base_builder("mlp", optim::spngd()).dist(dist).prefetch(true).build().unwrap();
        let mut off =
            base_builder("mlp", optim::spngd()).dist(dist).prefetch(false).build().unwrap();
        assert!(on.loader().prefetch_enabled());
        assert!(!off.loader().prefetch_enabled());
        for i in 0..5 {
            let ra = on.step().unwrap();
            let rb = off.step().unwrap();
            assert_eq!(
                ra.loss.to_bits(),
                rb.loss.to_bits(),
                "loss diverged at step {i} ({dist:?})"
            );
            assert_eq!(
                flat_params(&on),
                flat_params(&off),
                "params diverged at step {i} ({dist:?})"
            );
        }
        // validation stream unaffected by the prefetch schedule
        let va = on.evaluate(2).unwrap();
        let vb = off.evaluate(2).unwrap();
        assert_eq!(va.0.to_bits(), vb.0.to_bits());
    }
}

#[test]
fn named_synth_matches_registry_default_bitwise() {
    let mut dflt = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut named = base_builder("mlp", optim::spngd()).data("synth").build().unwrap();
    assert_eq!(named.loader().source().name(), "synth");
    for i in 0..4 {
        let ra = dflt.step().unwrap();
        let rb = named.step().unwrap();
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "step {i}");
        assert_eq!(flat_params(&dflt), flat_params(&named), "step {i}");
    }
}

/// Augmentation enabled (mixup + erasing, the paper's §6.1 pipeline) is
/// equally schedule-independent — the per-lane chain state advances
/// identically inline and on the prefetch pool.
#[test]
fn prefetch_neutral_with_augmentation_enabled() {
    let mk = |prefetch: bool| {
        base_builder("mlp", optim::spngd())
            .augment(AugmentCfg::default())
            .grad_accum(2)
            .prefetch(prefetch)
            .build()
            .unwrap()
    };
    let mut on = mk(true);
    let mut off = mk(false);
    for i in 0..5 {
        let ra = on.step().unwrap();
        let rb = off.step().unwrap();
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "step {i}");
        assert_eq!(flat_params(&on), flat_params(&off), "step {i}");
    }
}

// ------------------------------------------------------------------
// (b) transform chain == legacy Augment, bitwise
//
// Frozen copy of the pre-refactor `data::augment::Augment` (one RNG
// shared by erase + mixup, erase first). Do NOT "clean this up" to call
// the new transforms — its value is being the original op sequence.

struct LegacyAugment {
    cfg: AugmentCfg,
    prev: Option<Batch>,
    rng: Rng,
}

impl LegacyAugment {
    fn new(cfg: AugmentCfg, seed: u64) -> Self {
        LegacyAugment { cfg, prev: None, rng: Rng::new(seed ^ 0xA06_3E27) }
    }

    fn apply(&mut self, mut batch: Batch) -> Batch {
        if self.cfg.erase_p > 0.0 {
            self.random_erase(&mut batch);
        }
        if self.cfg.alpha_mixup > 0.0 {
            batch = self.running_mixup(batch);
        }
        batch
    }

    fn running_mixup(&mut self, raw: Batch) -> Batch {
        let out = match &self.prev {
            None => raw.clone(),
            Some(prev) if prev.x.shape == raw.x.shape => {
                let lam = self.rng.beta_symmetric(self.cfg.alpha_mixup) as f32;
                let mut x = raw.x.clone();
                let mut t = raw.t.clone();
                for (o, p) in x.data.iter_mut().zip(prev.x.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                for (o, p) in t.data.iter_mut().zip(prev.t.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                Batch { x, t }
            }
            Some(_) => raw.clone(),
        };
        self.prev = Some(out.clone());
        out
    }

    fn random_erase(&mut self, batch: &mut Batch) {
        let dims = batch.x.shape.clone();
        let (b, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
        for i in 0..b {
            if !self.rng.bool(self.cfg.erase_p) {
                continue;
            }
            let area = h as f64 * w as f64
                * self.rng.range_f64(self.cfg.erase_area.0, self.cfg.erase_area.1);
            let mut aspect =
                self.rng.range_f64(self.cfg.erase_aspect.0, self.cfg.erase_aspect.1);
            if self.rng.bool(0.5) {
                aspect = 1.0 / aspect;
            }
            let he = ((area * aspect).sqrt().round() as usize).clamp(1, h);
            let we = ((area / aspect).sqrt().round() as usize).clamp(1, w);
            let y0 = self.rng.below_usize(h - he + 1);
            let x0 = self.rng.below_usize(w - we + 1);
            for ch in 0..c {
                for y in y0..y0 + he {
                    let base = ((i * c + ch) * h + y) * w;
                    for x in x0..x0 + we {
                        batch.x.data[base + x] = 0.0;
                    }
                }
            }
        }
    }
}

#[test]
fn transform_chain_matches_legacy_augment_bitwise() {
    for cfg in [
        AugmentCfg::default(),
        AugmentCfg { alpha_mixup: 0.0, ..AugmentCfg::default() },
        AugmentCfg { erase_p: 0.0, ..AugmentCfg::default() },
        AugmentCfg::disabled(),
    ] {
        let source = SynthDataset::new(10, 3, 8, 8, 256, 11);
        let seed = 0xF00D;
        let mut legacy = LegacyAugment::new(cfg.clone(), seed);
        let mut chain = TransformChain::standard(&cfg, seed);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for step in 0..6 {
            let a = legacy.apply(source.batch(4, &mut r1));
            let b = chain.apply(source.batch(4, &mut r2));
            assert_eq!(a.x.data, b.x.data, "x diverged at step {step} (cfg {cfg:?})");
            assert_eq!(a.t.data, b.t.data, "t diverged at step {step} (cfg {cfg:?})");
        }
    }
}

// ------------------------------------------------------------------
// (c) sharding invariance for the new sources

#[test]
fn tensor_source_worker_invariance_across_engines() {
    let mk = |workers: usize, accum: usize, dist: DistMode| {
        base_builder("mlp", optim::spngd())
            .data("tensor")
            .workers(workers)
            .grad_accum(accum)
            .dist(dist)
            .build()
            .unwrap()
    };
    let mut a = mk(1, 4, DistMode::Sequential);
    let mut b = mk(2, 2, DistMode::Sequential);
    let mut c = mk(4, 1, DistMode::Threaded);
    for i in 0..3 {
        let ra = a.step().unwrap();
        let rb = b.step().unwrap();
        let rc = c.step().unwrap();
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "W=1 vs W=2 at step {i}");
        assert_eq!(ra.loss.to_bits(), rc.loss.to_bits(), "W=1 vs threaded W=4 at step {i}");
        assert_eq!(flat_params(&a), flat_params(&b), "params W=1 vs W=2 at step {i}");
        assert_eq!(flat_params(&a), flat_params(&c), "params W=1 vs threaded at step {i}");
    }
}

// ------------------------------------------------------------------
// (d) CIFAR-10 binary format

#[test]
fn cifar_binary_round_trip() {
    let dir = std::env::temp_dir().join("spngd_cifar_rt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rt.bin");
    // deterministic records: label i%10, pixels (i*31 + j) % 256
    let records: Vec<(u8, Vec<u8>)> = (0..5u8)
        .map(|i| {
            let px: Vec<u8> =
                (0..3072u32).map(|j| ((i as u32 * 31 + j) % 256) as u8).collect();
            (i % 10, px)
        })
        .collect();
    CifarBin::write_records(&path, &records).unwrap();
    let ds = CifarBin::open(&path).unwrap();
    let spec = ds.spec();
    assert_eq!((spec.classes, spec.channels, spec.h, spec.w, spec.len), (10, 3, 32, 32, 5));
    for (i, (label, px)) in records.iter().enumerate() {
        let (l, p) = ds.record_bytes(i);
        assert_eq!(l, *label, "label {i}");
        assert_eq!(p, &px[..], "pixels {i}");
        // normalization contract: byte/127.5 - 1
        let mut rng = Rng::new(0);
        let (img, _) = DataSource::sample(&ds, i, &mut rng);
        assert_eq!(img[0].to_bits(), (px[0] as f32 / 127.5 - 1.0).to_bits());
    }
}

#[test]
fn cifar_fixture_parses_and_has_expected_content() {
    let ds = CifarBin::open(&fixture_path()).unwrap();
    assert_eq!(ds.spec().len, 16, "fixture has 16 records");
    // the fixture's deterministic pattern: label = r % 10,
    // pixel(r, c, y, x) = (r*37 + c*11 + y*5 + x*3) % 256
    for r in [0usize, 7, 15] {
        let (label, px) = ds.record_bytes(r);
        assert_eq!(label as usize, r % 10, "record {r} label");
        for (c, y, x) in [(0usize, 0usize, 0usize), (1, 3, 5), (2, 31, 31)] {
            let want = ((r * 37 + c * 11 + y * 5 + x * 3) % 256) as u8;
            assert_eq!(px[(c * 32 + y) * 32 + x], want, "record {r} pixel ({c},{y},{x})");
        }
    }
}

#[test]
fn cifar_fixture_trains_convnet_tiny_end_to_end() {
    // 32×32 source onto the 8×8 model: the builder auto-fits a 4×4
    // average-pool Downsample into every lane chain
    let mut tr = base_builder("convnet_tiny", optim::spngd())
        .data("cifar10")
        .data_path(fixture_path())
        .build()
        .unwrap();
    assert_eq!(tr.loader().source().name(), "cifar10");
    assert_eq!(tr.loader().out_spec(), (10, (3, 8, 8)));
    for i in 0..2 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "step {i}");
    }
    let (vl, va) = tr.evaluate(2).unwrap();
    assert!(vl.is_finite() && (0.0..=1.0).contains(&va));
}

#[test]
fn cifar_without_path_is_actionable_error() {
    let err = base_builder("convnet_tiny", optim::spngd())
        .data("cifar10")
        .build()
        .err()
        .expect("cifar10 without a path must fail")
        .to_string();
    assert!(err.contains("--data-path"), "{err}");
}

// ------------------------------------------------------------------
// (e) registry end-to-end

#[test]
fn every_registered_source_trains_through_the_builder() {
    for &name in data::DATA_NAMES {
        // cifar10 is 32×32/10-class: pair each source with a model its
        // geometry reaches (equal grid or integer downsample)
        let mut b = base_builder("mlp", optim::spngd()).data(name);
        if name == "cifar10" {
            b = b.data_path(fixture_path());
        }
        let mut tr = b.build().unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(tr.loader().source().name(), name);
        for i in 0..3 {
            let rec = tr.step().unwrap_or_else(|e| panic!("{name} step {i}: {e:#}"));
            assert!(rec.loss.is_finite(), "{name} diverged at step {i}");
        }
    }
}

#[test]
fn unknown_data_name_is_hard_error_listing_choices() {
    let err = base_builder("mlp", optim::spngd())
        .data("imagenet")
        .build()
        .err()
        .expect("unknown data name must fail")
        .to_string();
    assert!(err.contains("unknown data source 'imagenet'"), "{err}");
    for name in data::DATA_NAMES {
        assert!(err.contains(name), "error must list '{name}': {err}");
    }
}

#[test]
fn data_stats_track_prep_and_wait() {
    let mut tr = base_builder("mlp", optim::spngd()).prefetch(true).build().unwrap();
    for _ in 0..4 {
        tr.step().unwrap();
    }
    let s = tr.data_stats();
    assert_eq!(s.batches, 4);
    // with prefetch on, at most one extra in-flight buffer is prepped
    assert!(s.prepped >= 4 && s.prepped <= 5, "prepped={}", s.prepped);
    assert!(s.prep_seconds > 0.0 && s.prep_per_batch() > 0.0);
    assert!((0.0..=1.0).contains(&s.hidden_fraction()));
}
