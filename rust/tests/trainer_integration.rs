//! End-to-end coordinator integration over the native backend: SP-NGD
//! training decreases the loss, the stale scheduler skips refreshes, the
//! SGD baseline works, and all practical-NGD modes run. Hermetic — no
//! artifacts, no network (the `data/synth` corpus is generated
//! in-process). Trainers are composed through `TrainerBuilder`.

use std::sync::Arc;

use spngd::collectives::Collective;
use spngd::coordinator::{Trainer, TrainerBuilder};
use spngd::optim::{self, BnMode, Fisher, HyperParams, Preconditioner, SpNgd};

/// The suites' standard composition: flat LR (decay far beyond the test
/// horizon), 2 workers, the 4000-sample corpus at data seed 42.
fn base_builder(model: &str, opt: Arc<dyn Preconditioner>) -> TrainerBuilder {
    let hp = HyperParams {
        p_decay: 2.0,
        e_start: 100.0, // effectively flat LR for these short runs
        e_end: 200.0,
        ..opt.default_hparams()
    };
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

fn make_trainer(b: TrainerBuilder) -> Trainer {
    b.build().unwrap()
}

#[test]
fn spngd_mlp_loss_decreases() {
    let mut tr = make_trainer(base_builder("mlp", optim::spngd()));
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..25 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "loss diverged at step {i}");
        if i == 0 {
            first = rec.loss;
        }
        last = rec.loss;
    }
    assert!(last < first * 0.8, "loss should drop: first={first} last={last}");
}

#[test]
fn one_step_changes_weights() {
    let mut tr = make_trainer(base_builder("mlp", optim::spngd()));
    let before: Vec<f32> = tr.params.iter().flat_map(|p| p.data.clone()).collect();
    tr.step().unwrap();
    let after: Vec<f32> = tr.params.iter().flat_map(|p| p.data.clone()).collect();
    let delta: f32 = before.iter().zip(after.iter()).map(|(a, b)| (a - b).abs()).sum();
    assert!(delta > 0.0, "a training step must move the weights");
    assert!(after.iter().all(|v| v.is_finite()));
}

#[test]
fn sgd_baseline_trains() {
    let mut tr = make_trainer(base_builder("mlp", optim::sgd()));
    let first = tr.step().unwrap().loss;
    let mut last = first;
    for _ in 0..24 {
        last = tr.step().unwrap().loss;
    }
    assert!(last < first, "sgd loss should drop: {first} -> {last}");
    // SGD moves zero statistics bytes and plans zero refreshes
    assert_eq!(tr.comm().stats().stats_total(), 0);
    assert_eq!(tr.log.records[0].total_stats, 0);
}

#[test]
fn stale_scheduler_reduces_refreshes() {
    // small per-step statistics batches fluctuate strongly (the paper's
    // own observation); grad accumulation stabilizes them enough for the
    // scheduler to start stretching intervals within the test budget.
    let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
    let mut tr = make_trainer(base_builder("mlp", opt).grad_accum(4));
    let mut refreshed = 0usize;
    let mut total = 0usize;
    for _ in 0..30 {
        let rec = tr.step().unwrap();
        refreshed += rec.refreshed;
        total += rec.total_stats;
    }
    assert!(refreshed < total, "stale must skip some refreshes: {refreshed}/{total}");
    let red = tr.comm_reduction();
    assert!(red < 1.0 && red > 0.0, "comm reduction {red}");
    // loss still improves under stale statistics
    assert!(tr.log.final_loss() < tr.log.records[0].loss);
}

#[test]
fn convnet_all_modes_one_step() {
    for (fisher, bn) in [
        (Fisher::Emp, BnMode::Unit),
        (Fisher::Emp, BnMode::Full),
        (Fisher::OneMc, BnMode::Unit),
    ] {
        let opt = Arc::new(SpNgd { fisher, bn_mode: bn, ..SpNgd::default() });
        let mut tr = make_trainer(base_builder("convnet_tiny", opt));
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "{fisher:?}/{bn:?}");
        assert!(rec.comm.stats_total() > 0);
        assert_eq!(rec.refreshed, rec.total_stats, "first step refreshes all");
    }
}

#[test]
fn convnet_small_spngd_step_runs() {
    let mut tr = make_trainer(base_builder("convnet_small", optim::spngd()));
    let rec = tr.step().unwrap();
    assert!(rec.loss.is_finite());
    assert_eq!(rec.refreshed, rec.total_stats);
    let rec2 = tr.step().unwrap();
    assert!(rec2.loss.is_finite());
}

#[test]
fn grad_accumulation_mimics_larger_batch() {
    let b = base_builder("mlp", optim::spngd()).grad_accum(4);
    let mut tr = make_trainer(b);
    assert_eq!(tr.cfg.effective_batch(32), 2 * 4 * 32);
    let rec = tr.step().unwrap();
    assert!(rec.loss.is_finite());
    let rec2 = tr.step().unwrap();
    assert!(rec2.loss.is_finite());
}

#[test]
fn evaluation_reports_sane_accuracy() {
    let mut tr = make_trainer(base_builder("mlp", optim::spngd()));
    let (l0, a0) = tr.evaluate(4).unwrap();
    assert!(l0 > 0.0 && (0.0..=1.0).contains(&a0));
    for _ in 0..30 {
        tr.step().unwrap();
    }
    let (l1, a1) = tr.evaluate(4).unwrap();
    assert!(l1 < l0, "val loss should improve: {l0} -> {l1}");
    assert!(a1 >= a0 * 0.8, "val acc not collapsing: {a0} -> {a1}");
}

#[test]
fn profile_has_all_components() {
    let mut tr = make_trainer(base_builder("mlp", optim::spngd()));
    for _ in 0..3 {
        tr.step().unwrap();
    }
    let p = tr.profile();
    assert!(p.t_forward > 0.0);
    assert!(p.t_backward > 0.0);
    assert!(p.t_factors > 0.0);
    assert!(p.t_inverse > 0.0);
    assert!(p.stats_bytes > 0.0);
    assert!(p.n_stats > 0);
}

#[test]
fn mixed_precision_halves_comm_bytes_and_still_trains() {
    use spngd::collectives::comm::Precision;
    let mut a = make_trainer(base_builder("mlp", optim::spngd()));
    let mut b =
        make_trainer(base_builder("mlp", optim::spngd()).precision(Precision::Mixed));
    assert_eq!(b.cfg.precision, Precision::Mixed);
    let ra = a.step().unwrap();
    let rb = b.step().unwrap();
    // gradient + statistics payloads travel f16: both classes halve
    // exactly (p=2 ring bytes are even in f32)
    assert_eq!(
        rb.comm.stats_total() * 2,
        ra.comm.stats_total(),
        "mixed wire should halve stats bytes: {} vs {}",
        rb.comm.stats_total(),
        ra.comm.stats_total()
    );
    assert_eq!(rb.comm.ar_grads * 2, ra.comm.ar_grads, "grad AllReduce halves");
    // parameters always travel f32
    assert_eq!(rb.comm.ag_params, ra.comm.ag_params, "param AllGather unchanged");
    // quantized comm perturbs numerics slightly but must not derail
    // training: both runs converge, and the final losses agree within a
    // 25% relative tolerance (documented in README "Performance")
    let (mut la, mut lb) = (ra.loss, rb.loss);
    for _ in 0..24 {
        la = a.step().unwrap().loss;
        lb = b.step().unwrap().loss;
        assert!(lb.is_finite(), "mixed-precision loss diverged");
    }
    assert!(lb < rb.loss * 0.8, "mixed run should still converge: {} -> {lb}", rb.loss);
    assert!(
        (la - lb).abs() <= 0.25 * la.abs().max(0.1),
        "mixed final loss should track f32: f32={la} mixed={lb}"
    );
}

#[test]
fn fp16_comm_builder_alias_selects_mixed() {
    use spngd::collectives::comm::Precision;
    let tr = make_trainer(base_builder("mlp", optim::spngd()).fp16_comm(true));
    assert_eq!(tr.cfg.precision, Precision::Mixed);
}

#[test]
fn layer_ownership_round_robin() {
    let tr = make_trainer(base_builder("convnet_small", optim::spngd()));
    let owners = tr.layer_owners();
    assert_eq!(owners.len(), 21);
    // round-robin across 2 workers
    for (i, &o) in owners.iter().enumerate() {
        assert_eq!(o, i % 2);
    }
}

#[test]
fn deterministic_given_seed() {
    let mut t1 = make_trainer(base_builder("mlp", optim::spngd()));
    let mut t2 = make_trainer(base_builder("mlp", optim::spngd()));
    for _ in 0..3 {
        let r1 = t1.step().unwrap();
        let r2 = t2.step().unwrap();
        assert_eq!(r1.loss, r2.loss);
        assert_eq!(r1.train_acc, r2.train_acc);
    }
}
