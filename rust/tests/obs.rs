//! Integration tests for the observability layer (`util::obs`):
//!
//! - tracing must be bitwise invisible: identical losses and parameters
//!   with span recording on vs off, across all three dist engines
//!   (sequential, threaded, multi-process) — the acceptance criterion
//!   that lets production runs leave `--trace-out` on without doubt;
//! - a drained trace of a threaded run must satisfy the structural
//!   invariants the Chrome exporter and the overlap accountant rely on
//!   (balanced spans, time-sorted starts, named worker lanes, both comm
//!   and compute categories present) and round-trip through the JSON
//!   writer;
//! - the library sources must stay free of raw `println!`/`eprintln!`:
//!   `util::log` (governed by `SPNGD_LOG`) and the JSONL event stream
//!   are the only sanctioned outputs outside the CLI and the bench
//!   harness.
//!
//! The tracing switch is process-global, so tests that toggle it
//! serialize on a local mutex (other test binaries are separate
//! processes and unaffected).

use std::sync::{Arc, Mutex, MutexGuard};

use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::dist::ProcCfg;
use spngd::optim::{self, HyperParams, Preconditioner};
use spngd::util::json::Json;
use spngd::util::obs;

static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn trace_lock() -> MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Same run shape as `tests/dist_engine.rs` / `tests/dist_proc.rs`.
fn base_builder(model: &str, opt: Arc<dyn Preconditioner>) -> TrainerBuilder {
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0,
        e_end: 200.0,
        eta0: 0.02,
        m0: 0.018,
        lambda: 2.5e-3,
    };
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

fn proc_cfg() -> ProcCfg {
    ProcCfg {
        worker_bin: Some(env!("CARGO_BIN_EXE_spngd").to_string()),
        heartbeat_ms: 25,
        join_timeout_ms: 20_000,
        backoff_base_ms: 10,
        ..ProcCfg::default()
    }
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

fn run_steps(mut tr: Trainer, steps: usize) -> (Vec<f32>, Vec<f32>) {
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        losses.push(tr.step().unwrap().loss);
    }
    (losses, flat_params(&tr))
}

// ------------------------------------------------------------ bit-identity

/// The acceptance criterion: recording spans must not change a single
/// bit of the training trajectory, in any engine.
#[test]
fn tracing_is_bitwise_invisible_in_all_engines() {
    let _g = trace_lock();
    let mk = |mode: DistMode| -> Trainer {
        let mut b = base_builder("mlp", optim::spngd()).dist(mode);
        if mode == DistMode::Proc {
            b = b.proc_cfg(proc_cfg());
        }
        b.build().unwrap()
    };
    for mode in [DistMode::Sequential, DistMode::Threaded, DistMode::Proc] {
        obs::set_enabled(false);
        let baseline = run_steps(mk(mode), 3);
        obs::set_enabled(true);
        let traced = run_steps(mk(mode), 3);
        obs::set_enabled(false);
        let _ = obs::drain(); // leave the rings empty for the next mode
        assert_eq!(baseline.0, traced.0, "{mode:?}: losses diverged under tracing");
        assert_eq!(baseline.1, traced.1, "{mode:?}: params diverged under tracing");
    }
}

// ------------------------------------------------------- trace round-trip

/// A traced threaded run drains into a structurally sound trace: spans
/// balanced and time-sorted, worker lanes named, comm and compute both
/// present, the overlap sums internally consistent — and the whole
/// thing survives a serialize/parse round trip of the Chrome JSON.
#[test]
fn threaded_trace_round_trips_with_consistent_spans() {
    let _g = trace_lock();
    obs::set_enabled(false);
    let _ = obs::drain();
    let mut tr = base_builder("convnet_tiny", optim::spngd())
        .dist(DistMode::Threaded)
        .build()
        .unwrap();
    obs::set_enabled(true);
    for _ in 0..2 {
        tr.step().unwrap();
    }
    obs::set_enabled(false);
    let trace = obs::drain();

    assert!(!trace.events.is_empty(), "traced run recorded nothing");
    assert_eq!(trace.dropped, 0, "two tiny steps must not overflow the rings");
    let mut last_t0 = 0u64;
    let (mut n_comm, mut n_compute) = (0usize, 0usize);
    for (tid, name, cat, t0, t1) in trace.spans() {
        assert!(t1 >= t0, "unbalanced span {name} on tid {tid}");
        assert!(t0 >= last_t0, "drain must sort spans by start time ({name})");
        last_t0 = t0;
        assert!(trace.threads.contains_key(&tid), "span {name} on unregistered tid {tid}");
        n_comm += cat.is_comm() as usize;
        n_compute += cat.is_compute() as usize;
    }
    assert!(n_comm > 0, "threaded run must record collective spans");
    assert!(n_compute > 0, "threaded run must record compute spans");
    let lanes: Vec<&str> = trace.threads.values().map(String::as_str).collect();
    assert!(
        lanes.iter().any(|n| n.starts_with("spngd-worker-")),
        "worker lanes must be named in the thread table: {lanes:?}"
    );

    let ov = obs::overlap(&trace);
    assert!(ov.comm_ns > 0 && ov.compute_ns > 0);
    assert!(ov.hidden_ns <= ov.comm_ns.min(ov.compute_ns));
    assert!(ov.critical_path_ns >= ov.comm_ns.max(ov.compute_ns));
    assert!(ov.critical_path_ns <= ov.comm_ns + ov.compute_ns);
    assert!((0.0..=1.0).contains(&ov.hidden_fraction));
    assert!(ov.by_name.contains_key("step"), "per-stage sums missing the step phase");

    // Chrome JSON round trip: parseable, complete, lanes labeled
    let s = trace.to_chrome_json().to_string();
    let back = Json::parse(&s).expect("chrome trace must be valid JSON");
    let evs = back.get("traceEvents").as_arr().expect("traceEvents array");
    assert_eq!(evs.len(), trace.events.len() + trace.threads.len());
    let mut meta_names = Vec::new();
    for e in evs {
        let ph = e.get("ph").as_str().expect("every event has ph");
        assert!(matches!(ph, "M" | "X" | "i" | "C"), "unknown ph {ph}");
        assert!(e.get("pid").as_usize().is_some() && e.get("tid").as_usize().is_some());
        if ph == "M" {
            meta_names.push(e.get("args").get("name").as_str().unwrap_or("").to_string());
        } else {
            assert!(e.get("ts").as_f64().is_some(), "non-meta event missing ts");
        }
    }
    assert!(
        meta_names.iter().any(|n| n.starts_with("spngd-worker-")),
        "thread_name metadata must label the worker lanes: {meta_names:?}"
    );
    assert_eq!(back.get("displayTimeUnit").as_str(), Some("ms"));
}

// The old grep-based print audit lived here; it is now the lint's
// `no-raw-print` rule (comment/string-aware, allowlist in `lint.toml`),
// enforced by `tests/lint.rs` and the CI `lint` job.
