//! Deterministic fuzz/property smoke over the repo's byte-level parsers:
//! random and mutated inputs through `Json::parse`, `CifarBin::from_bytes`,
//! the SPCK checkpoint container (`ckpt::Checkpoint`/`ckpt::Meta`), the
//! `spngd serve` HTTP/1.1 request parser and the f16 wire codec. Fixed
//! seeds, bounded case counts — this is the CI fuzz job (`fuzz-smoke`),
//! sized to finish in well under two minutes while still exercising both
//! the accept and reject paths of every parser. A panic anywhere in a
//! parser is a test failure by construction (`util::prop::check` runs
//! the property in-process).

use spngd::ckpt;
use spngd::collectives::comm::Precision;
use spngd::collectives::wire::{self, Frame, Kind};
use spngd::data::cifar::{CifarBin, CIFAR_CLASSES, CIFAR_RECORD};
use spngd::data::DataSource;
use spngd::serve::http::{self, read_request, HttpError};
use spngd::util::f16;
use spngd::util::json::Json;
use spngd::util::obs;
use spngd::util::prop::{check, gen};
use spngd::util::rng::Rng;
use std::io::Cursor;

fn rand_bytes(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Arbitrary byte soup must never panic the JSON parser, and anything it
/// accepts must survive a serialize → reparse round trip unchanged.
#[test]
fn json_parse_survives_byte_soup() {
    check(
        0xF00D,
        400,
        256,
        rand_bytes,
        |bytes| {
            let s = String::from_utf8_lossy(bytes);
            match Json::parse(&s) {
                Ok(v) => Json::parse(&v.to_string()).map(|v2| v2 == v).unwrap_or(false),
                Err(_) => true, // rejection is fine; panicking is not
            }
        },
    );
}

/// Mutate a realistic manifest-shaped document byte-by-byte: the parser
/// must reject or accept cleanly at every corruption, never crash, and
/// accepted documents must still round-trip.
#[test]
fn json_parse_survives_mutated_manifest() {
    const SEED_DOC: &str = r#"{"schema": "spngd/1", "models": [{"name": "convnet_tiny",
        "batch": 32, "lr": 1.5e-2, "damping": 0.05, "stale": null, "emp": true,
        "shape": [8, 3, 8, 8], "layers": [{"k": 3, "pad": 1}, {"k": 1, "pad": 0}]}]}"#;
    check(
        0xBADC0DE,
        400,
        24,
        |rng, size| {
            let mut b = SEED_DOC.as_bytes().to_vec();
            for _ in 0..size {
                let i = rng.below_usize(b.len());
                b[i] = rng.below(256) as u8;
            }
            b
        },
        |bytes| {
            let s = String::from_utf8_lossy(bytes);
            match Json::parse(&s) {
                Ok(v) => Json::parse(&v.to_string()).map(|v2| v2 == v).unwrap_or(false),
                Err(_) => true,
            }
        },
    );
}

/// CIFAR binary records: `from_bytes` must accept exactly the inputs the
/// format documents (non-empty, whole 3073-byte records, labels < 10)
/// and never panic on anything else. Half the cases are biased toward
/// well-formed records so the accept path (and the decoder behind it)
/// actually runs.
#[test]
fn cifar_from_bytes_accepts_exactly_the_documented_format() {
    check(
        0xC1FA2,
        300,
        4 * CIFAR_RECORD,
        |rng, size| {
            if rng.bool(0.5) {
                // record-aligned candidate, labels mostly in range
                let n = 1 + size / (CIFAR_RECORD / 2).max(1);
                let mut b = rand_bytes(rng, n * CIFAR_RECORD);
                for i in 0..n {
                    let space = if rng.bool(0.9) { CIFAR_CLASSES as u64 } else { 256 };
                    b[i * CIFAR_RECORD] = rng.below(space) as u8;
                }
                b
            } else {
                rand_bytes(rng, size) // unaligned soup: almost always rejected
            }
        },
        |bytes| {
            let valid = !bytes.is_empty()
                && bytes.len() % CIFAR_RECORD == 0
                && bytes.chunks(CIFAR_RECORD).all(|r| (r[0] as usize) < CIFAR_CLASSES);
            match CifarBin::from_bytes(bytes.clone()) {
                Err(_) => !valid,
                Ok(d) => {
                    if !valid || d.spec().len != bytes.len() / CIFAR_RECORD {
                        return false;
                    }
                    // decoded pixels land in the documented [-1, 1] range
                    let mut rng = Rng::new(0);
                    let (img, label) = d.sample(0, &mut rng);
                    label < CIFAR_CLASSES
                        && img.len() == CIFAR_RECORD - 1
                        && img.iter().all(|p| (-1.0..=1.0).contains(p))
                }
            }
        },
    );
}

const WIRE_KINDS: [Kind; 12] = [
    Kind::Hello,
    Kind::Welcome,
    Kind::Heartbeat,
    Kind::Ping,
    Kind::Pong,
    Kind::RoundStart,
    Kind::RoundEnd,
    Kind::ReduceGrad,
    Kind::GradSeg,
    Kind::ReduceStats,
    Kind::StatResult,
    Kind::Shutdown,
];

/// A random but well-formed wire frame (any kind, any flags, arbitrary
/// payload bytes).
fn rand_frame(rng: &mut Rng, max_payload: usize) -> Frame {
    let kind = WIRE_KINDS[rng.below_usize(WIRE_KINDS.len())];
    let flags = rng.below(2) as u8;
    let payload = rand_bytes(rng, rng.below_usize(max_payload + 1));
    Frame::new(kind, flags, payload)
}

/// Arbitrary byte soup through `Frame::parse`: never a panic, and
/// anything accepted must be canonical — re-encoding the frame gives
/// back exactly the bytes consumed.
#[test]
fn wire_frame_parse_survives_byte_soup() {
    check(0x51F0, 500, 96, rand_bytes, |bytes| match Frame::parse(bytes) {
        Err(_) | Ok(None) => true, // reject / ask-for-more are both fine
        Ok(Some((f, used))) => used <= bytes.len() && f.encode() == bytes[..used],
    });
}

/// Mutate valid frames byte-by-byte: the parser must reject or accept
/// cleanly at every corruption, and a mutated frame it accepts must
/// still be canonical. Payload corruption in particular must trip the
/// checksum, never crash a downstream decoder.
#[test]
fn wire_frame_parse_survives_mutated_frames() {
    check(
        0x51F1,
        500,
        8,
        |rng, size| {
            let mut b = rand_frame(rng, 48).encode();
            for _ in 0..1 + rng.below_usize(size.max(1)) {
                let i = rng.below_usize(b.len());
                b[i] = rng.below(256) as u8;
            }
            b
        },
        |bytes| match Frame::parse(bytes) {
            Err(_) | Ok(None) => true,
            Ok(Some((f, used))) => used <= bytes.len() && f.encode() == bytes[..used],
        },
    );
}

/// Every strict prefix of a valid frame is "read more bytes", never an
/// error and never a short parse — framing over a stream depends on it.
#[test]
fn wire_frame_truncation_always_asks_for_more() {
    check(
        0x51F2,
        200,
        64,
        |rng, size| rand_frame(rng, size).encode(),
        |bytes| {
            (0..bytes.len()).all(|cut| matches!(Frame::parse(&bytes[..cut]), Ok(None)))
                && matches!(Frame::parse(bytes), Ok(Some((_, used))) if used == bytes.len())
        },
    );
}

/// A header announcing an oversized payload is rejected outright from
/// the 16 header bytes alone — no allocation, no waiting for 64 MiB.
#[test]
fn wire_oversized_lengths_rejected_from_header_alone() {
    check(
        0x51F3,
        300,
        16,
        |rng, _| {
            let mut hdr = Vec::with_capacity(wire::HEADER_BYTES);
            hdr.extend_from_slice(&wire::MAGIC);
            hdr.extend_from_slice(&wire::VERSION.to_le_bytes());
            hdr.push(WIRE_KINDS[rng.below_usize(WIRE_KINDS.len())] as u8);
            hdr.push(rng.below(2) as u8);
            let over = wire::MAX_PAYLOAD as u64 + 1 + rng.next_u64() % (u32::MAX as u64 / 2);
            hdr.extend_from_slice(&(over.min(u32::MAX as u64) as u32).to_le_bytes());
            hdr.extend_from_slice(&(rng.next_u64() as u32).to_le_bytes());
            hdr
        },
        |hdr| matches!(Frame::parse(hdr), Err(wire::WireError::Oversized(_))),
    );
}

/// Fuzzed payloads through every typed decoder (including corrupt f16
/// element buffers under the mixed flag): decoders must accept or
/// reject structurally, never panic, and accepted reduction jobs must
/// be internally consistent.
#[test]
fn wire_payload_decoders_survive_fuzz() {
    check(
        0x51F4,
        600,
        80,
        |rng, size| rand_frame(rng, size),
        |f| {
            let _ = wire::decode_hello(f);
            let _ = wire::decode_welcome(f);
            let _ = wire::decode_step(f);
            if let Ok(job) = wire::decode_grad_job(f) {
                if job.lanes.is_empty() || job.lanes.iter().any(|l| l.len() != job.seg_len as usize)
                {
                    return false;
                }
            }
            if let Ok(job) = wire::decode_stat_job(f) {
                let mat = (job.rows as usize) * (job.cols as usize);
                if job.lanes.is_empty() || job.lanes.iter().any(|l| l.len() != mat) {
                    return false;
                }
            }
            if let Ok((_, seg)) = wire::decode_grad_seg(f) {
                let elem = if f.flags & wire::FLAG_F16 != 0 { 2 } else { 4 };
                if seg.len() * elem != f.payload.len() - 8 {
                    return false;
                }
            }
            let _ = wire::decode_stat_result(f);
            true
        },
    );
}

/// Mixed-precision element buffers: any even-length byte soup decodes
/// (every u16 is a valid f16 bit pattern), odd lengths are rejected,
/// and decode is exactly the `wire_quantize` fixed point — re-encoding
/// a decoded buffer reproduces the wire bytes.
#[test]
fn wire_f16_element_buffers_decode_totally() {
    check(
        0x51F5,
        400,
        128,
        rand_bytes,
        |bytes| match wire::decode_elems(Precision::Mixed, bytes) {
            Err(_) => bytes.len() % 2 != 0,
            Ok(vals) => {
                if bytes.len() % 2 != 0 || vals.len() != bytes.len() / 2 {
                    return false;
                }
                let mut back = Vec::new();
                wire::encode_elems(Precision::Mixed, &vals, &mut back);
                // decode→encode is the identity on the whole 16-bit
                // space (NaN payloads included): the wire bytes ARE
                // the quantized values
                back == *bytes
            }
        },
    );
}

/// Arbitrary byte soup through the JSONL event parser (`obs::parse_line`):
/// parse-or-skip, never a panic, and anything accepted must have carried
/// one of the accepted schema tags with the envelope keys stripped from
/// `fields`.
#[test]
fn event_line_parse_survives_byte_soup() {
    check(0xE7E1, 400, 256, rand_bytes, |bytes| {
        let s = String::from_utf8_lossy(bytes);
        match obs::parse_line(&s) {
            None => true, // skipping garbage is the contract
            Some(rec) => {
                obs::EVENT_SCHEMAS.iter().any(|sch| s.contains(sch))
                    && ["schema", "seq", "t", "kind"]
                        .iter()
                        .all(|k| !rec.fields.contains_key(*k))
            }
        }
    });
}

/// Mutate realistic emitted event lines byte-by-byte: the parser must
/// accept or skip cleanly at every corruption — a corrupt dist event
/// stream must never take the reader down with it. Lines are emitted
/// under both accepted schema versions (`/1` back-compat, `/2` current)
/// and both eras' kinds, checkpoint lifecycle included.
#[test]
fn event_line_parse_survives_mutated_lines() {
    const KINDS: [&str; 8] = [
        "state",
        "joined",
        "dead",
        "respawned",
        "poison",
        "fault_plan",
        "checkpoint_saved",
        "resumed",
    ];
    check(
        0xE7E2,
        400,
        16,
        |rng, size| {
            let kind = KINDS[rng.below_usize(KINDS.len())];
            let schema = obs::EVENT_SCHEMAS[rng.below_usize(obs::EVENT_SCHEMAS.len())];
            let mut b = format!(
                r#"{{"schema":"{schema}","seq":{},"t":{}.{:03},"kind":"{kind}","rank":{},"step":{},"reason":"job timeout"}}"#,
                rng.below(10_000),
                rng.below(100),
                rng.below(1000),
                rng.below(8),
                rng.below(50),
            )
            .into_bytes();
            for _ in 0..1 + rng.below_usize(size.max(1)) {
                let i = rng.below_usize(b.len());
                b[i] = rng.below(256) as u8;
            }
            b
        },
        |bytes| {
            let s = String::from_utf8_lossy(bytes);
            match obs::parse_line(&s) {
                None => true,
                // accepted ⇒ the envelope survived the corruption intact
                Some(rec) => {
                    obs::EVENT_SCHEMAS.iter().any(|sch| s.contains(sch))
                        && ["schema", "seq", "t", "kind"]
                            .iter()
                            .all(|k| !rec.fields.contains_key(*k))
                }
            }
        },
    );
}

/// Every strict prefix of a valid event line is skipped (truncated JSON
/// is not an event), the full line parses, and an oversized line is
/// rejected without reading its body.
#[test]
fn event_line_truncation_and_oversize_are_skipped() {
    check(
        0xE7E3,
        60,
        8,
        |rng, _| {
            format!(
                r#"{{"schema":"spngd-events/1","seq":{},"t":0.5,"kind":"dead","rank":{}}}"#,
                rng.below(1000),
                rng.below(8),
            )
            .into_bytes()
        },
        |bytes| {
            let s = std::str::from_utf8(bytes).unwrap();
            (1..s.len()).all(|cut| obs::parse_line(&s[..cut]).is_none())
                && obs::parse_line(s).is_some_and(|r| r.kind == "dead")
        },
    );
    // a single oversized-but-valid line: corrupt stream, not an event
    let huge = format!(
        r#"{{"schema":"spngd-events/1","seq":1,"t":0.5,"kind":"dead","pad":"{}"}}"#,
        "x".repeat(2 << 20)
    );
    assert!(obs::parse_line(&huge).is_none(), "lines over 1 MiB must be skipped");
}

/// f16 wire codec over ordinary magnitudes: slice quantization is exactly
/// per-element round-trip, quantization is idempotent, preserves sign,
/// and stays within the half-precision ulp bound across the normal range.
#[test]
fn f16_codec_properties_on_normal_range() {
    check(
        0x16F1,
        400,
        512,
        |rng, size| gen::vec_f32(rng, size, 1.0e4),
        |v| {
            let mut q = v.clone();
            f16::quantize_slice(&mut q);
            v.iter().zip(q.iter()).all(|(&x, &y)| {
                let rt = f16::round_trip(x);
                if rt.to_bits() != y.to_bits() {
                    return false; // slice path must equal the scalar path
                }
                if f16::round_trip(rt).to_bits() != rt.to_bits() {
                    return false; // idempotent: f16 values are fixed points
                }
                if x != 0.0 && rt != 0.0 && x.signum() != rt.signum() {
                    return false;
                }
                let ax = x.abs();
                // normal f16 range: relative error ≤ 2^-10 (RNE gives 2^-11)
                if (6.2e-5..6.5e4).contains(&ax) {
                    ((rt - x) / x).abs() <= 1.0 / 1024.0
                } else {
                    true
                }
            })
        },
    );
}

/// A random but well-formed SPCK checkpoint: a handful of sections over
/// the known kinds with unique `(kind, tag)` pairs and arbitrary small
/// payloads.
fn rand_checkpoint(rng: &mut Rng, max_payload: usize) -> ckpt::Checkpoint {
    const KINDS: [u16; 8] = [
        ckpt::SEC_META,
        ckpt::SEC_PARAM,
        ckpt::SEC_VELOCITY,
        ckpt::SEC_BN,
        ckpt::SEC_LAYER,
        ckpt::SEC_LOADER,
        ckpt::SEC_CHAIN,
        ckpt::SEC_STASH,
    ];
    let mut ck = ckpt::Checkpoint::new();
    let mut used = std::collections::BTreeSet::new();
    for _ in 0..1 + rng.below_usize(6) {
        let kind = KINDS[rng.below_usize(KINDS.len())];
        let tag = rng.below(8) as u16;
        if used.insert((kind, tag)) {
            ck.push(kind, tag, rand_bytes(rng, rng.below_usize(max_payload + 1)));
        }
    }
    ck
}

fn sections_equal(a: &ckpt::Checkpoint, b: &ckpt::Checkpoint) -> bool {
    a.sections.len() == b.sections.len()
        && a.sections.iter().zip(b.sections.iter()).all(|(x, y)| {
            x.kind == y.kind && x.tag == y.tag && x.payload == y.payload
        })
}

/// Arbitrary byte soup through `Checkpoint::parse`: never a panic, and
/// anything accepted must survive an encode → reparse round trip with
/// identical sections (flags/reserved header bytes are the only
/// non-canonical freedom, and they carry no state).
#[test]
fn ckpt_parse_survives_byte_soup() {
    check(0x5bc1, 500, 128, rand_bytes, |bytes| match ckpt::Checkpoint::parse(bytes) {
        Err(_) => true, // structured rejection is the contract
        Ok(ck) => ckpt::Checkpoint::parse(&ck.encode())
            .map(|back| sections_equal(&ck, &back))
            .unwrap_or(false),
    });
}

/// Mutate valid checkpoint files byte-by-byte: every corruption must be
/// rejected cleanly or accepted with intact structure — and payload
/// corruption specifically must trip the per-section checksum rather
/// than reach a state decoder.
#[test]
fn ckpt_parse_survives_mutated_checkpoints() {
    check(
        0x5bc2,
        500,
        8,
        |rng, size| {
            let mut b = rand_checkpoint(rng, 48).encode();
            for _ in 0..1 + rng.below_usize(size.max(1)) {
                let i = rng.below_usize(b.len());
                b[i] = rng.below(256) as u8;
            }
            b
        },
        |bytes| match ckpt::Checkpoint::parse(bytes) {
            Err(_) => true,
            Ok(ck) => {
                // accepted ⇒ canonical, and the META decoder (the next
                // parser in line on a restore) must not panic on it
                let _ = ckpt::Meta::of(&ck);
                ckpt::Checkpoint::parse(&ck.encode())
                    .map(|back| sections_equal(&ck, &back))
                    .unwrap_or(false)
            }
        },
    );
}

/// Every strict prefix of a valid checkpoint file is a structured error
/// (its own headers promise more bytes), and the full encoding parses.
#[test]
fn ckpt_truncation_is_always_a_structured_error() {
    check(
        0x5bc3,
        120,
        32,
        |rng, size| rand_checkpoint(rng, size).encode(),
        |bytes| {
            (0..bytes.len()).all(|cut| ckpt::Checkpoint::parse(&bytes[..cut]).is_err())
                && ckpt::Checkpoint::parse(bytes).is_ok()
        },
    );
}

/// Headers announcing oversized sections or absurd section counts are
/// rejected from the fixed-size headers alone — no allocation, no loop.
#[test]
fn ckpt_oversized_headers_rejected_before_allocation() {
    check(
        0x5bc4,
        300,
        1,
        |rng, _| {
            let mut ck = ckpt::Checkpoint::new();
            ck.push(ckpt::SEC_META, 0, rand_bytes(rng, 8));
            let mut b = ck.encode();
            if rng.bool(0.5) {
                // lying section length, over the 64 MiB cap
                let over = ckpt::MAX_SECTION + 1 + (rng.next_u64() as u32 % 1024);
                b[16 + 4..16 + 8].copy_from_slice(&over.to_le_bytes());
            } else {
                // lying section count, over the table cap
                let over = 65_537u32.saturating_add(rng.next_u64() as u32 % 4096);
                b[8..12].copy_from_slice(&over.to_le_bytes());
            }
            b
        },
        |bytes| {
            matches!(
                ckpt::Checkpoint::parse(bytes),
                Err(ckpt::CkptError::Oversized { .. })
                    | Err(ckpt::CkptError::TooManySections(_))
            )
        },
    );
}

/// Arbitrary byte soup through `ckpt::Meta::parse` (the restore path's
/// innermost decoder): never a panic, and accepted metas are canonical —
/// re-encoding reproduces the input bytes exactly.
#[test]
fn ckpt_meta_parse_survives_byte_soup() {
    check(0x5bc5, 500, 96, rand_bytes, |bytes| match ckpt::Meta::parse(bytes) {
        Err(_) => true,
        Ok(m) => m.encode() == *bytes,
    });
}

/// Arbitrary byte soup through the `spngd serve` request parser: every
/// outcome is a typed [`HttpError`] or a structurally sane [`Request`]
/// (method/path are whitespace-free tokens, body within the cap) —
/// never a panic, whatever a client throws at the socket.
#[test]
fn http_read_request_survives_byte_soup() {
    check(0x1771, 500, 256, rand_bytes, |bytes| {
        match read_request(&mut Cursor::new(&bytes[..])) {
            Err(_) => true, // typed rejection is the contract
            Ok(req) => {
                !req.method.is_empty()
                    && !req.method.contains(char::is_whitespace)
                    && !req.path.contains(char::is_whitespace)
                    && req.body.len() <= http::MAX_BODY_BYTES
            }
        }
    });
}

/// A realistic predict request with a randomized body length.
fn rand_http_request(rng: &mut Rng, max_body: usize) -> Vec<u8> {
    let body: Vec<u8> = (0..1 + rng.below_usize(max_body.max(1)))
        .map(|_| b'a' + rng.below(26) as u8)
        .collect();
    let mut req = format!(
        "POST /v1/predict HTTP/1.1\r\nHost: fuzz\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    req.extend_from_slice(&body);
    req
}

/// Mutate well-formed requests byte-by-byte: the parser must accept or
/// reject with a typed error at every corruption, and anything it
/// accepts must still be structurally sane.
#[test]
fn http_read_request_survives_mutated_requests() {
    check(
        0x1772,
        500,
        8,
        |rng, size| {
            let mut b = rand_http_request(rng, 32);
            for _ in 0..1 + rng.below_usize(size.max(1)) {
                let i = rng.below_usize(b.len());
                b[i] = rng.below(256) as u8;
            }
            b
        },
        |bytes| match read_request(&mut Cursor::new(&bytes[..])) {
            Err(_) => true,
            Ok(req) => !req.method.is_empty() && req.body.len() <= http::MAX_BODY_BYTES,
        },
    );
}

/// Every strict prefix of a valid request is a typed error (the body is
/// last, so a truncated stream can never yield a complete request), the
/// empty stream is the clean keep-alive `Closed`, and the full bytes
/// parse back the exact body.
#[test]
fn http_truncated_requests_are_typed_errors() {
    check(
        0x1773,
        120,
        24,
        rand_http_request,
        |bytes| {
            for cut in 0..bytes.len() {
                match read_request(&mut Cursor::new(&bytes[..cut])) {
                    Err(HttpError::Closed) if cut == 0 => {}
                    Err(HttpError::Closed) => return false, // mid-request is never "clean"
                    Err(_) => {}
                    Ok(_) => return false, // a strict prefix must not parse
                }
            }
            read_request(&mut Cursor::new(&bytes[..]))
                .is_ok_and(|req| bytes.ends_with(&req.body) && req.path == "/v1/predict")
        },
    );
}

/// Resource-exhaustion inputs are rejected from the declarations alone:
/// a header block over [`http::MAX_HEADER_BYTES`] dies mid-read with a
/// typed 400, and a hostile Content-Length over [`http::MAX_BODY_BYTES`]
/// is a 413 with no body allocation.
#[test]
fn http_oversized_headers_and_bodies_rejected_before_allocation() {
    check(
        0x1774,
        60,
        4,
        |rng, _| {
            if rng.bool(0.5) {
                let pad = "h".repeat(http::MAX_HEADER_BYTES + rng.below_usize(4096));
                (format!("GET /x HTTP/1.1\r\nPad: {pad}\r\n\r\n"), true)
            } else {
                let len = http::MAX_BODY_BYTES as u64 + 1 + rng.next_u64() % (1 << 40);
                (format!("POST /x HTTP/1.1\r\nContent-Length: {len}\r\n\r\n"), false)
            }
        },
        |(req, is_header_case)| {
            match read_request(&mut Cursor::new(req.as_bytes())) {
                Err(HttpError::Bad(_)) => *is_header_case,
                Err(HttpError::TooLarge) => !*is_header_case,
                _ => false,
            }
        },
    );
}

/// f16 wire codec over adversarial bit patterns (NaN payloads, infinities,
/// subnormals, overflow range): NaN stays NaN, infinities are exact,
/// finite inputs never decode to NaN.
#[test]
fn f16_codec_survives_arbitrary_bit_patterns() {
    check(
        0x16F2,
        300,
        128,
        |rng, size| {
            (0..size)
                .map(|_| f32::from_bits(rng.next_u64() as u32))
                .collect::<Vec<f32>>()
        },
        |v| {
            v.iter().all(|&x| {
                let rt = f16::round_trip(x);
                if x.is_nan() {
                    rt.is_nan()
                } else if x.is_infinite() {
                    rt == x
                } else {
                    // finite input may overflow to ±inf but never to NaN
                    !rt.is_nan()
                }
            })
        },
    );
}
