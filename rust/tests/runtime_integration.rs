//! Integration: the native executor honors the full manifest contract —
//! the step executable's output tuple, factor construction, Newton-Schulz
//! inversion and preconditioning all agree with host-side oracles
//! (`linalg`). Runs hermetically: the native backend needs no artifacts.
//!
//! The same assertions against real AOT artifacts through PJRT live in
//! the `pjrt`-gated module at the bottom (`cargo test --features pjrt`
//! after `make artifacts`).

use std::sync::Arc;

use spngd::linalg::{solve, Mat};
use spngd::runtime::{native, Executor, HostTensor, Manifest};
use spngd::util::rng::Rng;

fn runtime() -> (Arc<Manifest>, Arc<dyn Executor>) {
    let (manifest, backend) = native::build_default().unwrap();
    (Arc::new(manifest), Arc::new(backend) as Arc<dyn Executor>)
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> HostTensor {
    let n = shape.iter().product();
    let data = (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
    HostTensor::new(shape, data)
}

fn random_batch(rng: &mut Rng, model: &spngd::runtime::ModelManifest) -> (HostTensor, HostTensor) {
    let x = rand_tensor(rng, model.input_shape.clone(), 1.0);
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes + rng.below_usize(model.num_classes)] = 1.0;
    }
    (x, t)
}

#[test]
fn engine_runs_step_with_declared_outputs() {
    let (manifest, engine) = runtime();
    let model = manifest.model("mlp").unwrap();
    let params = manifest.load_init_params(model).unwrap();

    let mut rng = Rng::new(1);
    let (x, t) = random_batch(&mut rng, model);
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let outs = engine.execute(&model.step_emp, &inputs).unwrap();
    assert_eq!(outs.len(), model.step_outputs.len(), "output arity");

    let loss = outs[model.output_index("loss", None).unwrap()].data[0];
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // fresh 10-class model: loss near ln(10)
    assert!((loss - (10.0f32).ln()).abs() < 1.5, "loss={loss}");

    let ncorrect = outs[model.output_index("ncorrect", None).unwrap()].data[0];
    assert!((0.0..=model.batch as f32).contains(&ncorrect));

    // every declared output shape matches
    for (o, spec) in outs.iter().zip(model.step_outputs.iter()) {
        assert_eq!(o.shape, spec.shape, "shape of {}", spec.name);
    }
}

#[test]
fn convnet_step_emits_taps_and_bn_stats() {
    let (manifest, engine) = runtime();
    let model = manifest.model("convnet_tiny").unwrap();
    let params = manifest.load_init_params(model).unwrap();
    let mut rng = Rng::new(5);
    let (x, t) = random_batch(&mut rng, model);
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let outs = engine.execute(&model.step_emp, &inputs).unwrap();
    for (o, spec) in outs.iter().zip(model.step_outputs.iter()) {
        assert_eq!(o.shape, spec.shape, "shape of {}", spec.name);
        assert!(o.data.iter().all(|v| v.is_finite()), "{} has non-finite values", spec.name);
    }
    // BN batch variances are positive
    for bname in &model.bn_order {
        let vi = model.output_index("bn_var", Some(bname)).unwrap();
        assert!(outs[vi].data.iter().all(|&v| v > 0.0), "var of {bname}");
    }
    // a_tap of the stem conv is the raw input
    let ai = model.output_index("a_tap", Some("stem.conv")).unwrap();
    assert_eq!(outs[ai].data, x.data);
}

#[test]
fn invert_executable_matches_gauss_jordan() {
    let (manifest, engine) = runtime();

    // any invert_<n> executable
    let name = manifest
        .executables
        .keys()
        .find(|k| k.starts_with("invert_"))
        .expect("no invert executable")
        .clone();
    let n: usize = name.trim_start_matches("invert_").parse().unwrap();

    let mut rng = Rng::new(7);
    let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let bm = Mat::from_vec(n, n, b);
    let mut m = bm.transpose().matmul(&bm).scale(1.0 / n as f32);
    m.symmetrize();
    let lambda = 0.1f32;

    let mt = HostTensor::from_mat(&m);
    let damp = HostTensor::scalar(lambda);
    let outs = engine.execute(&name, &[&mt, &damp]).unwrap();
    let inv = outs[0].as_mat();

    let mut md = m.clone();
    md.add_diag(lambda);
    let want = solve::gauss_jordan_inverse(&md).unwrap();
    let diff = inv.max_abs_diff(&want);
    assert!(diff < 5e-3, "NS-vs-GJ diff {diff}");
    assert!(solve::inverse_residual(&md, &inv) < 5e-3);
}

#[test]
fn fc_factor_executable_matches_host_syrk() {
    let (manifest, engine) = runtime();
    let model = manifest.model("mlp").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();

    let b = model.batch;
    let d = layer.a_dim;
    let mut rng = Rng::new(9);
    let tap = rand_tensor(&mut rng, vec![b, d], 1.0);
    let outs = engine.execute(&layer.factor_a, &[&tap]).unwrap();
    let a = outs[0].as_mat();

    // host oracle: A = tap^T tap / B
    let tm = tap.as_mat();
    let want = tm.transpose().matmul(&tm).scale(1.0 / b as f32);
    assert!(a.max_abs_diff(&want) < 1e-3, "diff {}", a.max_abs_diff(&want));
}

#[test]
fn conv_factor_executable_matches_host_im2col_syrk() {
    let (manifest, engine) = runtime();
    let model = manifest.model("convnet_tiny").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.kind == "conv").unwrap();
    // stem conv of convnet_tiny: tap (B, 3, 8, 8), k=3 s=1 p=1
    let mut rng = Rng::new(10);
    let tap = rand_tensor(&mut rng, vec![model.batch, 3, 8, 8], 1.0);
    let outs = engine.execute(&layer.factor_a, &[&tap]).unwrap();
    assert_eq!(outs[0].shape, vec![layer.a_dim, layer.a_dim]);

    let (patches, ho, wo) = native::kernels::im2col(&tap, 3, 1, 1);
    let want = patches
        .transpose()
        .matmul(&patches)
        .scale(1.0 / (model.batch * ho * wo) as f32);
    assert!(outs[0].as_mat().max_abs_diff(&want) < 1e-3);
}

#[test]
fn precond_executable_matches_host_matmul() {
    let (manifest, engine) = runtime();
    let model = manifest.model("mlp").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();
    let (m, n) = layer.grad_shape;

    let mut rng = Rng::new(11);
    let ginv = rand_tensor(&mut rng, vec![m, m], 0.5);
    let grad = rand_tensor(&mut rng, vec![m, n], 0.5);
    let ainv = rand_tensor(&mut rng, vec![n, n], 0.5);
    let outs = engine.execute(&layer.precond, &[&ginv, &grad, &ainv]).unwrap();
    let got = outs[0].as_mat();
    let want = ginv.as_mat().matmul(&grad.as_mat()).matmul(&ainv.as_mat());
    assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
}

#[test]
fn bn_inv_executable_is_true_inverse() {
    let (manifest, engine) = runtime();
    let model = manifest.model("convnet_small").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.is_bn()).unwrap();
    let (b, c) = (model.batch, layer.channels);

    let mut rng = Rng::new(13);
    let gg = rand_tensor(&mut rng, vec![b, c], 1.0);
    let gb = rand_tensor(&mut rng, vec![b, c], 1.0);
    let lam = 0.05f32;
    let damp = HostTensor::scalar(lam);
    let outs = engine.execute(&layer.bn_inv, &[&gg, &gb, &damp]).unwrap();
    let inv = &outs[0];
    assert_eq!(inv.shape, vec![c, 2, 2]);

    for ch in 0..c.min(4) {
        let (mut f11, mut f12, mut f22) = (0.0f64, 0.0f64, 0.0f64);
        for bi in 0..b {
            let g1 = gg.data[bi * c + ch] as f64;
            let g2 = gb.data[bi * c + ch] as f64;
            f11 += g1 * g1;
            f12 += g1 * g2;
            f22 += g2 * g2;
        }
        let (f11, f12, f22) =
            (f11 / b as f64 + lam as f64, f12 / b as f64, f22 / b as f64 + lam as f64);
        let got = &inv.data[ch * 4..ch * 4 + 4];
        // check F * F^-1 = I
        let i00 = f11 * got[0] as f64 + f12 * got[2] as f64;
        let i01 = f11 * got[1] as f64 + f12 * got[3] as f64;
        let i11 = f12 * got[1] as f64 + f22 * got[3] as f64;
        assert!((i00 - 1.0).abs() < 1e-3, "ch{ch} i00={i00}");
        assert!(i01.abs() < 1e-3);
        assert!((i11 - 1.0).abs() < 1e-3);
    }
}

#[test]
fn step_1mc_runs_with_seed() {
    let (manifest, engine) = runtime();
    let model = manifest.model("mlp").unwrap();
    let params = manifest.load_init_params(model).unwrap();

    let mut rng = Rng::new(15);
    let (x, t) = random_batch(&mut rng, model);
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let o1 = engine.execute_seeded(&model.step_1mc, &inputs, Some(3)).unwrap();
    let o2 = engine.execute_seeded(&model.step_1mc, &inputs, Some(4)).unwrap();
    let loss_idx = model.output_index("loss", None).unwrap();
    assert_eq!(o1[loss_idx].data[0], o2[loss_idx].data[0], "loss is seed-free");
    // the MC taps differ with the seed
    let gt_idx = model
        .output_index("g_tap", model.kfac_layers.first().map(|l| l.name.as_str()))
        .unwrap();
    let d: f32 = o1[gt_idx]
        .data
        .iter()
        .zip(o2[gt_idx].data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-7, "1mc taps should vary with seed");
    // parameter gradients come from the true labels: identical across seeds
    let grad_idx = model.output_index("grad", Some(&model.params[0].name)).unwrap();
    assert_eq!(o1[grad_idx].data, o2[grad_idx].data, "grads are seed-free");
}

#[test]
fn eval_executable_consumes_running_stats() {
    let (manifest, engine) = runtime();
    let model = manifest.model("convnet_tiny").unwrap();
    let params = manifest.load_init_params(model).unwrap();
    let mut rng = Rng::new(17);
    let (x, t) = random_batch(&mut rng, model);
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let means: Vec<HostTensor> = model
        .bn_order
        .iter()
        .map(|n| HostTensor::zeros(vec![model.layer(n).unwrap().channels]))
        .collect();
    let vars: Vec<HostTensor> = model
        .bn_order
        .iter()
        .map(|n| {
            let c = model.layer(n).unwrap().channels;
            HostTensor::new(vec![c], vec![1.0; c])
        })
        .collect();
    for m in &means {
        inputs.push(m);
    }
    for v in &vars {
        inputs.push(v);
    }
    let outs = engine.execute(&model.eval_exe, &inputs).unwrap();
    assert_eq!(outs.len(), 2);
    assert!(outs[0].data[0].is_finite() && outs[0].data[0] > 0.0);
    assert!((0.0..=model.batch as f32).contains(&outs[1].data[0]));
}

/// The original artifact-backed assertions, PJRT-gated so the default
/// `cargo test` stays hermetic. Requires `make artifacts` (skips with a
/// message otherwise) and real `xla` bindings in place of the stub.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use spngd::runtime::Engine;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn pjrt_engine_compiles_and_runs_step() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("mlp").unwrap();
        let params = manifest.load_init_params(model).unwrap();

        let mut rng = Rng::new(1);
        let (x, t) = random_batch(&mut rng, model);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&t);
        let outs = Engine::execute(&engine, &model.step_emp, &inputs).unwrap();
        assert_eq!(outs.len(), model.step_outputs.len(), "output arity");
        let loss = outs[model.output_index("loss", None).unwrap()].data[0];
        assert!((loss - (10.0f32).ln()).abs() < 1.5, "loss={loss}");
    }

    #[test]
    fn pjrt_invert_matches_gauss_jordan() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let name = manifest
            .executables
            .keys()
            .find(|k| k.starts_with("invert_"))
            .expect("no invert executable")
            .clone();
        let n: usize = name.trim_start_matches("invert_").parse().unwrap();
        let mut rng = Rng::new(7);
        let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let bm = Mat::from_vec(n, n, b);
        let mut m = bm.transpose().matmul(&bm).scale(1.0 / n as f32);
        m.symmetrize();
        let lambda = 0.1f32;
        let mt = HostTensor::from_mat(&m);
        let damp = HostTensor::scalar(lambda);
        let outs = Engine::execute(&engine, &name, &[&mt, &damp]).unwrap();
        let inv = outs[0].as_mat();
        let mut md = m.clone();
        md.add_diag(lambda);
        let want = solve::gauss_jordan_inverse(&md).unwrap();
        assert!(inv.max_abs_diff(&want) < 5e-3);
    }

    #[test]
    fn pjrt_fc_factor_matches_host_syrk() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("mlp").unwrap();
        let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();
        let (b, d) = (model.batch, layer.a_dim);
        let mut rng = Rng::new(9);
        let tap = rand_tensor(&mut rng, vec![b, d], 1.0);
        let outs = Engine::execute(&engine, &layer.factor_a, &[&tap]).unwrap();
        let tm = tap.as_mat();
        let want = tm.transpose().matmul(&tm).scale(1.0 / b as f32);
        assert!(outs[0].as_mat().max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn pjrt_precond_matches_host_matmul() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("mlp").unwrap();
        let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();
        let (m, n) = layer.grad_shape;
        let mut rng = Rng::new(11);
        let ginv = rand_tensor(&mut rng, vec![m, m], 0.5);
        let grad = rand_tensor(&mut rng, vec![m, n], 0.5);
        let ainv = rand_tensor(&mut rng, vec![n, n], 0.5);
        let outs = Engine::execute(&engine, &layer.precond, &[&ginv, &grad, &ainv]).unwrap();
        let want = ginv.as_mat().matmul(&grad.as_mat()).matmul(&ainv.as_mat());
        assert!(outs[0].as_mat().max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn pjrt_bn_inv_is_true_inverse() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("convnet_small").unwrap();
        let layer = model.kfac_layers.iter().find(|l| l.is_bn()).unwrap();
        let (b, c) = (model.batch, layer.channels);
        let mut rng = Rng::new(13);
        let gg = rand_tensor(&mut rng, vec![b, c], 1.0);
        let gb = rand_tensor(&mut rng, vec![b, c], 1.0);
        let lam = 0.05f32;
        let damp = HostTensor::scalar(lam);
        let outs = Engine::execute(&engine, &layer.bn_inv, &[&gg, &gb, &damp]).unwrap();
        assert_eq!(outs[0].shape, vec![c, 2, 2]);
        for ch in 0..c.min(4) {
            let (mut f11, mut f12, mut f22) = (0.0f64, 0.0f64, 0.0f64);
            for bi in 0..b {
                let g1 = gg.data[bi * c + ch] as f64;
                let g2 = gb.data[bi * c + ch] as f64;
                f11 += g1 * g1;
                f12 += g1 * g2;
                f22 += g2 * g2;
            }
            let (f11, f12, f22) =
                (f11 / b as f64 + lam as f64, f12 / b as f64, f22 / b as f64 + lam as f64);
            let got = &outs[0].data[ch * 4..ch * 4 + 4];
            let i00 = f11 * got[0] as f64 + f12 * got[2] as f64;
            let i11 = f12 * got[1] as f64 + f22 * got[3] as f64;
            assert!((i00 - 1.0).abs() < 1e-3, "ch{ch} i00={i00}");
            assert!((i11 - 1.0).abs() < 1e-3, "ch{ch} i11={i11}");
        }
    }

    #[test]
    fn pjrt_step_1mc_runs_with_seed() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("mlp").unwrap();
        let params = manifest.load_init_params(model).unwrap();
        let mut rng = Rng::new(15);
        let (x, t) = random_batch(&mut rng, model);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&t);
        let o1 = Engine::execute_seeded(&engine, &model.step_1mc, &inputs, Some(3)).unwrap();
        let o2 = Engine::execute_seeded(&engine, &model.step_1mc, &inputs, Some(4)).unwrap();
        let loss_idx = model.output_index("loss", None).unwrap();
        assert_eq!(o1[loss_idx].data[0], o2[loss_idx].data[0], "loss is seed-free");
        let gt_idx = model
            .output_index("g_tap", model.kfac_layers.first().map(|l| l.name.as_str()))
            .unwrap();
        let d: f32 = o1[gt_idx]
            .data
            .iter()
            .zip(o2[gt_idx].data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(d > 1e-7, "1mc taps should vary with seed");
    }

    #[test]
    fn pjrt_eval_consumes_running_stats() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::new(&manifest).unwrap();
        let model = manifest.model("convnet_small").unwrap();
        let params = manifest.load_init_params(model).unwrap();
        let mut rng = Rng::new(17);
        let (x, t) = random_batch(&mut rng, model);
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&t);
        let means: Vec<HostTensor> = model
            .bn_order
            .iter()
            .map(|n| HostTensor::zeros(vec![model.layer(n).unwrap().channels]))
            .collect();
        let vars: Vec<HostTensor> = model
            .bn_order
            .iter()
            .map(|n| {
                let c = model.layer(n).unwrap().channels;
                HostTensor::new(vec![c], vec![1.0; c])
            })
            .collect();
        for m in &means {
            inputs.push(m);
        }
        for v in &vars {
            inputs.push(v);
        }
        let outs = Engine::execute(&engine, &model.eval_exe, &inputs).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs[0].data[0].is_finite() && outs[0].data[0] > 0.0);
    }
}
