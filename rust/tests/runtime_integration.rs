//! Integration: rust engine loads the real AOT artifacts and the numbers
//! agree with rust-side oracles (linalg) — the cross-layer correctness
//! seam between L3 and L2/L1.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use spngd::linalg::{solve, Mat};
use spngd::runtime::{Engine, HostTensor, Manifest};
use spngd::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> HostTensor {
    let n = shape.iter().product();
    let data = (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
    HostTensor::new(shape, data)
}

#[test]
fn engine_compiles_and_runs_step() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();
    let model = manifest.model("mlp").unwrap();
    let params = manifest.load_init_params(model).unwrap();

    let mut rng = Rng::new(1);
    let x = rand_tensor(&mut rng, model.input_shape.clone(), 1.0);
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes + rng.below_usize(model.num_classes)] = 1.0;
    }

    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let outs = engine.execute(&model.step_emp, &inputs).unwrap();
    assert_eq!(outs.len(), model.step_outputs.len(), "output arity");

    let loss = outs[model.output_index("loss", None).unwrap()].data[0];
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // fresh 10-class model: loss near ln(10)
    assert!((loss - (10.0f32).ln()).abs() < 1.5, "loss={loss}");

    let ncorrect = outs[model.output_index("ncorrect", None).unwrap()].data[0];
    assert!((0.0..=model.batch as f32).contains(&ncorrect));

    // every declared output shape matches
    for (o, spec) in outs.iter().zip(model.step_outputs.iter()) {
        assert_eq!(o.shape, spec.shape, "shape of {}", spec.name);
    }
}

#[test]
fn invert_executable_matches_gauss_jordan() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();

    // any invert_<n> artifact
    let name = manifest
        .executables
        .keys()
        .find(|k| k.starts_with("invert_"))
        .expect("no invert executable")
        .clone();
    let n: usize = name.trim_start_matches("invert_").parse().unwrap();

    let mut rng = Rng::new(7);
    // SPD test matrix
    let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let bm = Mat::from_vec(n, n, b);
    let mut m = bm.transpose().matmul(&bm).scale(1.0 / n as f32);
    m.symmetrize();
    let lambda = 0.1f32;

    let mt = HostTensor::from_mat(&m);
    let damp = HostTensor::scalar(lambda);
    let outs = engine.execute(&name, &[&mt, &damp]).unwrap();
    let inv = outs[0].as_mat();

    let mut md = m.clone();
    md.add_diag(lambda);
    let want = solve::gauss_jordan_inverse(&md).unwrap();
    let diff = inv.max_abs_diff(&want);
    assert!(diff < 5e-3, "NS-vs-GJ diff {diff}");
    assert!(solve::inverse_residual(&md, &inv) < 5e-3);
}

#[test]
fn fc_factor_executable_matches_host_syrk() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();
    let model = manifest.model("mlp").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();

    let b = model.batch;
    let d = layer.a_dim;
    let mut rng = Rng::new(9);
    let tap = rand_tensor(&mut rng, vec![b, d], 1.0);
    let outs = engine.execute(&layer.factor_a, &[&tap]).unwrap();
    let a = outs[0].as_mat();

    // host oracle: A = tap^T tap / B
    let tm = tap.as_mat();
    let want = tm.transpose().matmul(&tm).scale(1.0 / b as f32);
    assert!(a.max_abs_diff(&want) < 1e-3, "diff {}", a.max_abs_diff(&want));
}

#[test]
fn precond_executable_matches_host_matmul() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();
    let model = manifest.model("mlp").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();
    let (m, n) = layer.grad_shape;

    let mut rng = Rng::new(11);
    let ginv = rand_tensor(&mut rng, vec![m, m], 0.5);
    let grad = rand_tensor(&mut rng, vec![m, n], 0.5);
    let ainv = rand_tensor(&mut rng, vec![n, n], 0.5);
    let outs = engine.execute(&layer.precond, &[&ginv, &grad, &ainv]).unwrap();
    let got = outs[0].as_mat();
    let want = ginv.as_mat().matmul(&grad.as_mat()).matmul(&ainv.as_mat());
    assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
}

#[test]
fn bn_inv_executable_is_true_inverse() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();
    let model = manifest.model("convnet_small").unwrap();
    let layer = model.kfac_layers.iter().find(|l| l.is_bn()).unwrap();
    let (b, c) = (model.batch, layer.channels);

    let mut rng = Rng::new(13);
    let gg = rand_tensor(&mut rng, vec![b, c], 1.0);
    let gb = rand_tensor(&mut rng, vec![b, c], 1.0);
    let lam = 0.05f32;
    let damp = HostTensor::scalar(lam);
    let outs = engine.execute(&layer.bn_inv, &[&gg, &gb, &damp]).unwrap();
    let inv = &outs[0];
    assert_eq!(inv.shape, vec![c, 2, 2]);

    // host fisher: per channel 2x2 from per-sample grads
    for ch in 0..c.min(4) {
        let (mut f11, mut f12, mut f22) = (0.0f64, 0.0f64, 0.0f64);
        for bi in 0..b {
            let g1 = gg.data[bi * c + ch] as f64;
            let g2 = gb.data[bi * c + ch] as f64;
            f11 += g1 * g1;
            f12 += g1 * g2;
            f22 += g2 * g2;
        }
        let (f11, f12, f22) =
            (f11 / b as f64 + lam as f64, f12 / b as f64, f22 / b as f64 + lam as f64);
        let got = &inv.data[ch * 4..ch * 4 + 4];
        // check F * F^-1 = I
        let i00 = f11 * got[0] as f64 + f12 * got[2] as f64;
        let i01 = f11 * got[1] as f64 + f12 * got[3] as f64;
        let i11 = f12 * got[1] as f64 + f22 * got[3] as f64;
        assert!((i00 - 1.0).abs() < 1e-3, "ch{ch} i00={i00}");
        assert!(i01.abs() < 1e-3);
        assert!((i11 - 1.0).abs() < 1e-3);
    }
}

#[test]
fn step_1mc_runs_with_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::new(&manifest).unwrap();
    let model = manifest.model("mlp").unwrap();
    let params = manifest.load_init_params(model).unwrap();

    let mut rng = Rng::new(15);
    let x = rand_tensor(&mut rng, model.input_shape.clone(), 1.0);
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes + rng.below_usize(model.num_classes)] = 1.0;
    }
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let o1 = engine.execute_seeded(&model.step_1mc, &inputs, Some(3)).unwrap();
    let o2 = engine.execute_seeded(&model.step_1mc, &inputs, Some(4)).unwrap();
    let loss_idx = model.output_index("loss", None).unwrap();
    assert_eq!(o1[loss_idx].data[0], o2[loss_idx].data[0], "loss is seed-free");
    // the MC taps differ with the seed
    let gt_idx = model
        .output_index("g_tap", model.kfac_layers.first().map(|l| l.name.as_str()))
        .unwrap();
    let d: f32 = o1[gt_idx]
        .data
        .iter()
        .zip(o2[gt_idx].data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-7, "1mc taps should vary with seed");
}
