//! The repo lint as a `cargo test` gate: the committed tree must scan
//! clean under the committed `lint.toml`, and the lint itself must
//! still flag every `fixtures/bad_*.rs` (so a scanner regression can't
//! silently green the tree).
//!
//! Integration tests run with the package root (`rust/`) as cwd, so the
//! repo root is `..`.

use std::path::Path;

fn repo_root() -> &'static Path {
    Path::new("..")
}

#[test]
fn committed_tree_is_lint_clean() {
    let root = repo_root();
    let cfg = spngd_lint::Config::load(&root.join("lint.toml")).expect("lint.toml must parse");
    let findings = spngd_lint::run(root, &cfg).expect("lint scan must run");
    assert!(
        findings.is_empty(),
        "spngd-lint found {} violation(s) in the committed tree:\n{}",
        findings.len(),
        findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn fixture_self_test_passes() {
    let report = spngd_lint::self_test(&repo_root().join("tools/lint"))
        .expect("fixture self-test must pass");
    // Every fixture accounted for — the report names each one.
    assert!(report.contains("good_clean.rs"), "self-test report: {report}");
}
