//! Differential tests for the threaded dist engine and the trainer's
//! worker-count determinism:
//!
//! - the threaded engine (real OS worker threads + `RingComm`) must
//!   produce bit-identical losses, parameters and byte accounting to the
//!   sequential coordinator at every step;
//! - for a fixed global lane total (workers × grad_accum) the
//!   synthesized global batch, losses and updates must be bit-identical
//!   across worker counts — the property that makes `workers=1` runs
//!   ground truth for `workers=4` runs.

use std::sync::Arc;

use spngd::collectives::Collective;
use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::optim::{self, BnMode, Fisher, HyperParams, Preconditioner, SpNgd};

fn base_builder(model: &str, opt: Arc<dyn Preconditioner>) -> TrainerBuilder {
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0,
        e_end: 200.0,
        eta0: 0.02,
        m0: 0.018,
        lambda: 2.5e-3,
    };
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

/// The core differential: threaded == sequential, step by step, bitwise.
#[test]
fn threaded_engine_matches_sequential_bitwise() {
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut thr = base_builder("mlp", optim::spngd()).dist(DistMode::Threaded).build().unwrap();
    for i in 0..6 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(rs.train_acc, rt.train_acc, "acc diverged at step {i}");
        assert_eq!(rs.refreshed, rt.refreshed, "plan diverged at step {i}");
        // byte accounting parity (SimComm vs RingComm formulas)
        assert_eq!(rs.comm.rs_stats_a, rt.comm.rs_stats_a, "step {i}");
        assert_eq!(rs.comm.rs_stats_g, rt.comm.rs_stats_g, "step {i}");
        assert_eq!(rs.comm.ar_grads, rt.comm.ar_grads, "step {i}");
        assert_eq!(rs.comm.ag_params, rt.comm.ag_params, "step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

#[test]
fn threaded_engine_matches_sequential_on_convnet() {
    let mut seq = base_builder("convnet_tiny", optim::spngd()).workers(4).build().unwrap();
    let mut thr = base_builder("convnet_tiny", optim::spngd())
        .workers(4)
        .dist(DistMode::Threaded)
        .build()
        .unwrap();
    for i in 0..3 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

/// Fixed lane total, varying worker count: (W=1, accum=4), (2, 2), (4, 1)
/// must synthesize the same global batch and produce identical training.
#[test]
fn worker_count_invariance_sequential() {
    let mk = |workers: usize, accum: usize| {
        base_builder("mlp", optim::spngd())
            .workers(workers)
            .grad_accum(accum)
            .build()
            .unwrap()
    };
    let mut a = mk(1, 4);
    let mut b = mk(2, 2);
    let mut c = mk(4, 1);
    for i in 0..5 {
        let ra = a.step().unwrap();
        let rb = b.step().unwrap();
        let rc = c.step().unwrap();
        assert_eq!(ra.loss, rb.loss, "W=1 vs W=2 loss at step {i}");
        assert_eq!(ra.loss, rc.loss, "W=1 vs W=4 loss at step {i}");
        assert_eq!(ra.train_acc, rc.train_acc, "acc at step {i}");
        let (pa, pb, pc) = (flat_params(&a), flat_params(&b), flat_params(&c));
        assert_eq!(pa, pb, "W=1 vs W=2 params at step {i}");
        assert_eq!(pa, pc, "W=1 vs W=4 params at step {i}");
    }
}

/// Worker-count invariance holds for the threaded engine too, which is
/// exactly why a W=1 sequential run is ground truth for a W=4 dist run.
#[test]
fn worker_count_invariance_threaded_vs_single_sequential() {
    let mut seq =
        base_builder("mlp", optim::spngd()).workers(1).grad_accum(4).build().unwrap();
    let mut thr = base_builder("mlp", optim::spngd())
        .workers(4)
        .grad_accum(1)
        .dist(DistMode::Threaded)
        .build()
        .unwrap();
    for i in 0..5 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

/// The stale-statistics scheduler lives at the owners; its refresh plans
/// must evolve identically under both engines.
#[test]
fn threaded_stale_scheduler_matches_sequential() {
    // same stale config the sequential suite proves skips under
    // (trainer_integration::stale_scheduler_reduces_refreshes)
    let mk = |dist: DistMode| {
        let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
        base_builder("mlp", opt).grad_accum(4).dist(dist).build().unwrap()
    };
    let mut seq = mk(DistMode::Sequential);
    let mut thr = mk(DistMode::Threaded);
    let mut skipped_any = false;
    for i in 0..30 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.refreshed, rt.refreshed, "refresh plan diverged at step {i}");
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        skipped_any |= rs.refreshed < rs.total_stats;
    }
    assert!(skipped_any, "stale scheduler never skipped — test exercises nothing");
}

/// All practical-NGD modes run (and train) under the threaded engine.
#[test]
fn threaded_all_modes_one_step() {
    for (fisher, bn) in [
        (Fisher::Emp, BnMode::Unit),
        (Fisher::Emp, BnMode::Full),
        (Fisher::OneMc, BnMode::Unit),
    ] {
        let opt = Arc::new(SpNgd { fisher, bn_mode: bn, ..SpNgd::default() });
        let mut tr = base_builder("convnet_tiny", opt)
            .workers(3)
            .dist(DistMode::Threaded)
            .build()
            .unwrap();
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "{fisher:?}/{bn:?}");
        assert!(rec.comm.stats_total() > 0);
        assert_eq!(rec.refreshed, rec.total_stats, "first step refreshes all");
    }
}

#[test]
fn threaded_sgd_baseline() {
    let mut tr = base_builder("mlp", optim::sgd()).dist(DistMode::Threaded).build().unwrap();
    let first = tr.step().unwrap().loss;
    let mut last = first;
    for _ in 0..9 {
        last = tr.step().unwrap().loss;
    }
    assert!(last < first, "threaded sgd loss should drop: {first} -> {last}");
    assert_eq!(tr.comm().stats().stats_total(), 0, "SGD moves no statistics");
}

#[test]
fn threaded_loss_decreases_and_evaluates() {
    let mut tr = base_builder("mlp", optim::spngd())
        .workers(4)
        .dist(DistMode::Threaded)
        .build()
        .unwrap();
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..20 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "loss diverged at step {i}");
        if i == 0 {
            first = rec.loss;
        }
        last = rec.loss;
    }
    assert!(last < first, "threaded loss should drop: {first} -> {last}");
    let (vl, va) = tr.evaluate(4).unwrap();
    assert!(vl.is_finite() && (0.0..=1.0).contains(&va));
}
