//! Differential tests for the threaded dist engine and the trainer's
//! worker-count determinism:
//!
//! - the threaded engine (real OS worker threads + `RingComm`) must
//!   produce bit-identical losses, parameters and byte accounting to the
//!   sequential coordinator at every step;
//! - for a fixed global lane total (workers × grad_accum) the
//!   synthesized global batch, losses and updates must be bit-identical
//!   across worker counts — the property that makes `workers=1` runs
//!   ground truth for `workers=4` runs.

use std::sync::Arc;

use spngd::collectives::Collective;
use spngd::coordinator::{BnMode, DistMode, Fisher, Optim, Trainer, TrainerCfg};
use spngd::data::{AugmentCfg, SynthDataset};
use spngd::optim::{HyperParams, Schedule};
use spngd::runtime::native;

fn base_cfg(model: &str) -> TrainerCfg {
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0,
        e_end: 200.0,
        eta0: 0.02,
        m0: 0.018,
        lambda: 2.5e-3,
    };
    TrainerCfg {
        model: model.to_string(),
        workers: 2,
        grad_accum: 1,
        fisher: Fisher::Emp,
        bn_mode: BnMode::Unit,
        stale: false,
        stale_alpha: 0.1,
        lambda: hp.lambda,
        schedule: Schedule::new(hp, 50),
        optimizer: Optim::SpNgd,
        weight_rescale: false,
        clip_update_ratio: 0.3,
        augment: AugmentCfg::disabled(),
        bn_momentum: 0.9,
        fp16_comm: false,
        dist: DistMode::Sequential,
        seed: 7,
    }
}

fn make_trainer(cfg: TrainerCfg) -> Trainer {
    let (manifest, engine) = native::build_default().unwrap();
    let manifest = Arc::new(manifest);
    let m = manifest.model(&cfg.model).unwrap();
    let (c, h, w) = (m.input_shape[1], m.input_shape[2], m.input_shape[3]);
    let ds = SynthDataset::new(m.num_classes, c, h, w, 4000, 42);
    Trainer::new(manifest, Arc::new(engine), cfg, ds).unwrap()
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

/// The core differential: threaded == sequential, step by step, bitwise.
#[test]
fn threaded_engine_matches_sequential_bitwise() {
    let mut seq = make_trainer(base_cfg("mlp"));
    let mut cfg = base_cfg("mlp");
    cfg.dist = DistMode::Threaded;
    let mut thr = make_trainer(cfg);
    for i in 0..6 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(rs.train_acc, rt.train_acc, "acc diverged at step {i}");
        assert_eq!(rs.refreshed, rt.refreshed, "plan diverged at step {i}");
        // byte accounting parity (SimComm vs RingComm formulas)
        assert_eq!(rs.comm.rs_stats_a, rt.comm.rs_stats_a, "step {i}");
        assert_eq!(rs.comm.rs_stats_g, rt.comm.rs_stats_g, "step {i}");
        assert_eq!(rs.comm.ar_grads, rt.comm.ar_grads, "step {i}");
        assert_eq!(rs.comm.ag_params, rt.comm.ag_params, "step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

#[test]
fn threaded_engine_matches_sequential_on_convnet() {
    let mut cfg = base_cfg("convnet_tiny");
    cfg.dist = DistMode::Threaded;
    cfg.workers = 4;
    let mut seq4 = base_cfg("convnet_tiny");
    seq4.workers = 4;
    let mut seq = make_trainer(seq4);
    let mut thr = make_trainer(cfg);
    for i in 0..3 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

/// Fixed lane total, varying worker count: (W=1, accum=4), (2, 2), (4, 1)
/// must synthesize the same global batch and produce identical training.
#[test]
fn worker_count_invariance_sequential() {
    let mk = |workers: usize, accum: usize| {
        let mut cfg = base_cfg("mlp");
        cfg.workers = workers;
        cfg.grad_accum = accum;
        make_trainer(cfg)
    };
    let mut a = mk(1, 4);
    let mut b = mk(2, 2);
    let mut c = mk(4, 1);
    for i in 0..5 {
        let ra = a.step().unwrap();
        let rb = b.step().unwrap();
        let rc = c.step().unwrap();
        assert_eq!(ra.loss, rb.loss, "W=1 vs W=2 loss at step {i}");
        assert_eq!(ra.loss, rc.loss, "W=1 vs W=4 loss at step {i}");
        assert_eq!(ra.train_acc, rc.train_acc, "acc at step {i}");
        let (pa, pb, pc) = (flat_params(&a), flat_params(&b), flat_params(&c));
        assert_eq!(pa, pb, "W=1 vs W=2 params at step {i}");
        assert_eq!(pa, pc, "W=1 vs W=4 params at step {i}");
    }
}

/// Worker-count invariance holds for the threaded engine too, which is
/// exactly why a W=1 sequential run is ground truth for a W=4 dist run.
#[test]
fn worker_count_invariance_threaded_vs_single_sequential() {
    let mut seq = {
        let mut cfg = base_cfg("mlp");
        cfg.workers = 1;
        cfg.grad_accum = 4;
        make_trainer(cfg)
    };
    let mut thr = {
        let mut cfg = base_cfg("mlp");
        cfg.workers = 4;
        cfg.grad_accum = 1;
        cfg.dist = DistMode::Threaded;
        make_trainer(cfg)
    };
    for i in 0..5 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
    }
}

/// The stale-statistics scheduler lives at the owners; its refresh plans
/// must evolve identically under both engines.
#[test]
fn threaded_stale_scheduler_matches_sequential() {
    // same stale config the sequential suite proves skips under
    // (trainer_integration::stale_scheduler_reduces_refreshes)
    let mk = |dist: DistMode| {
        let mut cfg = base_cfg("mlp");
        cfg.stale = true;
        cfg.stale_alpha = 0.3;
        cfg.grad_accum = 4;
        cfg.dist = dist;
        make_trainer(cfg)
    };
    let mut seq = mk(DistMode::Sequential);
    let mut thr = mk(DistMode::Threaded);
    let mut skipped_any = false;
    for i in 0..30 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        assert_eq!(rs.refreshed, rt.refreshed, "refresh plan diverged at step {i}");
        assert_eq!(rs.loss, rt.loss, "loss diverged at step {i}");
        skipped_any |= rs.refreshed < rs.total_stats;
    }
    assert!(skipped_any, "stale scheduler never skipped — test exercises nothing");
}

/// All practical-NGD modes run (and train) under the threaded engine.
#[test]
fn threaded_all_modes_one_step() {
    for (fisher, bn) in [
        (Fisher::Emp, BnMode::Unit),
        (Fisher::Emp, BnMode::Full),
        (Fisher::OneMc, BnMode::Unit),
    ] {
        let mut cfg = base_cfg("convnet_tiny");
        cfg.fisher = fisher;
        cfg.bn_mode = bn;
        cfg.dist = DistMode::Threaded;
        cfg.workers = 3;
        let mut tr = make_trainer(cfg);
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "{fisher:?}/{bn:?}");
        assert!(rec.comm.stats_total() > 0);
        assert_eq!(rec.refreshed, rec.total_stats, "first step refreshes all");
    }
}

#[test]
fn threaded_sgd_baseline() {
    let mut cfg = base_cfg("mlp");
    cfg.optimizer = Optim::Sgd;
    cfg.dist = DistMode::Threaded;
    let mut tr = make_trainer(cfg);
    let first = tr.step().unwrap().loss;
    let mut last = first;
    for _ in 0..9 {
        last = tr.step().unwrap().loss;
    }
    assert!(last < first, "threaded sgd loss should drop: {first} -> {last}");
    assert_eq!(tr.comm().stats().stats_total(), 0, "SGD moves no statistics");
}

#[test]
fn threaded_loss_decreases_and_evaluates() {
    let mut cfg = base_cfg("mlp");
    cfg.dist = DistMode::Threaded;
    cfg.workers = 4;
    let mut tr = make_trainer(cfg);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..20 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "loss diverged at step {i}");
        if i == 0 {
            first = rec.loss;
        }
        last = rec.loss;
    }
    assert!(last < first, "threaded loss should drop: {first} -> {last}");
    let (vl, va) = tr.evaluate(4).unwrap();
    assert!(vl.is_finite() && (0.0..=1.0).contains(&va));
}
