//! Checkpoint/restore fidelity: a save → kill → resume cycle must be
//! **bit-identical to an uninterrupted run** — same per-step losses,
//! same final parameter digest — across all three engines (sequential,
//! threaded, multi-process) and both wire precisions. The "kill" is
//! dropping the trainer mid-run and rebuilding from scratch, so nothing
//! can survive outside the SPCK file itself.
//!
//! Also covered: the META fingerprint rejecting mismatched run configs
//! before any state is touched, corruption surfacing as a structured
//! error, and the proc engine's restore-over-a-live-trainer recovery
//! path (`recover_from_latest`).
//!
//! Worker processes (proc engine) are the test binary's sibling `spngd`
//! executable via `CARGO_BIN_EXE_spngd`, as in `tests/dist_proc.rs`.

use std::path::PathBuf;

use spngd::ckpt;
use spngd::collectives::Precision;
use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::dist::ProcCfg;
use spngd::optim::{self, HyperParams};

fn base_builder(dist: DistMode, precision: Precision) -> TrainerBuilder {
    let opt = optim::spngd();
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0, // effectively flat LR over these short runs
        e_end: 200.0,
        ..opt.default_hparams()
    };
    let mut b = TrainerBuilder::new("mlp")
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(2048)
        .data_seed(11)
        .seed(5)
        .precision(precision)
        .dist(dist);
    if matches!(dist, DistMode::Proc) {
        b = b.proc_cfg(ProcCfg {
            worker_bin: Some(env!("CARGO_BIN_EXE_spngd").to_string()),
            heartbeat_ms: 25,
            join_timeout_ms: 20_000,
            backoff_base_ms: 10,
            ..ProcCfg::default()
        });
    }
    b
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("spngd_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn run_steps(tr: &mut Trainer, n: usize) -> Vec<f32> {
    (0..n).map(|_| tr.step().unwrap().loss).collect()
}

/// The core property: N uninterrupted steps == K steps + save + kill +
/// fresh build + resume + (N-K) steps, bitwise.
fn assert_resume_bitwise(tag: &str, dist: DistMode, precision: Precision, k: usize, n: usize) {
    let mut a = base_builder(dist, precision).build().unwrap();
    let losses_a = run_steps(&mut a, n);
    let digest_a = a.params_digest();
    drop(a);

    let dir = tmpdir(tag);
    let mut b = base_builder(dist, precision).build().unwrap();
    let losses_b = run_steps(&mut b, k);
    b.save_checkpoint(&dir).unwrap();
    drop(b); // the "kill": no in-memory state survives

    let mut c = base_builder(dist, precision).build().unwrap();
    assert_eq!(c.resume_latest(&dir).unwrap(), Some(k as u64), "{tag}: resume step");
    let losses_c = run_steps(&mut c, n - k);

    assert_eq!(losses_a[..k], losses_b[..], "{tag}: pre-kill prefix diverged");
    assert_eq!(losses_a[k..], losses_c[..], "{tag}: post-resume losses diverged");
    assert_eq!(digest_a, c.params_digest(), "{tag}: final params diverged");
    assert_eq!(c.log.final_params_fnv, Some(c.params_digest()), "{tag}: RunLog digest");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_is_bitwise_sequential_f32() {
    assert_resume_bitwise("seq_f32", DistMode::Sequential, Precision::F32, 3, 6);
}

#[test]
fn resume_is_bitwise_sequential_mixed() {
    assert_resume_bitwise("seq_mixed", DistMode::Sequential, Precision::Mixed, 3, 6);
}

#[test]
fn resume_is_bitwise_threaded_f32() {
    assert_resume_bitwise("thr_f32", DistMode::Threaded, Precision::F32, 3, 6);
}

#[test]
fn resume_is_bitwise_threaded_mixed() {
    assert_resume_bitwise("thr_mixed", DistMode::Threaded, Precision::Mixed, 3, 6);
}

#[test]
fn resume_is_bitwise_proc_f32() {
    assert_resume_bitwise("proc_f32", DistMode::Proc, Precision::F32, 2, 4);
}

#[test]
fn resume_is_bitwise_proc_mixed() {
    assert_resume_bitwise("proc_mixed", DistMode::Proc, Precision::Mixed, 2, 4);
}

/// The resume matrix above runs with the loader's default prefetch ON,
/// so saves happen mid-double-buffer and ride the stash sections. This
/// cross-check pins the other leg: prefetch itself is bitwise-neutral,
/// so stash-bearing and stash-free checkpoints describe the same run.
#[test]
fn prefetch_is_bitwise_neutral() {
    let mut on = base_builder(DistMode::Sequential, Precision::F32).build().unwrap();
    let mut off =
        base_builder(DistMode::Sequential, Precision::F32).prefetch(false).build().unwrap();
    let la = run_steps(&mut on, 4);
    let lb = run_steps(&mut off, 4);
    assert_eq!(la, lb, "prefetch must be bitwise-neutral");
    assert_eq!(on.params_digest(), off.params_digest());
}

#[test]
fn restore_rejects_mismatched_run_configs() {
    let dir = tmpdir("meta_reject");
    let mut tr = base_builder(DistMode::Sequential, Precision::F32).build().unwrap();
    run_steps(&mut tr, 2);
    let path = tr.save_checkpoint(&dir).unwrap();
    let ck = ckpt::read_file(&path).unwrap();

    // wrong seed
    let mut other = base_builder(DistMode::Sequential, Precision::F32).seed(6).build().unwrap();
    let e = format!("{:#}", other.restore(&ck).unwrap_err());
    assert!(e.contains("seed"), "{e}");

    // wrong model
    let mut other = TrainerBuilder::new("convnet_tiny")
        .optimizer(optim::spngd())
        .workers(2)
        .dataset_len(2048)
        .data_seed(11)
        .seed(5)
        .build()
        .unwrap();
    let e = format!("{:#}", other.restore(&ck).unwrap_err());
    assert!(e.contains("model"), "{e}");

    // wrong wire precision
    let mut other = base_builder(DistMode::Sequential, Precision::Mixed).build().unwrap();
    let e = format!("{:#}", other.restore(&ck).unwrap_err());
    assert!(e.contains("precision"), "{e}");

    // wrong lane total (workers × grad-accum)
    let mut other =
        base_builder(DistMode::Sequential, Precision::F32).workers(4).build().unwrap();
    let e = format!("{:#}", other.restore(&ck).unwrap_err());
    assert!(e.contains("lane"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_checkpoint_is_a_structured_error_not_a_panic() {
    let dir = tmpdir("corrupt");
    let mut tr = base_builder(DistMode::Sequential, Precision::F32).build().unwrap();
    run_steps(&mut tr, 1);
    let path = tr.save_checkpoint(&dir).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40; // flip one payload bit → a section checksum breaks
    std::fs::write(&path, &bytes).unwrap();

    let mut fresh = base_builder(DistMode::Sequential, Precision::F32).build().unwrap();
    let err = fresh.resume_from(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("checksum") || msg.contains("parsing") || msg.contains("truncated"),
        "unexpected diagnostic: {msg}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The proc fault-recovery path: restore the latest checkpoint over a
/// *live* trainer (relaunching the worker pool), then keep training —
/// the continuation must be bitwise equal to the uninterrupted run.
#[test]
fn proc_recover_from_latest_restores_a_live_trainer() {
    let mut a = base_builder(DistMode::Proc, Precision::F32).build().unwrap();
    let losses_a = run_steps(&mut a, 4);
    let digest_a = a.params_digest();
    drop(a);

    let dir = tmpdir("proc_recover");
    let mut b = base_builder(DistMode::Proc, Precision::F32).build().unwrap();
    run_steps(&mut b, 2);
    b.save_checkpoint(&dir).unwrap();
    // train past the checkpoint, then roll back in place — the restart
    // policy's move after a zero-survivor fatal
    run_steps(&mut b, 1);
    let step = b.recover_from_latest(&dir).unwrap();
    assert_eq!(step, 2);
    let tail = run_steps(&mut b, 2);
    assert_eq!(losses_a[2..], tail[..], "post-recovery losses diverged");
    assert_eq!(digest_a, b.params_digest(), "post-recovery params diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
