//! Differential tests for the blocked/parallel linalg substrate: every
//! parallel kernel against its retained single-threaded `*_ref` oracle,
//! across odd shapes (non-multiples of the k-block, fewer rows than
//! threads, 1×N / N×1, padded convs) and pool sizes 1 / 2 / 8.

use spngd::linalg::{Mat, Scratch};
use spngd::runtime::native::kernels;
use spngd::runtime::{native, Executor, HostTensor};
use spngd::util::pool::Pool;
use spngd::util::rng::Rng;

const POOL_SIZES: [usize; 3] = [1, 2, 8];

fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape, (0..n).map(|_| rng.normal() as f32).collect())
}

#[test]
fn matmul_matches_ref_across_pools_and_shapes() {
    let shapes = [
        (1, 1, 1),
        (1, 17, 5),
        (5, 17, 1),
        (2, 300, 2),
        (31, 257, 33),
        (64, 64, 64),
        (2, 40, 40),
        (129, 7, 65),
    ];
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(7);
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = a.matmul_with(&pool, &b);
            let want = a.matmul_ref(&b);
            let tol = 1e-5 * k as f32;
            let d = got.max_abs_diff(&want);
            assert!(d <= tol, "matmul {m}x{k}x{n} @ {threads} threads: diff {d}");
        }
    }
}

#[test]
fn matmul_transposed_matches_ref_across_pools_and_shapes() {
    let shapes = [(1, 3, 1), (4, 27, 7), (19, 64, 33), (3, 301, 2), (65, 8, 129)];
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(11);
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let got = a.matmul_transposed_with(&pool, &b);
            let want = a.matmul_ref(&b.transpose());
            let tol = 1e-5 * k as f32;
            let d = got.max_abs_diff(&want);
            assert!(d <= tol, "matmul_t {m}x{k}x{n} @ {threads} threads: diff {d}");
        }
    }
}

#[test]
fn syrk_matches_ref_across_pools_and_shapes() {
    // rows < threads, rows < min-band, long-thin and short-wide taps,
    // plus factors wide enough (c ≥ 160) to take the packed j-tile path
    let shapes = [
        (1, 1),
        (3, 5),
        (7, 3),
        (100, 17),
        (1000, 7),
        (64, 33),
        (5, 64),
        (513, 48),
        (64, 200),
        (40, 513),
    ];
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(13);
        for &(r, c) in &shapes {
            let x = rand_mat(&mut rng, r, c);
            let scale = 1.0 / r as f32;
            let got = kernels::syrk_with(&pool, &x, scale);
            let want = kernels::syrk_ref(&x, scale);
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-5, "syrk {r}x{c} @ {threads} threads: diff {d}");
            for i in 0..c {
                for j in 0..c {
                    assert_eq!(got.at(i, j), got.at(j, i), "syrk symmetry {r}x{c}");
                }
            }
        }
    }
}

#[test]
fn im2col_matches_ref_exactly_across_pools() {
    // (shape, k, stride, pad) — includes b=1, pad > spatial dim, stride 2
    let cases = [
        (vec![1, 1, 3, 3], 1, 1, 0),
        (vec![2, 3, 5, 5], 3, 2, 1),
        (vec![3, 2, 4, 4], 2, 1, 0),
        (vec![2, 1, 2, 2], 3, 1, 2),
        (vec![9, 4, 6, 6], 3, 1, 1),
    ];
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(17);
        for (shape, k, s, p) in &cases {
            let x = rand_tensor(&mut rng, shape.clone());
            let (got, ho, wo) = kernels::im2col_with(&pool, &x, *k, *s, *p);
            let (want, ho_r, wo_r) = kernels::im2col_ref(&x, *k, *s, *p);
            assert_eq!((ho, wo), (ho_r, wo_r));
            assert_eq!(got.data, want.data, "im2col {shape:?} k{k} s{s} p{p} @ {threads}");
        }
    }
}

#[test]
fn col2im_matches_ref_exactly_across_pools() {
    let cases = [
        ([1, 1, 3, 3], 1, 1, 0),
        ([2, 3, 5, 5], 3, 2, 1),
        ([3, 2, 4, 4], 2, 1, 0),
        ([2, 1, 2, 2], 3, 1, 2),
        ([9, 4, 6, 6], 3, 1, 1),
    ];
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(19);
        for (shape, k, s, p) in &cases {
            let [b, c, h, w] = *shape;
            let (ho, wo) = kernels::conv_out_dims(h, w, *k, *s, *p);
            let dp = rand_mat(&mut rng, b * ho * wo, c * k * k);
            let got = kernels::col2im_with(&pool, &dp, shape, *k, *s, *p, ho, wo);
            let want = kernels::col2im_ref(&dp, shape, *k, *s, *p, ho, wo);
            assert_eq!(got.data, want.data, "col2im {shape:?} k{k} s{s} p{p} @ {threads}");
        }
    }
}

#[test]
fn ns_inverse_matches_ref_across_pools() {
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let mut rng = Rng::new(23);
        for &n in &[5usize, 16, 33, 48] {
            let b = rand_mat(&mut rng, n, n);
            let mut m = b.matmul_ref(&b.transpose()).scale(1.0 / n as f32);
            m.symmetrize();
            let mut scratch = Scratch::new();
            let got = kernels::ns_inverse_with(&pool, &mut scratch, &m.data, n, 0.05, 20);
            let want = kernels::ns_inverse_ref(&m, 0.05, 20);
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-4, "ns_inverse {n} @ {threads} threads: diff {d}");
        }
    }
}

/// Force each SIMD dispatch path in turn (`SPNGD_SIMD` override hook)
/// and assert (a) every kernel still agrees with its naive `*_ref`
/// oracle, and (b) the scalar and native paths are **bit-identical** —
/// the vector lanes replicate the scalar op sequence exactly (separate
/// mul+add, scalar-equivalent reduce tree), so equality is `==`, not a
/// tolerance.
#[test]
fn simd_dispatch_paths_agree_with_ref_and_each_other() {
    use spngd::util::simd;
    let mm_shapes = [(2, 300, 2), (31, 257, 33), (129, 7, 65)];
    let syrk_shapes = [(100, 17), (64, 200), (40, 513)];
    let mut per_mode: Vec<Vec<Vec<f32>>> = Vec::new();
    for mode in ["scalar", "native"] {
        simd::force(mode);
        if mode == "scalar" {
            assert_eq!(simd::kernel_name(), "scalar");
        }
        let pool = Pool::new(4);
        let mut rng = Rng::new(43); // reseeded per mode: identical inputs
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for &(m, k, n) in &mm_shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = a.matmul_with(&pool, &b);
            let want = a.matmul_ref(&b);
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-5 * k as f32, "matmul {m}x{k}x{n} [{mode}]: diff {d}");
            outs.push(got.data);
            let bt = rand_mat(&mut rng, n, k);
            let got_t = a.matmul_transposed_with(&pool, &bt);
            let want_t = a.matmul_ref(&bt.transpose());
            let d = got_t.max_abs_diff(&want_t);
            assert!(d <= 1e-5 * k as f32, "matmul_t {m}x{k}x{n} [{mode}]: diff {d}");
            outs.push(got_t.data);
        }
        for &(r, c) in &syrk_shapes {
            let x = rand_mat(&mut rng, r, c);
            let got = kernels::syrk_with(&pool, &x, 1.0 / r as f32);
            let want = kernels::syrk_ref(&x, 1.0 / r as f32);
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-5, "syrk {r}x{c} [{mode}]: diff {d}");
            outs.push(got.data);
        }
        per_mode.push(outs);
    }
    simd::force("auto"); // back to runtime detection for other tests
    assert!(["avx2", "neon", "scalar"].contains(&simd::kernel_name()));
    for (i, (s, n)) in per_mode[0].iter().zip(per_mode[1].iter()).enumerate() {
        assert_eq!(s, n, "output {i} differs bitwise between scalar and native paths");
    }
}

#[test]
fn matmul_nan_propagates_through_zero_rows() {
    // regression: the old kernel skipped `a == 0.0` and silently dropped
    // NaN/Inf from the other operand
    for &threads in &POOL_SIZES {
        let pool = Pool::new(threads);
        let a = Mat::zeros(3, 4);
        let mut b = Mat::zeros(4, 2);
        b.data[0] = f32::NAN;
        b.data[3] = f32::INFINITY;
        let out = a.matmul_with(&pool, &b);
        assert!(out.data[0].is_nan(), "NaN must propagate @ {threads} threads");
        assert!(out.data[1].is_nan(), "0 * inf must be NaN @ {threads} threads");
    }
}

#[test]
fn scratch_reuse_keeps_step_outputs_identical() {
    // two executions of the same step through one backend (shared scratch
    // arena) must be bit-identical — recycled buffers cannot leak state
    let (manifest, backend) = native::build(&["convnet_tiny"], 3).unwrap();
    let model = manifest.model("convnet_tiny").unwrap();
    let params = manifest.load_init_params(model).unwrap();
    let mut rng = Rng::new(31);
    let n_in: usize = model.input_shape.iter().product();
    let x = HostTensor::new(
        model.input_shape.clone(),
        (0..n_in).map(|_| rng.f32() * 2.0 - 1.0).collect(),
    );
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes + rng.below_usize(model.num_classes)] = 1.0;
    }
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let o1 = backend.execute(&model.step_emp, &inputs).unwrap();
    let o2 = backend.execute(&model.step_emp, &inputs).unwrap();
    assert_eq!(o1.len(), o2.len());
    for (a, b) in o1.iter().zip(o2.iter()) {
        assert_eq!(a.data, b.data, "step outputs must be reproducible");
    }
}
