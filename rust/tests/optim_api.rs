//! Differential suite for the composable optimizer API:
//!
//! (a) the trait-based SP-NGD and SGD paths are **bit-identical to the
//!     pre-refactor trainer**: `RefTrainer` below is a frozen, straight-
//!     line copy of the pre-refactor step math (lane loop, canonical
//!     f64 reductions, Alg. 2 scheduler refresh, π-split damped
//!     inversion, preconditioning, guard, clip, Eq. 23 momentum) that
//!     must track the real `Trainer` loss- and parameter-bitwise under
//!     both dist engines — this is the pre-refactor golden, expressed as
//!     executable reference code instead of hardcoded constants so it
//!     holds on any machine;
//! (b) LARS smoke-trains the synth model end to end with decreasing
//!     loss (the API carries a genuinely new optimizer);
//! (c) a `MockPreconditioner` asserts the Stage 4a/4b call contract
//!     (refresh at most once per layer per step — at the owner — and
//!     direction exactly once per layer per step, on both engines);
//! plus the registry's hard-error contract and the `SPNGD_OPTIM` harness
//! hook the CI matrix drives.

use std::sync::{Arc, Mutex};

use anyhow::Result;
use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::data::SynthDataset;
use spngd::kfac::bn::BnFisher;
use spngd::kfac::damping::pi_split;
use spngd::linalg::Mat;
use spngd::optim::{
    self, HyperParams, LayerStateBox, Preconditioner, Schedule, SpNgd, StaleState, StatKind,
};
use spngd::runtime::{native, Executor, HostTensor, ModelManifest};
use spngd::util::rng::Rng;

// ------------------------------------------------------------------
// shared test composition (mirrors the pre-refactor suites' base_cfg)

fn flat_hp(eta0: f64, m0: f64) -> HyperParams {
    HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0, // effectively flat LR for these short runs
        e_end: 200.0,
        eta0,
        m0,
        lambda: 2.5e-3,
    }
}

fn builder(model: &str, opt: Arc<dyn Preconditioner>, eta0: f64, m0: f64) -> TrainerBuilder {
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(flat_hp(eta0, m0))
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

// ------------------------------------------------------------------
// (a) the frozen pre-refactor reference implementation
//
// Everything below is a verbatim port of the PRE-refactor
// `coordinator/trainer.rs` math (run_lane statistics construction,
// refresh_and_invert_layer, update_layer, clip_direction, spngd_update)
// with the enum-era `ngd: bool` switch. Do NOT "clean this up" to call
// into `optim/` — its whole value is being an independent copy of the
// original op sequence.

struct RefCfg {
    model: String,
    workers: usize,
    grad_accum: usize,
    /// true = SP-NGD (emp Fisher, unitBN), false = SGD
    ngd: bool,
    stale: bool,
    stale_alpha: f32,
    lambda: f32,
    clip: f32,
    seed: u64,
}

struct RefLayer {
    a_stale: StaleState,
    g_stale: StaleState,
    a: Option<Mat>,
    g: Option<Mat>,
    a_inv: Option<HostTensor>,
    g_inv: Option<HostTensor>,
    bn_fisher: Option<BnFisher>,
}

struct RefTrainer {
    cfg: RefCfg,
    model: ModelManifest,
    engine: Arc<dyn Executor>,
    params: Vec<HostTensor>,
    velocity: Vec<HostTensor>,
    layers: Vec<RefLayer>,
    dataset: SynthDataset,
    data_rng: Rng,
    schedule: Schedule,
    step: u64,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum RefStat {
    A,
    G,
    BnF,
}

impl RefTrainer {
    fn new(cfg: RefCfg, eta0: f64, m0: f64) -> Result<RefTrainer> {
        let (manifest, backend) = native::build_default()?;
        let engine: Arc<dyn Executor> = Arc::new(backend);
        let model = manifest.model(&cfg.model)?.clone();
        let params = manifest.load_init_params(&model)?;
        let velocity: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(p.shape.clone())).collect();
        // identical data-RNG derivation to the pre-refactor Trainer::new
        // (augmentation was disabled in this suite — a disabled pipeline
        // is an exact identity that consumes no RNG, pre- and
        // post-refactor, so it is simply omitted here)
        let mut rng = Rng::new(cfg.seed);
        let layers = model
            .kfac_layers
            .iter()
            .map(|_| RefLayer {
                a_stale: StaleState::new(cfg.stale_alpha),
                g_stale: StaleState::new(cfg.stale_alpha),
                a: None,
                g: None,
                a_inv: None,
                g_inv: None,
                bn_fisher: None,
            })
            .collect();
        let (c, h, w) = (model.input_shape[1], model.input_shape[2], model.input_shape[3]);
        let dataset = SynthDataset::new(model.num_classes, c, h, w, 4000, 42);
        Ok(RefTrainer {
            data_rng: rng.fork(0xDA7A),
            cfg,
            model,
            engine,
            params,
            velocity,
            layers,
            dataset,
            schedule: Schedule::new(flat_hp(eta0, m0), 50),
            step: 0,
        })
    }

    /// One pre-refactor step. Returns (mean loss, refreshed count).
    fn step(&mut self) -> Result<(f32, usize)> {
        self.step += 1;
        let t = self.step;
        let lanes_n = self.cfg.workers.max(1) * self.cfg.grad_accum.max(1);

        // refresh plan (pre-refactor loop shape)
        let mut plan: Vec<(usize, RefStat)> = Vec::new();
        if self.cfg.ngd {
            for (li, l) in self.layers.iter_mut().enumerate() {
                let ml = &self.model.kfac_layers[li];
                let due_always = !self.cfg.stale;
                if ml.is_bn() {
                    if due_always || l.a_stale.due(t) {
                        plan.push((li, RefStat::BnF));
                    } else {
                        l.a_stale.note_skip();
                    }
                } else {
                    if due_always || l.a_stale.due(t) {
                        plan.push((li, RefStat::A));
                    } else {
                        l.a_stale.note_skip();
                    }
                    if due_always || l.g_stale.due(t) {
                        plan.push((li, RefStat::G));
                    } else {
                        l.g_stale.note_skip();
                    }
                }
            }
        }

        // Stage 1-2 per lane (canonical order), emp Fisher
        let mut losses = Vec::with_capacity(lanes_n);
        let mut grad_lanes: Vec<Vec<f32>> = Vec::with_capacity(lanes_n);
        let mut factor_lanes: Vec<Vec<Mat>> = Vec::with_capacity(lanes_n);
        for _g in 0..lanes_n {
            let batch = self.dataset.batch(self.model.batch, &mut self.data_rng);
            let mut inputs: Vec<&HostTensor> = self.params.iter().collect();
            inputs.push(&batch.x);
            inputs.push(&batch.t);
            let outs = self.engine.execute(&self.model.step_emp, &inputs)?;
            losses.push(outs[0].data[0] as f64);
            let mut grads: Vec<f32> = Vec::with_capacity(self.model.total_param_count());
            for pi in 0..self.params.len() {
                grads.extend_from_slice(&outs[2 + pi].data);
            }
            grad_lanes.push(grads);
            let mut factors = Vec::with_capacity(plan.len());
            for &(li, kind) in &plan {
                let ml = &self.model.kfac_layers[li];
                let mat = match kind {
                    RefStat::A => {
                        let ti = self.model.output_index("a_tap", Some(&ml.name)).unwrap();
                        self.engine.execute(&ml.factor_a, &[&outs[ti]])?[0].as_mat()
                    }
                    RefStat::G => {
                        let ti = self.model.output_index("g_tap", Some(&ml.name)).unwrap();
                        let tap = &outs[ti];
                        let f = if ml.kind == "conv" {
                            let t2 = tap.nchw_to_rows_channels();
                            self.engine.execute(&ml.factor_g, &[&t2])?
                        } else {
                            self.engine.execute(&ml.factor_g, &[tap])?
                        };
                        f[0].as_mat()
                    }
                    RefStat::BnF => {
                        let gi = self.model.output_index("g_gamma", Some(&ml.name)).unwrap();
                        let bi = self.model.output_index("g_beta", Some(&ml.name)).unwrap();
                        BnFisher::from_taps(
                            &outs[gi].data,
                            &outs[bi].data,
                            self.model.batch,
                            ml.channels,
                        )
                        .as_mat()
                    }
                };
                factors.push(mat);
            }
            factor_lanes.push(factors);
        }

        // Stage 3: gradient mean — the canonical-lane f64 op sequence
        let n = grad_lanes[0].len();
        let mut grads_flat = vec![0.0f32; n];
        for (i, gf) in grads_flat.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for lane in &grad_lanes {
                acc += lane[i] as f64;
            }
            *gf = (acc / lanes_n as f64) as f32;
        }

        // Stages 2-3: statistic means (multiply-by-reciprocal form)
        let mut reduced: Vec<Mat> = Vec::with_capacity(plan.len());
        for item in 0..plan.len() {
            let (rows, cols) = (factor_lanes[0][item].rows, factor_lanes[0][item].cols);
            let inv_l = 1.0 / lanes_n as f64;
            let mut out = Mat::zeros(rows, cols);
            for (j, v) in out.data.iter_mut().enumerate() {
                let mut s = 0.0f64;
                for lane in &factor_lanes {
                    s += lane[item].data[j] as f64;
                }
                *v = (s * inv_l) as f32;
            }
            reduced.push(out);
        }

        // Stage 4a: pre-refactor refresh_and_invert_layer, grouped by layer
        let mut layer_jobs: Vec<(usize, Vec<(RefStat, Mat)>)> = Vec::new();
        for (&(li, kind), m) in plan.iter().zip(reduced.into_iter()) {
            match layer_jobs.last_mut() {
                Some((last, items)) if *last == li => items.push((kind, m)),
                _ => layer_jobs.push((li, vec![(kind, m)])),
            }
        }
        for (li, items) in layer_jobs {
            let ml = &self.model.kfac_layers[li];
            let layer = &mut self.layers[li];
            for (kind, m) in &items {
                match kind {
                    RefStat::A => {
                        layer.a_stale.refresh(t, m);
                        layer.a = Some(m.clone());
                    }
                    RefStat::G => {
                        layer.g_stale.refresh(t, m);
                        layer.g = Some(m.clone());
                    }
                    RefStat::BnF => {
                        layer.a_stale.refresh(t, m);
                    }
                }
            }
            let tr_a = layer.a.as_ref().map(|m| m.trace()).unwrap_or(0.0);
            let tr_g = layer.g.as_ref().map(|m| m.trace()).unwrap_or(0.0);
            for (kind, mat) in items {
                match kind {
                    RefStat::BnF => {
                        layer.bn_fisher = Some(BnFisher {
                            channels: ml.channels,
                            blocks: (0..ml.channels)
                                .map(|c| {
                                    [mat.data[c * 3], mat.data[c * 3 + 1], mat.data[c * 3 + 2]]
                                })
                                .collect(),
                        });
                    }
                    RefStat::A | RefStat::G => {
                        let a1 = Mat::from_vec(1, 1, vec![tr_a / (ml.a_dim as f32).max(1.0)]);
                        let g1 = Mat::from_vec(1, 1, vec![tr_g / (ml.g_dim as f32).max(1.0)]);
                        let (da, dg) = pi_split(&a1, &g1, self.cfg.lambda);
                        let (exe, bucket, dim, damp) = match kind {
                            RefStat::A => (&ml.invert_a, ml.a_bucket, ml.a_dim, da),
                            _ => (&ml.invert_g, ml.g_bucket, ml.g_dim, dg),
                        };
                        let padded = HostTensor::from_mat(&mat).pad_square(bucket);
                        let damp = HostTensor::scalar(damp);
                        let out = self.engine.execute(exe, &[&padded, &damp])?;
                        let inv = out[0].slice_square(dim);
                        match kind {
                            RefStat::A => layer.a_inv = Some(inv),
                            _ => layer.g_inv = Some(inv),
                        }
                    }
                }
            }
        }

        // Stage 4b: pre-refactor update_layer per layer, canonical order
        let lr = self.schedule.lr(t) as f32;
        let mom = self.schedule.momentum(t) as f32;
        let grad_of = |pi: usize| -> HostTensor {
            let mut off = 0usize;
            for p in &self.model.params[..pi] {
                off += p.shape.iter().product::<usize>();
            }
            let np: usize = self.model.params[pi].shape.iter().product();
            HostTensor::new(self.model.params[pi].shape.clone(), grads_flat[off..off + np].to_vec())
        };
        let clip = |dir: &mut HostTensor, w: &HostTensor| {
            if self.cfg.clip <= 0.0 || lr <= 0.0 {
                return;
            }
            let wn = w.norm().max(1e-3);
            let dn = dir.norm() * lr;
            if dn > self.cfg.clip * wn {
                dir.scale_inplace(self.cfg.clip * wn / dn);
            }
        };
        let update = |w: &mut HostTensor, v: &mut HostTensor, dir: &HostTensor| {
            for i in 0..w.data.len() {
                let dw = -lr * dir.data[i] + mom * v.data[i];
                w.data[i] += dw;
                v.data[i] = dw;
            }
        };
        for li in 0..self.model.kfac_layers.len() {
            let ml = &self.model.kfac_layers[li];
            let layer = &self.layers[li];
            if ml.is_bn() {
                let gi = self.model.param_index(&ml.gamma_param).unwrap();
                let bi = self.model.param_index(&ml.beta_param).unwrap();
                let g_gamma = grad_of(gi);
                let g_beta = grad_of(bi);
                let (dir_g, dir_b) = if self.cfg.ngd {
                    let f = layer.bn_fisher.as_ref().expect("bn fisher");
                    f.precondition(&g_gamma.data, &g_beta.data, self.cfg.lambda)
                } else {
                    (g_gamma.data.clone(), g_beta.data.clone())
                };
                let mut dg = HostTensor::new(g_gamma.shape.clone(), dir_g);
                let mut db = HostTensor::new(g_beta.shape.clone(), dir_b);
                if !dg.norm().is_finite() {
                    dg = g_gamma.clone();
                }
                if !db.norm().is_finite() {
                    db = g_beta.clone();
                }
                clip(&mut dg, &self.params[gi]);
                {
                    let (p, v) = (&mut self.params[gi], &mut self.velocity[gi]);
                    update(p, v, &dg);
                }
                clip(&mut db, &self.params[bi]);
                {
                    let (p, v) = (&mut self.params[bi], &mut self.velocity[bi]);
                    update(p, v, &db);
                }
            } else {
                let wi = self.model.param_index(&ml.weight_param).unwrap();
                let gw = grad_of(wi);
                let (m, nn) = ml.grad_shape;
                let gmat = gw.clone().reshape(vec![m, nn]);
                let mut dir = if self.cfg.ngd {
                    let ainv = layer.a_inv.as_ref().expect("A inverse");
                    let ginv = layer.g_inv.as_ref().expect("G inverse");
                    let out = self.engine.execute(&ml.precond, &[ginv, &gmat, ainv])?;
                    out[0].clone().reshape(gw.shape.clone())
                } else {
                    gw.clone()
                };
                if !dir.norm().is_finite() {
                    dir = gw.clone();
                }
                clip(&mut dir, &self.params[wi]);
                let (p, v) = (&mut self.params[wi], &mut self.velocity[wi]);
                update(p, v, &dir);
            }
        }

        let loss = (losses.iter().sum::<f64>() / lanes_n as f64) as f32;
        Ok((loss, plan.len()))
    }

    fn flat_params(&self) -> Vec<f32> {
        self.params.iter().flat_map(|p| p.data.clone()).collect()
    }
}

/// Run the trait-based trainer against the frozen reference, bitwise.
fn assert_matches_reference(
    model: &str,
    opt: Arc<dyn Preconditioner>,
    ngd: bool,
    stale: bool,
    stale_alpha: f32,
    grad_accum: usize,
    eta0: f64,
    m0: f64,
    dist: DistMode,
    steps: usize,
) {
    let mut tr = builder(model, opt, eta0, m0).grad_accum(grad_accum).dist(dist).build().unwrap();
    let mut rf = RefTrainer::new(
        RefCfg {
            model: model.to_string(),
            workers: 2,
            grad_accum,
            ngd,
            stale,
            stale_alpha,
            lambda: 2.5e-3,
            clip: 0.3,
            seed: 7,
        },
        eta0,
        m0,
    )
    .unwrap();
    for i in 0..steps {
        let rec = tr.step().unwrap();
        let (ref_loss, ref_refreshed) = rf.step().unwrap();
        assert_eq!(
            rec.loss.to_bits(),
            ref_loss.to_bits(),
            "loss diverged from pre-refactor reference at step {i} ({dist:?})"
        );
        assert_eq!(rec.refreshed, ref_refreshed, "refresh plan diverged at step {i}");
        assert_eq!(
            flat_params(&tr),
            rf.flat_params(),
            "params diverged from pre-refactor reference at step {i} ({dist:?})"
        );
    }
}

#[test]
fn trait_spngd_matches_pre_refactor_reference_sequential() {
    assert_matches_reference(
        "mlp",
        optim::spngd(),
        true,
        false,
        0.1,
        1,
        0.02,
        0.018,
        DistMode::Sequential,
        5,
    );
}

#[test]
fn trait_spngd_matches_pre_refactor_reference_threaded() {
    assert_matches_reference(
        "mlp",
        optim::spngd(),
        true,
        false,
        0.1,
        1,
        0.02,
        0.018,
        DistMode::Threaded,
        5,
    );
}

/// The acceptance pin for the data-pipeline redesign: `synth` training
/// through the new `DataSource`/`Loader` stack matches the pre-refactor
/// trainer bitwise with prefetch forced on AND forced off (the env
/// default is covered by the tests above under the CI matrix).
#[test]
fn trait_spngd_matches_reference_with_prefetch_forced_on_and_off() {
    for prefetch in [true, false] {
        let mut tr =
            builder("mlp", optim::spngd(), 0.02, 0.018).prefetch(prefetch).build().unwrap();
        let mut rf = RefTrainer::new(
            RefCfg {
                model: "mlp".to_string(),
                workers: 2,
                grad_accum: 1,
                ngd: true,
                stale: false,
                stale_alpha: 0.1,
                lambda: 2.5e-3,
                clip: 0.3,
                seed: 7,
            },
            0.02,
            0.018,
        )
        .unwrap();
        for i in 0..4 {
            let rec = tr.step().unwrap();
            let (ref_loss, _) = rf.step().unwrap();
            assert_eq!(
                rec.loss.to_bits(),
                ref_loss.to_bits(),
                "loss diverged at step {i} (prefetch={prefetch})"
            );
            assert_eq!(
                flat_params(&tr),
                rf.flat_params(),
                "params diverged at step {i} (prefetch={prefetch})"
            );
        }
    }
}

#[test]
fn trait_sgd_matches_pre_refactor_reference_both_engines() {
    for dist in [DistMode::Sequential, DistMode::Threaded] {
        assert_matches_reference("mlp", optim::sgd(), false, false, 0.1, 1, 0.05, 0.045, dist, 4);
    }
}

#[test]
fn trait_spngd_convnet_with_bn_matches_reference() {
    // conv + BN layers: exercises the unitBN Fisher and the conv G-tap
    // transpose through the trait path
    assert_matches_reference(
        "convnet_tiny",
        optim::spngd(),
        true,
        false,
        0.1,
        1,
        0.02,
        0.018,
        DistMode::Sequential,
        3,
    );
}

#[test]
fn trait_spngd_stale_scheduler_matches_reference() {
    let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
    assert_matches_reference(
        "mlp",
        opt,
        true,
        true,
        0.3,
        4,
        0.02,
        0.018,
        DistMode::Sequential,
        8,
    );
}

// ------------------------------------------------------------------
// (b) LARS carries end-to-end

#[test]
fn lars_smoke_trains_mlp() {
    let opt = optim::by_name("lars").unwrap();
    let mut tr = builder("mlp", opt, 0.02, 0.018).build().unwrap();
    let first = tr.step().unwrap().loss;
    let mut last = first;
    for _ in 0..29 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "lars loss diverged");
        last = rec.loss;
    }
    assert!(last < first, "lars loss should drop: {first} -> {last}");
    // first-order: zero statistics planned or communicated
    assert_eq!(tr.log.records[0].total_stats, 0);
    use spngd::collectives::Collective;
    assert_eq!(tr.comm().stats().stats_total(), 0);
}

#[test]
fn lars_runs_on_convnet_and_both_engines() {
    for dist in [DistMode::Sequential, DistMode::Threaded] {
        let mut tr =
            builder("convnet_tiny", optim::lars(), 0.02, 0.018).dist(dist).build().unwrap();
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite(), "{dist:?}");
        let rec2 = tr.step().unwrap();
        assert!(rec2.loss.is_finite(), "{dist:?}");
    }
}

/// LARS must be bit-identical across engines and lane splits like every
/// optimizer driven through the lane-canonical pipeline.
#[test]
fn lars_bit_identical_across_engines_and_lane_splits() {
    let mut seq = builder("mlp", optim::lars(), 0.02, 0.018).build().unwrap();
    let mut thr =
        builder("mlp", optim::lars(), 0.02, 0.018).dist(DistMode::Threaded).build().unwrap();
    let mut split =
        builder("mlp", optim::lars(), 0.02, 0.018).workers(1).grad_accum(2).build().unwrap();
    for i in 0..4 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        let rp = split.step().unwrap();
        assert_eq!(rs.loss, rt.loss, "threaded diverged at step {i}");
        assert_eq!(rs.loss, rp.loss, "lane split diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&thr), "params diverged at step {i}");
        assert_eq!(flat_params(&seq), flat_params(&split), "params diverged at step {i}");
    }
}

// ------------------------------------------------------------------
// (c) the Stage 4a/4b call contract

#[derive(Default)]
struct MockPreconditioner {
    /// (step, layer) per refresh call
    refreshes: Mutex<Vec<(u64, usize)>>,
    /// layer per direction call
    directions: Mutex<Vec<usize>>,
}

impl Preconditioner for MockPreconditioner {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn default_hparams(&self) -> HyperParams {
        flat_hp(0.05, 0.045)
    }

    fn init_layer(&self, _model: &ModelManifest, _li: usize) -> LayerStateBox {
        Box::new(())
    }

    fn stats_spec(&self, model: &ModelManifest, li: usize) -> Vec<StatKind> {
        if model.kfac_layers[li].is_bn() {
            vec![StatKind::BnF]
        } else {
            vec![StatKind::A]
        }
    }

    fn plan(
        &self,
        model: &ModelManifest,
        li: usize,
        _state: &mut LayerStateBox,
        _t: u64,
    ) -> Vec<StatKind> {
        self.stats_spec(model, li) // always due; default build_stat = zeros
    }

    fn refresh(
        &self,
        _engine: &dyn Executor,
        _model: &ModelManifest,
        li: usize,
        _state: &mut LayerStateBox,
        t: u64,
        items: Vec<(StatKind, Mat)>,
    ) -> anyhow::Result<()> {
        assert!(!items.is_empty(), "refresh must only fire with reduced stats");
        self.refreshes.lock().unwrap().push((t, li));
        Ok(())
    }

    fn direction(
        &self,
        _engine: &dyn Executor,
        _model: &ModelManifest,
        li: usize,
        _state: &LayerStateBox,
        grads: &[HostTensor],
        _weights: &[&HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        self.directions.lock().unwrap().push(li);
        Ok(grads.to_vec())
    }
}

#[test]
fn mock_preconditioner_call_contract_on_both_engines() {
    for dist in [DistMode::Sequential, DistMode::Threaded] {
        let mock = Arc::new(MockPreconditioner::default());
        let mut tr = builder("mlp", mock.clone(), 0.05, 0.045).dist(dist).build().unwrap();
        let nlayers = tr.layer_owners().len();
        let steps = 2u64;
        for _ in 0..steps {
            let rec = tr.step().unwrap();
            assert!(rec.loss.is_finite());
            // the mock's zero statistics still move bytes (plumbing live)
            assert!(rec.comm.stats_total() > 0, "{dist:?}");
        }
        // refresh: exactly once per layer per step, at the owner — a
        // non-owner calling refresh would double these counts
        let refreshes = mock.refreshes.lock().unwrap().clone();
        assert_eq!(refreshes.len(), nlayers * steps as usize, "{dist:?}");
        for t in 1..=steps {
            for li in 0..nlayers {
                let n = refreshes.iter().filter(|&&(rt, rl)| rt == t && rl == li).count();
                assert_eq!(n, 1, "refresh count for step {t} layer {li} ({dist:?})");
            }
        }
        // direction: exactly once per layer per step
        let directions = mock.directions.lock().unwrap().clone();
        assert_eq!(directions.len(), nlayers * steps as usize, "{dist:?}");
        for li in 0..nlayers {
            let n = directions.iter().filter(|&&dl| dl == li).count();
            assert_eq!(n, steps as usize, "direction count for layer {li} ({dist:?})");
        }
    }
}

// ------------------------------------------------------------------
// registry + harness hooks

#[test]
fn unknown_optimizer_name_is_hard_error_listing_choices() {
    let err = optim::by_name("adamw").unwrap_err().to_string();
    assert!(err.contains("unknown optimizer 'adamw'"), "{err}");
    for name in optim::OPTIMIZER_NAMES {
        assert!(err.contains(name), "choices must list {name}: {err}");
    }
}

/// Every registered optimizer trains the synth model in-process — the
/// coverage does not depend on the CI matrix (which additionally runs
/// the whole suite once per `SPNGD_OPTIM` to vary env-driven paths).
#[test]
fn every_registered_optimizer_smoke_trains() {
    for name in optim::OPTIMIZER_NAMES {
        let opt = optim::by_name(name).unwrap();
        let hp =
            HyperParams { p_decay: 2.0, e_start: 100.0, e_end: 200.0, ..opt.default_hparams() };
        let mut tr = TrainerBuilder::new("mlp")
            .optimizer(opt)
            .hyperparams(hp)
            .steps_per_epoch(50)
            .workers(2)
            .dataset_len(4000)
            .data_seed(42)
            .seed(7)
            .build()
            .unwrap();
        let first = tr.step().unwrap().loss;
        let mut last = first;
        for _ in 0..19 {
            let rec = tr.step().unwrap();
            assert!(rec.loss.is_finite(), "{name} diverged");
            last = rec.loss;
        }
        assert!(last < first, "{name} loss should drop: {first} -> {last}");
    }
}

/// The CI matrix runs this suite once per optimizer via `SPNGD_OPTIM`;
/// whichever is selected must train the synth model end to end.
#[test]
fn env_selected_optimizer_smoke_trains() {
    let opt = spngd::harness::env_optimizer().unwrap();
    let hp = HyperParams { p_decay: 2.0, e_start: 100.0, e_end: 200.0, ..opt.default_hparams() };
    let mut tr = TrainerBuilder::new("mlp")
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
        .build()
        .unwrap();
    let before = flat_params(&tr);
    let first = tr.step().unwrap().loss;
    let mut last = first;
    for _ in 0..24 {
        let rec = tr.step().unwrap();
        assert!(rec.loss.is_finite());
        last = rec.loss;
    }
    assert!(last < first, "loss should drop: {first} -> {last}");
    let after = flat_params(&tr);
    assert!(before.iter().zip(after.iter()).any(|(a, b)| a != b), "weights must move");
}
