//! Golden-value tests for the native backend against the L1 oracle
//! implementations in `python/compile/kernels/ref.py`.
//!
//! The constants below were produced by running the jnp oracles (f32) on
//! the inputs given in each test; the native kernels must reproduce them.
//! Regenerate with the corresponding `ref.syrk` / `ref.matmul` /
//! `ref.newton_schulz_inverse` / `ref.precondition` / `ref.bn_full_fisher`
//! / `ref.im2col` calls if the contract ever changes.

use spngd::linalg::Mat;
use spngd::runtime::native::kernels;
use spngd::runtime::{native, Executor, HostTensor};
use spngd::util::rng::Rng;

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert!((g - w).abs() <= tol, "{what}[{i}]: got {g}, want {w}");
    }
}

#[test]
fn syrk_matches_ref_golden() {
    // ref.syrk(X, 1/3) for X = [[1,2],[3,-1],[0.5,4]]
    let x = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, -1.0, 0.5, 4.0]);
    let got = kernels::syrk(&x, 1.0 / 3.0);
    let want = [3.41666675e0, 3.33333343e-1, 3.33333343e-1, 7.0];
    assert_close(&got.data, &want, 1e-5, "syrk");
}

#[test]
fn matmul_matches_ref_golden() {
    // ref.matmul(A, B), A = [[1,2,3],[4,5,6]], B = [[7,8],[9,10],[11,12]]
    let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let b = Mat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
    let got = a.matmul(&b);
    assert_close(&got.data, &[58.0, 64.0, 139.0, 154.0], 1e-6, "matmul");
}

#[test]
fn newton_schulz_inverse_matches_ref_golden() {
    // ref.newton_schulz_inverse(M, 0.1, iters=20, power_iters=8)
    let m = Mat::from_vec(
        4,
        4,
        vec![
            2.0, 0.5, 0.1, 0.0, //
            0.5, 1.5, 0.2, 0.1, //
            0.1, 0.2, 1.0, 0.3, //
            0.0, 0.1, 0.3, 0.8,
        ],
    );
    let got = kernels::ns_inverse(&m, 0.1, 20);
    let want = [
        5.15369415e-1,
        -1.59562767e-1,
        -2.49431487e-2,
        2.60435790e-2,
        -1.59562767e-1,
        6.89971387e-1,
        -9.90389660e-2,
        -4.36505042e-2,
        -2.49431469e-2,
        -9.90389511e-2,
        1.01900089e0,
        -3.28662604e-1,
        2.60435771e-2,
        -4.36505005e-2,
        -3.28662634e-1,
        1.22551537e0,
    ];
    assert_close(&got.data, &want, 1e-4, "ns_inverse");
}

#[test]
fn precondition_matches_ref_golden() {
    // ref.precondition(Ginv, grad, Ainv)
    let gi = Mat::from_vec(2, 2, vec![1.0, 0.2, 0.2, 0.5]);
    let gr = Mat::from_vec(2, 3, vec![1.0, -2.0, 3.0, 0.5, 0.0, -1.0]);
    let ai = Mat::from_vec(3, 3, vec![0.5, 0.1, 0.0, 0.1, 0.4, 0.1, 0.0, 0.1, 0.3]);
    let got = kernels::precondition(&gi, &gr, &ai);
    let want = [
        3.50000024e-1,
        -4.09999996e-1,
        6.40000045e-1,
        1.84999987e-1,
        -1.05000004e-1,
        -9.99999419e-3,
    ];
    assert_close(&got.data, &want, 1e-5, "precondition");
}

#[test]
fn bn_full_fisher_matches_ref_golden() {
    // ref.bn_full_fisher(gg, gb) for (B, C) = (3, 2)
    let gg = HostTensor::new(vec![3, 2], vec![1.0, 0.5, 2.0, -1.0, 0.0, 1.5]);
    let gb = HostTensor::new(vec![3, 2], vec![0.5, 1.0, 1.0, 0.0, -0.5, 2.0]);
    let got = kernels::bn_full_fisher(&gg, &gb);
    let want = [
        1.66666675e0,
        8.33333373e-1,
        -5.00000000e-1,
        3.33333343e-1,
        8.33333373e-1,
        5.00000000e-1,
        -5.00000000e-1,
        -1.66666672e-1,
        -5.00000000e-1,
        -5.00000000e-1,
        1.16666675e0,
        1.16666675e0,
        3.33333343e-1,
        -1.66666672e-1,
        1.16666675e0,
        1.66666675e0,
    ];
    assert_close(&got.data, &want, 1e-5, "bn_full_fisher");
}

#[test]
fn im2col_matches_ref_patch_layout() {
    // ref.im2col on x = arange(18).reshape(1,2,3,3), k=2, s=1, p=0:
    // rows are (oy, ox), columns are c-major then (kh, kw).
    let x = HostTensor::new(vec![1, 2, 3, 3], (0..18).map(|v| v as f32).collect());
    let (patches, ho, wo) = kernels::im2col(&x, 2, 1, 0);
    assert_eq!((ho, wo), (2, 2));
    assert_eq!(patches.rows, 4);
    assert_eq!(patches.cols, 8);
    let want = [
        0.0, 1.0, 3.0, 4.0, 9.0, 10.0, 12.0, 13.0, //
        1.0, 2.0, 4.0, 5.0, 10.0, 11.0, 13.0, 14.0, //
        3.0, 4.0, 6.0, 7.0, 12.0, 13.0, 15.0, 16.0, //
        4.0, 5.0, 7.0, 8.0, 13.0, 14.0, 16.0, 17.0,
    ];
    assert_close(&patches.data, &want, 0.0, "im2col");
}

/// Directional-derivative check of the native step executable's
/// gradients: loss(w + eps·d̂) − loss(w − eps·d̂) over 2·eps must match
/// ‖∇L‖ when d̂ = ∇L/‖∇L‖, per parameter tensor. Catches porting errors
/// in the conv/BN/residual backward without any external reference.
#[test]
fn step_gradients_match_directional_derivative() {
    let (manifest, backend) = native::build(&["convnet_tiny"], 3).unwrap();
    let model = manifest.model("convnet_tiny").unwrap();
    let params = manifest.load_init_params(model).unwrap();
    let mut rng = Rng::new(21);
    let n_in: usize = model.input_shape.iter().product();
    let x = HostTensor::new(
        model.input_shape.clone(),
        (0..n_in).map(|_| (rng.f32() * 2.0 - 1.0)).collect(),
    );
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch {
        t.data[b * model.num_classes + rng.below_usize(model.num_classes)] = 1.0;
    }

    let loss_of = |params: &[HostTensor]| -> f32 {
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&t);
        let outs = backend.execute(&model.step_emp, &inputs).unwrap();
        outs[0].data[0]
    };

    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&t);
    let outs = backend.execute(&model.step_emp, &inputs).unwrap();

    // check a conv weight, a bn gamma and the fc weight
    for pname in ["stem.conv.w", "stem.bn.gamma", "fc.w"] {
        let pi = model.param_index(pname).unwrap();
        let gi = model.output_index("grad", Some(pname)).unwrap();
        let grad = &outs[gi];
        let gnorm = grad.norm();
        assert!(gnorm > 1e-6, "{pname}: gradient vanished ({gnorm})");
        let eps = 1e-2f32;
        let mut plus = params.clone();
        let mut minus = params.clone();
        for i in 0..grad.data.len() {
            let d = grad.data[i] / gnorm;
            plus[pi].data[i] += eps * d;
            minus[pi].data[i] -= eps * d;
        }
        let fd = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
        let rel = (fd - gnorm).abs() / gnorm.max(1e-6);
        assert!(rel < 0.1, "{pname}: directional derivative {fd} vs ‖∇‖ {gnorm} (rel {rel})");
    }
}

/// One full trainer step on the synthetic corpus moves the weights, and a
/// short run reduces the loss (the satellite smoke test for the native
/// training path).
#[test]
fn trainer_smoke_on_synth_data() {
    use spngd::coordinator::TrainerBuilder;
    use spngd::optim;

    let mut tr = TrainerBuilder::new("convnet_tiny")
        .optimizer(optim::spngd())
        .workers(2)
        .dataset_len(2048)
        .data_seed(5)
        .build()
        .unwrap();
    let w0: Vec<f32> = tr.params.iter().flat_map(|p| p.data.clone()).collect();
    let first = tr.step().unwrap();
    let w1: Vec<f32> = tr.params.iter().flat_map(|p| p.data.clone()).collect();
    assert!(w0.iter().zip(w1.iter()).any(|(a, b)| a != b), "weights must move");
    let mut last = first.clone();
    for _ in 0..11 {
        last = tr.step().unwrap();
    }
    assert!(
        last.loss < first.loss,
        "loss should drop over 12 steps: {} -> {}",
        first.loss,
        last.loss
    );
}
