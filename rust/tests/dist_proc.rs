//! Integration tests for the multi-process transport (`dist::ProcComm`):
//!
//! - the healthy multi-process path must be bit-identical to the
//!   sequential coordinator AND the threaded engine, in both `f32` and
//!   `mixed` wire precision, with byte-identical `CommStats`;
//! - the actual framed wire bytes must match the closed-form counters
//!   in `collectives::wire`;
//! - every injected fault (kill, drop, delay, corrupt, mute) either
//!   recovers bit-identically via the membership state machine or fails
//!   loudly with a structured diagnostic — never hangs (the CI job runs
//!   this suite under a hard `timeout`).
//!
//! Worker processes are the test binary's sibling `spngd` executable
//! (`CARGO_BIN_EXE_spngd`), spawned over a fresh temp-dir Unix socket
//! per trainer, so tests are independent and parallel-safe.

use std::sync::Arc;

use spngd::collectives::comm::StatClass;
use spngd::collectives::{wire, Collective, Precision, SimComm};
use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::dist::{FaultPlan, MemberEvent, ProcCfg, ProcComm, RespawnPolicy};
use spngd::linalg::Mat;
use spngd::optim::{self, HyperParams, Preconditioner};
use spngd::util::obs;

/// Same run shape as `tests/dist_engine.rs` — W=1 sequential runs of
/// this builder are the ground truth the proc engine must reproduce.
fn base_builder(model: &str, opt: Arc<dyn Preconditioner>) -> TrainerBuilder {
    let hp = HyperParams {
        alpha_mixup: 0.0,
        p_decay: 2.0,
        e_start: 100.0,
        e_end: 200.0,
        eta0: 0.02,
        m0: 0.018,
        lambda: 2.5e-3,
    };
    TrainerBuilder::new(model)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(50)
        .workers(2)
        .dataset_len(4000)
        .data_seed(42)
        .seed(7)
}

/// Short-fuse transport knobs so fault tests finish in seconds, with a
/// generous join timeout (worker spawn under test parallelism is slow).
fn proc_cfg() -> ProcCfg {
    ProcCfg {
        worker_bin: Some(env!("CARGO_BIN_EXE_spngd").to_string()),
        heartbeat_ms: 25,
        join_timeout_ms: 20_000,
        backoff_base_ms: 10,
        ..ProcCfg::default()
    }
}

fn proc_builder(model: &str, cfg: ProcCfg) -> TrainerBuilder {
    base_builder(model, optim::spngd()).dist(DistMode::Proc).proc_cfg(cfg)
}

fn flat_params(tr: &Trainer) -> Vec<f32> {
    tr.params.iter().flat_map(|p| p.data.clone()).collect()
}

fn assert_step_parity(seq: &mut Trainer, proc: &mut Trainer, steps: usize, tag: &str) {
    for i in 0..steps {
        let rs = seq.step().unwrap();
        let rp = proc.step().unwrap();
        assert_eq!(rs.loss, rp.loss, "{tag}: loss diverged at step {i}");
        assert_eq!(rs.train_acc, rp.train_acc, "{tag}: acc diverged at step {i}");
        assert_eq!(rs.refreshed, rp.refreshed, "{tag}: plan diverged at step {i}");
        assert_eq!(rs.comm.rs_stats_a, rp.comm.rs_stats_a, "{tag}: step {i}");
        assert_eq!(rs.comm.rs_stats_g, rp.comm.rs_stats_g, "{tag}: step {i}");
        assert_eq!(rs.comm.ar_grads, rp.comm.ar_grads, "{tag}: step {i}");
        assert_eq!(rs.comm.ag_params, rp.comm.ag_params, "{tag}: step {i}");
        assert_eq!(rs.comm.num_ops, rp.comm.num_ops, "{tag}: step {i}");
        assert_eq!(flat_params(seq), flat_params(proc), "{tag}: params diverged at step {i}");
    }
}

fn dead_events(events: &[MemberEvent]) -> Vec<(u32, u64, String)> {
    events
        .iter()
        .filter_map(|e| match e {
            MemberEvent::Dead { rank, step, reason } => Some((*rank, *step, reason.clone())),
            _ => None,
        })
        .collect()
}

fn respawned_ranks(events: &[MemberEvent]) -> Vec<u32> {
    events
        .iter()
        .filter_map(|e| match e {
            MemberEvent::Respawned { rank, .. } => Some(*rank),
            _ => None,
        })
        .collect()
}

// ---------------------------------------------------------------- healthy

/// The tentpole differential: multi-process == sequential == threaded,
/// step by step, bitwise — losses, params and byte accounting.
#[test]
fn proc_engine_matches_sequential_and_threaded_bitwise_f32() {
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut thr = base_builder("mlp", optim::spngd()).dist(DistMode::Threaded).build().unwrap();
    let mut proc = proc_builder("mlp", proc_cfg()).build().unwrap();
    for i in 0..5 {
        let rs = seq.step().unwrap();
        let rt = thr.step().unwrap();
        let rp = proc.step().unwrap();
        assert_eq!(rs.loss, rp.loss, "seq vs proc loss diverged at step {i}");
        assert_eq!(rt.loss, rp.loss, "threaded vs proc loss diverged at step {i}");
        assert_eq!(rs.train_acc, rp.train_acc, "acc diverged at step {i}");
        assert_eq!(rs.refreshed, rp.refreshed, "plan diverged at step {i}");
        assert_eq!(rs.comm.rs_stats_a, rp.comm.rs_stats_a, "step {i}");
        assert_eq!(rs.comm.rs_stats_g, rp.comm.rs_stats_g, "step {i}");
        assert_eq!(rs.comm.ar_grads, rp.comm.ar_grads, "step {i}");
        assert_eq!(rs.comm.ag_params, rp.comm.ag_params, "step {i}");
        assert_eq!(flat_params(&seq), flat_params(&proc), "params diverged at step {i}");
        assert_eq!(flat_params(&thr), flat_params(&proc), "thr params diverged at step {i}");
    }
    let pc = proc.proc().unwrap();
    assert_eq!(pc.live(), 2, "healthy run keeps full membership");
    let events = pc.take_events();
    assert!(dead_events(&events).is_empty(), "healthy run saw deaths: {events:?}");
}

/// Same differential under f16 wire precision: the worker decodes real
/// f16 payload bytes, which IS the wire quantization SimComm applies.
#[test]
fn proc_engine_matches_sequential_bitwise_mixed() {
    let mut seq =
        base_builder("mlp", optim::spngd()).precision(Precision::Mixed).build().unwrap();
    let mut proc =
        proc_builder("mlp", proc_cfg()).precision(Precision::Mixed).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 4, "mixed");
    assert!(dead_events(&proc.proc().unwrap().take_events()).is_empty());
}

/// The membership state machine walks WaitingForMembers → Warmup →
/// (RoundStart → RoundEnd)* and admits exactly `world` workers.
#[test]
fn proc_membership_state_machine_sequence() {
    let mut proc = proc_builder("mlp", proc_cfg()).build().unwrap();
    for _ in 0..2 {
        proc.step().unwrap();
    }
    let events = proc.proc().unwrap().take_events();
    let states: Vec<&str> = events
        .iter()
        .filter_map(|e| match e {
            MemberEvent::State { state, .. } => Some(*state),
            _ => None,
        })
        .collect();
    let joined = events
        .iter()
        .filter(|e| matches!(e, MemberEvent::Joined { .. }))
        .count();
    assert_eq!(joined, 2, "two workers admitted: {events:?}");
    assert_eq!(states.first(), Some(&"WaitingForMembers"), "{states:?}");
    assert!(states.contains(&"Warmup"), "{states:?}");
    let starts = states.iter().filter(|s| **s == "RoundStart").count();
    let ends = states.iter().filter(|s| **s == "RoundEnd").count();
    assert_eq!((starts, ends), (2, 2), "{states:?}");
}

// ------------------------------------------------- wire-byte accounting

/// Drive ProcComm directly as a `Collective` against SimComm on the same
/// buffers: results bitwise equal, modeled `CommStats` byte-identical,
/// and the actual framed wire bytes equal to the closed-form counters.
#[test]
fn proc_collective_matches_simcomm_and_closed_form_wire_bytes() {
    for p in [Precision::F32, Precision::Mixed] {
        let proc = ProcComm::launch(2, p, &proc_cfg()).unwrap();
        let mut sim = SimComm::new(2);
        sim.precision = p;

        proc.round_start(1).unwrap();
        // AllReduce: 4 lanes × 10 elems — splits into [5, 5] over 2 workers
        let mk_lanes = || -> Vec<Vec<f32>> {
            (0..4usize)
                .map(|l| (0..10).map(|i| (i as f32 * 0.37 - 1.3) * (l as f32 + 0.5)).collect())
                .collect()
        };
        let mut a = mk_lanes();
        let mut b = mk_lanes();
        proc.all_reduce_mean(&mut a);
        sim.all_reduce_mean(&mut b);
        assert_eq!(a, b, "{p:?}: AllReduce mean diverged from SimComm");

        // ReduceScatterV: one square (symmetry-packed) + one rectangular
        let mk_items = || -> Vec<Vec<Mat>> {
            (0..4usize)
                .map(|l| {
                    let sq = Mat::from_vec(
                        8,
                        8,
                        (0..64).map(|i| (i as f32 - 30.0) * 0.011 * (l as f32 + 1.0)).collect(),
                    );
                    let rect = Mat::from_vec(
                        4,
                        1,
                        (0..4).map(|i| i as f32 * 0.2 + l as f32).collect(),
                    );
                    vec![sq, rect]
                })
                .collect()
        };
        let classes = [StatClass::A, StatClass::GorF];
        let ra = proc.reduce_scatter_v(&mk_items(), &classes);
        let rb = sim.reduce_scatter_v(&mk_items(), &classes);
        for (i, (ma, mb)) in ra.iter().zip(rb.iter()).enumerate() {
            assert_eq!(ma.data, mb.data, "{p:?}: stat {i} diverged from SimComm");
        }
        proc.all_gather_v_params(100);
        sim.all_gather_v_params(100);
        proc.round_end(1).unwrap();

        // modeled accounting is byte-identical to SimComm
        let (sp, ss) = (proc.stats(), sim.stats());
        assert_eq!(sp.ar_grads, ss.ar_grads, "{p:?}");
        assert_eq!(sp.rs_stats_a, ss.rs_stats_a, "{p:?}");
        assert_eq!(sp.rs_stats_g, ss.rs_stats_g, "{p:?}");
        assert_eq!(sp.ag_params, ss.ag_params, "{p:?}");
        assert_eq!(sp.num_ops, ss.num_ops, "{p:?}");

        // actual framed bytes match the closed-form counters
        let e = p.wire_elem_bytes();
        let segs: Vec<usize> =
            wire::split_segments(10, 2).iter().map(|&(_, len)| len).collect();
        assert_eq!(segs, vec![5, 5]);
        let w = proc.wire_stats();
        assert_eq!(w.grad_tx, wire::grad_round_tx_bytes(&segs, 4, e), "{p:?}");
        assert_eq!(w.grad_rx, wire::grad_round_rx_bytes(&segs, e), "{p:?}");
        let stat_tx =
            wire::stat_item_tx_bytes(8, 8, 4, e) + wire::stat_item_tx_bytes(4, 1, 4, e);
        let stat_rx = wire::stat_item_rx_bytes(8, 8) + wire::stat_item_rx_bytes(4, 1);
        assert_eq!(w.stat_tx, stat_tx, "{p:?}");
        assert_eq!(w.stat_rx, stat_rx, "{p:?}");
        assert_eq!(w.data_frames, 8, "{p:?}: 2 grad jobs + 2 segs + 2 stat jobs + 2 results");
    }
}

// ------------------------------------------------------ fault injection

/// A worker killed mid-step is detected, its jobs re-queued to the
/// survivor (bit-identically), and a replacement is re-admitted at the
/// round boundary — the acceptance-criteria scenario.
#[test]
fn kill_mid_step_recovers_bitwise_and_respawns() {
    let mut cfg = proc_cfg();
    cfg.fault_plan = FaultPlan::parse("kill:2:1").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 4, "kill");
    let pc = proc.proc().unwrap();
    let events = pc.take_events();
    let dead = dead_events(&events);
    assert_eq!(dead.len(), 1, "exactly one death: {events:?}");
    assert_eq!((dead[0].0, dead[0].1), (1, 2), "rank 1 died at step 2: {}", dead[0].2);
    assert_eq!(respawned_ranks(&events), vec![1], "{events:?}");
    assert_eq!(pc.live(), 2, "replacement re-admitted at the round boundary");
}

/// Under the shrink policy the run continues on the survivors — still
/// bit-identical, because lane math never depended on the worker count.
#[test]
fn shrink_policy_continues_bitwise_on_survivors() {
    let mut cfg = proc_cfg();
    cfg.respawn = RespawnPolicy::Shrink;
    cfg.fault_plan = FaultPlan::parse("kill:1:0").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 3, "shrink");
    let pc = proc.proc().unwrap();
    let events = pc.take_events();
    assert_eq!(dead_events(&events).len(), 1, "{events:?}");
    assert!(respawned_ranks(&events).is_empty(), "shrink never respawns: {events:?}");
    assert_eq!(pc.live(), 1);
}

/// Strict policy: any death is fatal at the round boundary — the step
/// fails loudly with a structured diagnostic instead of hanging.
#[test]
fn strict_policy_fails_loudly_on_death() {
    let mut cfg = proc_cfg();
    cfg.respawn = RespawnPolicy::Strict;
    cfg.fault_plan = FaultPlan::parse("kill:2:0").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 1, "strict");
    let err = proc.step().unwrap_err().to_string();
    assert!(err.contains("proc transport fatal"), "unstructured error: {err}");
}

/// Respawn budget of zero behaves like strict-after-recovery: the death
/// itself is survived bitwise, then the exhausted budget is fatal.
#[test]
fn respawn_budget_exhaustion_is_fatal() {
    let mut cfg = proc_cfg();
    cfg.respawn = RespawnPolicy::Respawn { max: 0 };
    cfg.fault_plan = FaultPlan::parse("kill:1:1").unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    let err = proc.step().unwrap_err().to_string();
    assert!(err.contains("proc transport fatal"), "{err}");
    assert!(err.contains("exhausted"), "should name the exhausted budget: {err}");
}

/// A dropped reply (process alive, heartbeats flowing, job never
/// answered) is caught by the job timeout, not the heartbeat timeout.
#[test]
fn drop_fault_hits_job_timeout_and_recovers_bitwise() {
    let mut cfg = proc_cfg();
    cfg.job_timeout_ms = 1500;
    cfg.fault_plan = FaultPlan::parse("drop:1:1").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 3, "drop");
    let events = proc.proc().unwrap().take_events();
    let dead = dead_events(&events);
    assert_eq!(dead.len(), 1, "{events:?}");
    assert!(dead[0].2.contains("job timeout"), "wrong diagnostic: {}", dead[0].2);
    assert_eq!(respawned_ranks(&events), vec![1], "{events:?}");
}

/// A delayed reply inside the job timeout is tolerated: no deaths, no
/// divergence — latency is not failure.
#[test]
fn delay_fault_inside_timeout_is_tolerated() {
    let mut cfg = proc_cfg();
    cfg.fault_plan = FaultPlan::parse("delay:1:0:300").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 2, "delay");
    let events = proc.proc().unwrap().take_events();
    assert!(dead_events(&events).is_empty(), "delay must not kill: {events:?}");
}

/// A corrupted frame breaks the payload checksum; the connection is
/// dropped with the checksum diagnostic and the job re-queued.
#[test]
fn corrupt_fault_is_detected_by_checksum() {
    let mut cfg = proc_cfg();
    cfg.fault_plan = FaultPlan::parse("corrupt:1:0").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 3, "corrupt");
    let events = proc.proc().unwrap().take_events();
    let dead = dead_events(&events);
    assert_eq!(dead.len(), 1, "{events:?}");
    assert!(dead[0].2.contains("checksum"), "wrong diagnostic: {}", dead[0].2);
    assert_eq!(respawned_ranks(&events), vec![0], "{events:?}");
}

/// The JSONL event stream is the machine-readable source of truth for
/// membership transitions: with a sink configured, a killed worker must
/// appear as a parseable `dead` record followed (in `seq` order) by a
/// `respawned` record for the same rank, and the armed fault plan must
/// be on the stream. The sink is process-global, so concurrent tests in
/// this binary may interleave their own records — every assertion here
/// filters on this test's unique (step=3, rank=1) fault coordinates.
#[test]
fn kill_fault_streams_dead_then_respawned_jsonl() {
    let path = std::env::temp_dir()
        .join(format!("spngd_dist_events_{}.jsonl", std::process::id()));
    obs::set_events_path(&path).unwrap();
    let mut cfg = proc_cfg();
    cfg.fault_plan = FaultPlan::parse("kill:3:1").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 5, "jsonl-kill");
    obs::close_events();

    // every non-empty line must parse under the stable schema
    let text = std::fs::read_to_string(&path).unwrap();
    let recs: Vec<_> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| obs::parse_line(l).unwrap_or_else(|| panic!("unparseable event line: {l}")))
        .collect();
    assert!(!recs.is_empty(), "the run streamed no events");

    let plan = recs
        .iter()
        .find(|r| r.kind == "fault_plan" && r.get("plan").as_str() == Some("kill:3:1"))
        .expect("armed fault plan must be announced on the stream");
    assert_eq!(plan.get("world").as_usize(), Some(2));
    let dead = recs
        .iter()
        .find(|r| {
            r.kind == "dead"
                && r.get("rank").as_usize() == Some(1)
                && r.get("step").as_usize() == Some(3)
        })
        .expect("kill:3:1 must stream a dead record for rank 1 at step 3");
    assert!(dead.get("reason").as_str().is_some(), "dead records carry a diagnostic");
    let respawned = recs
        .iter()
        .find(|r| r.kind == "respawned" && r.get("rank").as_usize() == Some(1) && r.seq > dead.seq)
        .expect("the replacement must stream a respawned record after the death");
    assert!(respawned.get("attempt").as_usize().is_some());
    assert!(
        recs.iter().any(|r| r.kind == "state" && r.get("state").as_str().is_some()),
        "membership state transitions belong on the stream"
    );
    let _ = std::fs::remove_file(&path);
}

/// A muted worker (alive but silent — no heartbeats, no replies) is
/// caught by the heartbeat timeout.
#[test]
fn mute_fault_hits_heartbeat_timeout() {
    let mut cfg = proc_cfg();
    cfg.heartbeat_timeout_ms = 600;
    cfg.fault_plan = FaultPlan::parse("mute:1:0").unwrap();
    let mut seq = base_builder("mlp", optim::spngd()).build().unwrap();
    let mut proc = proc_builder("mlp", cfg).build().unwrap();
    assert_step_parity(&mut seq, &mut proc, 2, "mute");
    let events = proc.proc().unwrap().take_events();
    let dead = dead_events(&events);
    assert_eq!(dead.len(), 1, "{events:?}");
    assert!(dead[0].2.contains("heartbeat timeout"), "wrong diagnostic: {}", dead[0].2);
}
