//! End-to-end driver (DESIGN.md §7): trains the ResNet-style ConvNet on
//! the synthetic corpus with (a) the SGD baseline and (b) SP-NGD with all
//! practical techniques (emp + unitBN + stale), through the full stack:
//!
//!   rust data pipeline (mixup/erasing) → per-worker HLO fwd/bwd →
//!   ReduceScatterV(statistics) → model-parallel Newton-Schulz inversion →
//!   preconditioned update → AllGatherV
//!
//! Logs the loss curve per step, evaluates each epoch, writes CSVs under
//! results/, and reports the paper's headline comparison: steps for
//! SP-NGD to reach the target accuracy vs SGD.
//!
//!     cargo run --release --example train_e2e [steps] [target_acc]

use std::sync::Arc;

use anyhow::Result;
use spngd::coordinator::Trainer;
use spngd::data::AugmentCfg;
use spngd::harness;
use spngd::optim::{self, Preconditioner, SpNgd};
use spngd::util::stats::{fmt_bytes, fmt_duration};

struct Outcome {
    name: &'static str,
    steps_to_target: Option<u64>,
    final_val_acc: f32,
    final_val_loss: f32,
    mean_step: f64,
    comm_reduction: f64,
}

fn run(
    name: &'static str,
    optimizer: Arc<dyn Preconditioner>,
    steps: usize,
    target_acc: f32,
    csv: &str,
) -> Result<Outcome> {
    // steps-per-epoch for the schedule: corpus 8192 / eff-batch 64 = 128
    let dataset_len = 8192;
    let model = harness::env_model("convnet_small")?;
    let mut trainer: Trainer = harness::builder(&model, optimizer)?
        .workers(2)
        .augment(AugmentCfg {
            alpha_mixup: 0.2,
            erase_p: 0.25,
            ..AugmentCfg::default()
        })
        .dataset_len(dataset_len)
        .data_seed(7)
        .build()?;
    let steps_per_epoch =
        dataset_len / (trainer.cfg.workers * trainer.cfg.grad_accum * 32);

    println!("=== {name} ===");
    let mut steps_to_target = None;
    let mut val = (f32::NAN, 0.0f32);
    for i in 1..=steps {
        let rec = trainer.step()?;
        // fine-grained probe for the steps-to-target headline
        if steps_to_target.is_none() && i % 8 == 0 {
            let (_, acc) = trainer.evaluate(4)?;
            if acc >= target_acc {
                steps_to_target = Some(i as u64);
            }
        }
        if i % steps_per_epoch == 0 {
            // validation after each epoch, as in the paper's runs
            val = trainer.evaluate(8)?;
            println!(
                "epoch {:2} (step {:4})  train loss {:.4} acc {:.3} | val loss {:.4} acc {:.3} | {}/step",
                i / steps_per_epoch,
                i,
                rec.loss,
                rec.train_acc,
                val.0,
                val.1,
                fmt_duration(rec.times.t_total),
            );
        } else if i <= 3 {
            println!("step {:4}  loss {:.4}  acc {:.3}", i, rec.loss, rec.train_acc);
        }
    }
    if val.0.is_nan() {
        val = trainer.evaluate(8)?;
    }
    trainer.log.write_csv(csv)?;
    println!(
        "{name}: total stats comm {}, wrote {csv}",
        fmt_bytes(trainer.log.total_stats_bytes() as f64)
    );
    Ok(Outcome {
        name,
        steps_to_target,
        final_val_acc: val.1,
        final_val_loss: val.0,
        mean_step: trainer.log.mean_step_time(3),
        comm_reduction: trainer.comm_reduction(),
    })
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(384);
    let target: f32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.80);

    std::fs::create_dir_all("results")?;
    let sgd = run("SGD baseline", optim::sgd(), steps, target, "results/e2e_sgd.csv")?;
    let ngd = run(
        "SP-NGD (emp+unitBN+stale)",
        Arc::new(SpNgd { stale: true, ..SpNgd::default() }),
        steps,
        target,
        "results/e2e_spngd.csv",
    )?;

    println!("\n=== headline comparison (paper §7.2: NGD converges in ~half the steps) ===");
    for o in [&sgd, &ngd] {
        println!(
            "{:<28} steps-to-{:.0}%-val-acc: {:>6}   final val acc {:.3} (loss {:.4})   mean step {}   stats-comm kept {:.1}%",
            o.name,
            target * 100.0,
            o.steps_to_target.map(|s| s.to_string()).unwrap_or("n/a".into()),
            o.final_val_acc,
            o.final_val_loss,
            fmt_duration(o.mean_step),
            o.comm_reduction * 100.0,
        );
    }
    if let (Some(a), Some(b)) = (ngd.steps_to_target, sgd.steps_to_target) {
        println!(
            "SP-NGD reached the target in {:.2}x the steps of SGD (paper: ~0.5x on ImageNet)",
            a as f64 / b as f64
        );
    }
    Ok(())
}
