//! Extreme-large-batch sweep (Tables 1-2 analog): trains SP-NGD at
//! growing effective batch sizes — mimicked with gradient/statistics
//! accumulation exactly as the paper did for BS=65K/131K (§7.1) — and
//! reports steps-to-target, final accuracy, and the stale-statistics
//! communication reduction per batch size.
//!
//!     cargo run --release --example large_batch [steps_budget]

use std::sync::Arc;

use anyhow::Result;
use spngd::harness;
use spngd::optim::{Preconditioner, SpNgd};
use spngd::util::stats::fmt_duration;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let budget: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(160);
    let target_acc = 0.75f32;

    // (workers, accum) — effective batch = workers * accum * 32
    let settings = [(2usize, 1usize), (2, 2), (2, 4), (2, 8)];
    println!(
        "{:>6} {:>8} {:>8} {:>10} {:>10} {:>12} {:>10}",
        "BS", "workers", "accum", "steps@tgt", "final acc", "mean step", "comm kept"
    );
    for (workers, accum) in settings {
        let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.1, ..SpNgd::default() });
        // LR scaling with batch size (the paper tunes η₀ per Table 2 row;
        // we use sqrt scaling from the optimizer's base)
        let scale = (accum as f64).sqrt();
        let mut hp = opt.default_hparams();
        hp.eta0 *= scale;
        hp.m0 *= scale;
        let eff_bs = workers * accum * 32;
        // same #samples budget for every BS: fewer steps at bigger BS
        let steps = budget / accum;

        let mut tr = harness::builder("mlp", opt)?
            .hyperparams(hp)
            .workers(workers)
            .grad_accum(accum)
            .dataset_len(8192)
            .data_seed(11)
            .build()?;
        let mut steps_to_target = None;
        for i in 1..=steps {
            tr.step()?;
            if steps_to_target.is_none() && i % 5 == 0 {
                let (_, acc) = tr.evaluate(4)?;
                if acc >= target_acc {
                    steps_to_target = Some(i);
                }
            }
        }
        let (_, final_acc) = tr.evaluate(16)?;
        println!(
            "{:>6} {:>8} {:>8} {:>10} {:>10.3} {:>12} {:>9.1}%",
            eff_bs,
            workers,
            accum,
            steps_to_target.map(|s| s.to_string()).unwrap_or("n/a".into()),
            final_acc,
            fmt_duration(tr.log.mean_step_time(2)),
            tr.comm_reduction() * 100.0
        );
    }
    println!(
        "\npaper shape: accuracy holds as BS grows while steps-to-target shrinks\n\
         (Table 1: 10,948 steps @ 4K -> 873 steps @ 131K, accuracy 74.8-75.6%)"
    );
    Ok(())
}
