//! Quickstart: train a small MLP with SP-NGD on the synthetic corpus.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Runs end-to-end on the native CPU backend — no artifacts needed.
//! Demonstrates the minimal public API: compose a trainer with the
//! builder, step it, evaluate. (`SPNGD_BACKEND=pjrt` switches to the
//! PJRT engine when built with `--features pjrt`; `--optim`-style
//! swaps are one `optim::by_name` call away.)

use std::sync::Arc;

use anyhow::Result;
use spngd::harness;
use spngd::optim::SpNgd;

fn main() -> Result<()> {
    // SP-NGD with every practical technique on: empirical Fisher,
    // unit-wise BN (no BN in the MLP, but the mode is set), stale stats.
    // Small-batch statistics fluctuate (the paper's own observation,
    // §4.3) so the quickstart uses a looser similarity threshold +
    // accumulation.
    let opt = Arc::new(SpNgd { stale: true, stale_alpha: 0.3, ..SpNgd::default() });
    let model = harness::env_model("mlp")?;
    let mut trainer = harness::builder(&model, opt)?
        .workers(2)
        .grad_accum(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()?;
    println!("SP-NGD quickstart: {model} on the synthetic corpus");
    for i in 1..=60 {
        let rec = trainer.step()?;
        if i % 10 == 0 || i <= 2 {
            println!(
                "step {:3}  loss {:.4}  train acc {:.3}  refreshed {}/{} stats",
                rec.step, rec.loss, rec.train_acc, rec.refreshed, rec.total_stats
            );
        }
    }
    let (val_loss, val_acc) = trainer.evaluate(16)?;
    println!("validation: loss {val_loss:.4}, accuracy {val_acc:.3}");
    println!(
        "statistics comm reduced to {:.1}% of always-refresh (stale scheduler)",
        trainer.comm_reduction() * 100.0
    );
    Ok(())
}
