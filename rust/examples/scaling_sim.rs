//! Scaling simulation (Fig. 5): measure a real coordinator profile on
//! this machine, then sweep the α-β cluster model over 1→1024 simulated
//! GPUs for every technique combination the paper plots.
//!
//!     cargo run --release --example scaling_sim

use std::sync::Arc;

use anyhow::Result;
use spngd::collectives::cost::ClusterModel;
use spngd::harness;
use spngd::optim::{Fisher, SpNgd};
use spngd::simulator;

fn main() -> Result<()> {
    let model = harness::env_model("convnet_small")?;
    // --- measure the emp+unitBN base profile on real steps
    let mut tr = harness::builder(&model, Arc::new(SpNgd::default()))?
        .workers(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()?;
    for _ in 0..4 {
        tr.step()?;
    }
    let base = tr.profile();

    // --- measure the 1mc extra-backward delta on real steps
    let opt1 = Arc::new(SpNgd { fisher: Fisher::OneMc, ..SpNgd::default() });
    let mut tr1 = harness::builder(&model, opt1)?
        .workers(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()?;
    for _ in 0..4 {
        tr1.step()?;
    }
    let base1 = tr1.profile();
    let extra_bwd =
        ((base1.t_forward + base1.t_backward) - (base.t_forward + base.t_backward)).max(0.0);

    // --- measure the stale refresh fraction on a longer stale run
    let opt_s = Arc::new(SpNgd { stale: true, ..SpNgd::default() });
    let mut tr_s = harness::builder(&model, opt_s)?
        .workers(2)
        .grad_accum(2)
        .dataset_len(4096)
        .data_seed(7)
        .build()?;
    for _ in 0..20 {
        tr_s.step()?;
    }
    let stale_fraction = tr_s.comm_reduction();

    // fullBN deltas: analytic from the model's BN channel sizes
    // (construction+inversion of (2C)² matrices vs 2×2 blocks)
    let deltas = simulator::TechniqueDeltas {
        t_extra_bwd_1mc: extra_bwd,
        t_full_bn_extra: base.t_inverse * 0.5,
        full_bn_extra_bytes: base.stats_bytes * 0.25,
        stale_fraction,
    };
    println!(
        "measured profile: fwd+bwd {:.1}ms, factors {:.1}ms, inverse {:.1}ms, stats {:.1} KiB, 1mc extra bwd {:.1}ms, stale fraction {:.1}%",
        (base.t_forward + base.t_backward) * 1e3,
        base.t_factors * 1e3,
        base.t_inverse * 1e3,
        base.stats_bytes / 1024.0,
        extra_bwd * 1e3,
        stale_fraction * 100.0
    );

    let variants: Vec<simulator::Variant> = simulator::fig5_techniques()
        .iter()
        .map(|&t| simulator::derive(&base, &deltas, t))
        .collect();
    let gpus = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
    let cm = ClusterModel::default();
    let rows = simulator::sweep(&variants, &gpus, &cm);

    println!("\nFig. 5 reproduction — time/step (ms) vs #GPUs (32 images/GPU):");
    print!("{:>20}", "technique");
    for g in &gpus {
        print!("{g:>8}");
    }
    println!();
    for row in &rows {
        print!("{:>20}", row.label);
        for (_, t) in &row.points {
            print!("{:>8.1}", t * 1e3);
        }
        println!();
    }

    // the paper's qualitative claims, checked numerically:
    let best = rows.last().unwrap(); // emp+unitBN+stale
    let t1 = best.points[0].1;
    let t64 = best.points.iter().find(|&&(g, _)| g == 64).unwrap().1;
    let t128 = best.points.iter().find(|&&(g, _)| g == 128).unwrap().1;
    let t1024 = best.points.iter().find(|&&(g, _)| g == 1024).unwrap().1;
    println!("\nshape checks:");
    println!("  superlinear region: t(1)/t(64) = {:.2}x (paper: ~3-4x)", t1 / t64);
    println!(
        "  near-ideal region: t(1024)/t(128) = {:.2}x (paper: ~1, 'almost ideal')",
        t1024 / t128
    );

    std::fs::create_dir_all("results")?;
    let mut w = spngd::util::log::TableWriter::create(
        "results/fig5.csv",
        &["variant", "gpus", "time_s"],
    )?;
    for (vi, row) in rows.iter().enumerate() {
        for (g, t) in &row.points {
            w.row(&[vi as f64, *g as f64, *t])?;
        }
    }
    println!("wrote results/fig5.csv");
    Ok(())
}
