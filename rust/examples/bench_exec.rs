//! Micro-bench of individual executables through the active backend
//! (native CPU by default; `SPNGD_BACKEND=pjrt` for the PJRT engine).
use anyhow::Result;
use spngd::harness::{self, bench};
use spngd::runtime::{Executor, HostTensor};
use spngd::util::rng::Rng;

fn main() -> Result<()> {
    let (manifest, engine) = harness::load_runtime()?;
    let model = manifest.model(&harness::env_model("convnet_small")?)?;
    let params = manifest.load_init_params(model)?;
    let mut rng = Rng::new(1);
    let n_in: usize = model.input_shape.iter().product();
    let x = HostTensor::new(model.input_shape.clone(), (0..n_in).map(|_| rng.f32()).collect());
    let mut t = HostTensor::zeros(vec![model.batch, model.num_classes]);
    for b in 0..model.batch { t.data[b*10] = 1.0; }
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&x); inputs.push(&t);
    bench("step_emp (convnet_small)", 2, 8, || {
        engine.execute(&model.step_emp, &inputs).unwrap();
    });
    // factor exe on the stem conv layer
    let l = model.kfac_layers.iter().find(|l| l.kind == "conv").unwrap();
    let tap = HostTensor::new(vec![model.batch, 3, 16, 16], (0..model.batch*3*256).map(|_| rng.f32()).collect());
    bench(&format!("factor_a ({})", l.factor_a), 2, 8, || {
        engine.execute(&l.factor_a, &[&tap]).unwrap();
    });
    // largest invert bucket
    let name = manifest.executables.keys().filter(|k| k.starts_with("invert_"))
        .max_by_key(|k| k.trim_start_matches("invert_").parse::<usize>().unwrap()).unwrap().clone();
    let n: usize = name.trim_start_matches("invert_").parse().unwrap();
    let mm = HostTensor::new(vec![n,n], (0..n*n).map(|_| rng.f32()*0.01).collect());
    let mut spd = mm.as_mat().transpose().matmul(&mm.as_mat()); spd.add_diag(1.0);
    let mt = HostTensor::from_mat(&spd); let damp = HostTensor::scalar(0.05);
    bench(&format!("{name}"), 2, 8, || {
        engine.execute(&name, &[&mt, &damp]).unwrap();
    });
    let fc = model.kfac_layers.iter().find(|l| l.kind == "fc").unwrap();
    let (m2, n2) = fc.grad_shape;
    let ginv = HostTensor::zeros(vec![m2,m2]); let grad = HostTensor::zeros(vec![m2,n2]); let ainv = HostTensor::zeros(vec![n2,n2]);
    bench(&format!("precond {}x{}", m2, n2), 2, 8, || {
        engine.execute(&fc.precond, &[&ginv, &grad, &ainv]).unwrap();
    });
    // eval exe
    let mut ev_inputs: Vec<&HostTensor> = params.iter().collect();
    ev_inputs.push(&x); ev_inputs.push(&t);
    let bn: Vec<HostTensor> = model.bn_order.iter().map(|nm| {
        let c = model.layer(nm).unwrap().channels; HostTensor::zeros(vec![c])
    }).collect();
    let bnv: Vec<HostTensor> = model.bn_order.iter().map(|nm| {
        let c = model.layer(nm).unwrap().channels; HostTensor::new(vec![c], vec![1.0;c])
    }).collect();
    for b in &bn { ev_inputs.push(b); }
    for v in &bnv { ev_inputs.push(v); }
    bench("eval (convnet_small)", 2, 8, || {
        engine.execute(&model.eval_exe, &ev_inputs).unwrap();
    });
    Ok(())
}
