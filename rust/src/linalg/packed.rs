//! Symmetry-aware packing (§5.2 of the paper).
//!
//! The statistics matrices A, G, F_unitBN are symmetric; to communicate an
//! N×N symmetric matrix only the upper triangle with N(N+1)/2 elements is
//! sent. These helpers convert between dense row-major and packed
//! row-major-upper-triangular layouts and are used by the collectives.

use super::Mat;

/// Number of packed elements for an n×n symmetric matrix.
#[inline]
pub const fn packed_len(n: usize) -> usize {
    n * (n + 1) / 2
}

/// Pack the upper triangle (row-major: row i contributes cols i..n).
pub fn pack_upper(m: &Mat) -> Vec<f32> {
    assert!(m.is_square(), "pack_upper requires square");
    let n = m.rows;
    let mut out = Vec::with_capacity(packed_len(n));
    for i in 0..n {
        out.extend_from_slice(&m.data[i * n + i..(i + 1) * n]);
    }
    out
}

/// Unpack into a dense symmetric matrix.
pub fn unpack_upper(packed: &[f32], n: usize) -> Mat {
    assert_eq!(packed.len(), packed_len(n), "packed length mismatch");
    let mut m = Mat::zeros(n, n);
    let mut k = 0;
    for i in 0..n {
        for j in i..n {
            m.data[i * n + j] = packed[k];
            m.data[j * n + i] = packed[k];
            k += 1;
        }
    }
    m
}

/// Bytes saved by packing an n×n f32 symmetric matrix (for comm accounting).
pub fn packed_savings_bytes(n: usize) -> usize {
    (n * n - packed_len(n)) * std::mem::size_of::<f32>()
}

/// Copy the column panel [j0, j1) of rows [t0, t1) of a row-major slice
/// with leading dimension `c` into a dense (t1−t0) × (j1−j0) panel,
/// reusing `out`'s allocation. The SYRK tile loop packs the active
/// j-tile once per row block so its inner axpy streams a contiguous,
/// cache-resident operand instead of striding by the full factor width.
pub fn pack_panel(
    x: &[f32],
    c: usize,
    t0: usize,
    t1: usize,
    j0: usize,
    j1: usize,
    out: &mut Vec<f32>,
) {
    debug_assert!(j1 <= c && t1 * c <= x.len());
    out.clear();
    out.reserve((t1 - t0) * (j1 - j0));
    for t in t0..t1 {
        out.extend_from_slice(&x[t * c + j0..t * c + j1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gen};
    use crate::util::rng::Rng;

    #[test]
    fn pack_len() {
        assert_eq!(packed_len(1), 1);
        assert_eq!(packed_len(4), 10);
        assert_eq!(packed_len(64), 2080);
    }

    #[test]
    fn roundtrip_small() {
        let m = unpack_upper(&[1., 2., 3., 4., 5., 6.], 3);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(2, 0), 3.0);
        assert_eq!(m.at(1, 1), 4.0);
        let p = pack_upper(&m);
        assert_eq!(p, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn prop_roundtrip_symmetric() {
        prop::check(
            42,
            50,
            32,
            |rng: &mut Rng, size| {
                let n = size.max(1);
                let d = gen::spd(rng, n, 0.1);
                Mat::from_vec(n, n, d.iter().map(|x| *x as f32).collect())
            },
            |m| {
                let p = pack_upper(m);
                let m2 = unpack_upper(&p, m.rows);
                m.max_abs_diff(&m2) == 0.0
            },
        );
    }

    #[test]
    fn savings_grow_quadratically() {
        assert_eq!(packed_savings_bytes(1), 0);
        assert!(packed_savings_bytes(256) > packed_savings_bytes(128) * 3);
    }

    #[test]
    fn pack_panel_extracts_tile() {
        // 3 rows × 4 cols, values encode (row, col) as 10·t + j
        let x: Vec<f32> = (0..12).map(|i| (10 * (i / 4) + i % 4) as f32).collect();
        let mut panel = vec![99.0; 3]; // stale contents must be dropped
        pack_panel(&x, 4, 1, 3, 1, 3, &mut panel);
        assert_eq!(panel, vec![11., 12., 21., 22.]);
        pack_panel(&x, 4, 0, 1, 0, 4, &mut panel);
        assert_eq!(panel, vec![0., 1., 2., 3.]);
    }
}
