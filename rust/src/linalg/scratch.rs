//! Scratch-buffer arena: recycles the f32 buffers of the per-step hot
//! loop (matmul outputs, im2col patch matrices, backward flow tensors) so
//! the native backend stops allocating fresh `Vec`s per layer per step.
//!
//! The arena is a plain free list of `Vec<f32>` allocations. `take`
//! returns a zeroed buffer of the requested length, reusing the
//! smallest free allocation whose capacity suffices; `recycle` returns a
//! buffer to the list. Buffers that are never recycled (e.g. ones moved
//! into step outputs) simply drop — the arena is an optimization, not an
//! ownership regime.

use super::Mat;

/// Upper bound on retained free buffers — a safety valve so a pathological
/// caller can't grow the list without bound (the per-step hot loop keeps
/// it far below this).
const MAX_FREE: usize = 256;

#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch { free: Vec::new() }
    }

    /// Number of buffers currently in the free list.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// An empty buffer with capacity ≥ `cap`, reusing the smallest free
    /// allocation that is large enough.
    fn take_raw(&mut self, cap: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() >= cap && best.is_none_or(|j| b.capacity() < self.free[j].capacity()) {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut v = self.free.swap_remove(i);
                v.clear();
                v
            }
            None => Vec::with_capacity(cap),
        }
    }

    /// A zeroed buffer of exactly `len` elements, reusing a recycled
    /// allocation when one is large enough.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_raw(len);
        v.resize(len, 0.0);
        v
    }

    /// A buffer holding a copy of `src` (no intermediate zero-fill).
    pub fn take_from(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.take_raw(src.len());
        v.extend_from_slice(src);
        v
    }

    /// A zeroed (rows, cols) matrix backed by a recycled buffer.
    pub fn mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: self.take(rows * cols) }
    }

    /// An empty matrix whose buffer reserves rows·cols elements — for
    /// `_into` kernels, which set the real shape themselves via
    /// [`Mat::reset`] (skips the redundant pre-zeroing of [`Self::mat`]).
    pub fn mat_spare(&mut self, rows: usize, cols: usize) -> Mat {
        Mat { rows: 0, cols: 0, data: self.take_raw(rows * cols) }
    }

    /// A (rows, cols) matrix holding a copy of `src`.
    pub fn mat_from(&mut self, rows: usize, cols: usize, src: &[f32]) -> Mat {
        assert_eq!(rows * cols, src.len(), "mat_from shape mismatch");
        Mat { rows, cols, data: self.take_from(src) }
    }

    /// Return a buffer to the free list.
    pub fn recycle(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < MAX_FREE {
            self.free.push(v);
        }
    }

    /// Return a matrix's backing buffer to the free list.
    pub fn recycle_mat(&mut self, m: Mat) {
        self.recycle(m.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_after_recycle() {
        let mut s = Scratch::new();
        let mut v = s.take(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        s.recycle(v);
        let w = s.take(4);
        assert_eq!(w, vec![0.0; 4]);
    }

    #[test]
    fn reuses_allocation() {
        let mut s = Scratch::new();
        let v = s.take(100);
        let p = v.as_ptr();
        s.recycle(v);
        let w = s.take(50);
        assert_eq!(w.as_ptr(), p, "smaller request reuses the freed buffer");
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn picks_smallest_sufficient_buffer() {
        let mut s = Scratch::new();
        let big = s.take(1000);
        let small = s.take(10);
        let (pb, ps) = (big.as_ptr(), small.as_ptr());
        s.recycle(big);
        s.recycle(small);
        assert_eq!(s.take(5).as_ptr(), ps, "best fit wins");
        assert_eq!(s.take(500).as_ptr(), pb);
    }

    #[test]
    fn mat_spare_reserves_without_zeroing() {
        let mut s = Scratch::new();
        let v = s.take(64);
        let p = v.as_ptr();
        s.recycle(v);
        let m = s.mat_spare(8, 8);
        assert_eq!((m.rows, m.cols), (0, 0));
        assert!(m.data.is_empty() && m.data.capacity() >= 64);
        assert_eq!(m.data.as_ptr(), p, "reuses the recycled allocation");
    }

    #[test]
    fn mat_roundtrip() {
        let mut s = Scratch::new();
        let m = s.mat_from(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(1, 2), 6.0);
        s.recycle_mat(m);
        let z = s.mat(3, 2);
        assert_eq!(z.data, vec![0.0; 6]);
    }
}
