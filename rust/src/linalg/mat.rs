//! Row-major dense f32 matrix with blocked, pool-parallel products.
//!
//! The product kernels split the output into row bands scheduled on
//! [`crate::util::pool::global`] (or an explicit pool via the `_with`
//! variants) and walk the shared operand in k-blocks with a two-row
//! register tile, so every worker streams cache-resident slices. The
//! pre-refactor single-threaded loop survives as [`Mat::matmul_ref`] —
//! the differential-testing oracle and the bench baseline. Unlike the old
//! loop there is no `a == 0.0` skip: the branch cost more than the
//! multiplies on real factor data and silently dropped NaN/Inf
//! propagation from the other operand.
//!
//! The register-tile inner loops run through [`crate::util::simd`]
//! (AVX2/NEON with a scalar fallback, selected at runtime); every path
//! keeps the per-element accumulation order, so the blocked kernels stay
//! bit-exact against the references on every CPU.

use crate::util::pool::{self, Pool};
use crate::util::simd;

/// k-block edge for the blocked matmul: one block of the B operand's rows
/// (KC·n floats) stays L1/L2-resident while a row band streams past it.
const KC: usize = 256;

/// Product work (m·k·n) below which parallel dispatch costs more than it
/// saves and the kernels run on the calling thread.
const PAR_FLOP_CUTOFF: usize = 1 << 15;

/// Row-major dense matrix of f32 (the training-path element type).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Reshape to (rows, cols) and zero-fill, reusing the allocation when
    /// its capacity suffices — the reset step of every `_into` kernel.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into `out` (reshaped as needed).
    pub fn transpose_into(&self, out: &mut Mat) {
        out.reset(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
    }

    /// self @ other — blocked parallel matmul on the global pool.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_with(pool::global(), other)
    }

    /// self @ other on an explicit pool.
    pub fn matmul_with(&self, pool: &Pool, other: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.matmul_into_with(pool, other, &mut out);
        out
    }

    /// self @ other into `out` (global pool).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        self.matmul_into_with(pool::global(), other, out);
    }

    /// self @ other into `out` on an explicit pool. `out` is reshaped to
    /// (self.rows, other.cols); its allocation is reused when possible.
    pub fn matmul_into_with(&self, pool: &Pool, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.reset(m, n);
        if super::reference_kernels() {
            mm_rows_ref(&self.data, &other.data, &mut out.data, 0, m, k, n);
            return;
        }
        if m * k * n < PAR_FLOP_CUTOFF || pool.size() <= 1 {
            mm_rows(&self.data, &other.data, &mut out.data, 0, m, k, n);
            return;
        }
        let grain = row_grain(pool, m, k * n);
        let (a, b) = (&self.data, &other.data);
        pool.parallel_for_mut(&mut out.data, grain * n, |ci, chunk| {
            let i0 = ci * grain;
            mm_rows(a, b, chunk, i0, (i0 + grain).min(m), k, n);
        });
    }

    /// self @ otherᵀ — the fused form of `a.matmul(&b.transpose())` the
    /// conv/fc forward passes use (no transposed copy is materialized).
    pub fn matmul_transposed(&self, other: &Mat) -> Mat {
        self.matmul_transposed_with(pool::global(), other)
    }

    /// self @ otherᵀ on an explicit pool.
    pub fn matmul_transposed_with(&self, pool: &Pool, other: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.matmul_transposed_into_with(pool, other, &mut out);
        out
    }

    /// self @ otherᵀ into `out` (global pool).
    pub fn matmul_transposed_into(&self, other: &Mat, out: &mut Mat) {
        self.matmul_transposed_into_with(pool::global(), other, out);
    }

    /// self @ otherᵀ into `out` on an explicit pool. `out` is reshaped to
    /// (self.rows, other.rows).
    pub fn matmul_transposed_into_with(&self, pool: &Pool, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.cols, "matmul_transposed shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        out.reset(m, n);
        if super::reference_kernels() {
            let bt = other.transpose();
            mm_rows_ref(&self.data, &bt.data, &mut out.data, 0, m, k, n);
            return;
        }
        if m * k * n < PAR_FLOP_CUTOFF || pool.size() <= 1 {
            mm_tb_rows(&self.data, &other.data, &mut out.data, 0, m, k, n);
            return;
        }
        let grain = row_grain(pool, m, k * n);
        let (a, b) = (&self.data, &other.data);
        pool.parallel_for_mut(&mut out.data, grain * n, |ci, chunk| {
            let i0 = ci * grain;
            mm_tb_rows(a, b, chunk, i0, (i0 + grain).min(m), k, n);
        });
    }

    /// self @ other — the pre-refactor single-threaded ikj loop, kept as
    /// the oracle for differential tests and the naive bench baseline.
    pub fn matmul_ref(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        mm_rows_ref(&self.data, &other.data, &mut out.data, 0, m, k, n);
        out
    }

    /// self + alpha * other (element-wise), shapes must match.
    pub fn axpy(&self, alpha: f32, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + alpha * b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Add `lambda` to the diagonal (Tikhonov damping), in place.
    pub fn add_diag(&mut self, lambda: f32) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            self.data[i * n + i] += lambda;
        }
    }

    pub fn trace(&self) -> f32 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.at(i, i)).sum()
    }

    /// Frobenius norm — the staleness metric of Algorithm 2 uses
    /// ||X - X₋₁||_F / ||X₋₁||_F.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// ||self - other||_F.
    pub fn fro_dist(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| {
                let d = (*a as f64) - (*b as f64);
                d * d
            })
            .sum::<f64>()
            .sqrt() as f32
    }

    /// Max |aᵢⱼ - bᵢⱼ|.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Symmetrize in place: X ← (X + Xᵀ)/2. Keeps accumulated factors
    /// numerically symmetric so packed communication is lossless.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = v;
                self.data[j * n + i] = v;
            }
        }
    }
}

/// Rows per parallel chunk: enough chunks for load balance (≈4 per
/// worker), but at least `PAR_FLOP_CUTOFF` work per chunk so small
/// products don't shred into dispatch overhead.
fn row_grain(pool: &Pool, m: usize, flops_per_row: usize) -> usize {
    let balance = m.div_ceil(pool.size() * 4);
    let floor = PAR_FLOP_CUTOFF.div_ceil(flops_per_row.max(1));
    balance.max(floor).max(1)
}

/// The pre-refactor naive ikj loop over a row range (without the
/// `a == 0.0` skip, which broke NaN/Inf propagation).
fn mm_rows_ref(a: &[f32], b: &[f32], out: &mut [f32], i0: usize, i1: usize, k: usize, n: usize) {
    for i in i0..i1 {
        let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Blocked ikj matmul over the row band [i0, i1): k is walked in
/// KC-blocks and rows in register-tiled pairs, so each pass streams one
/// cache-resident block of B past two accumulator rows. `out` holds only
/// the band (row i lands at out[(i - i0) * n..]). Accumulation order per
/// output element is p-ascending — identical to the naive reference, so
/// results match it bit-for-bit.
fn mm_rows(a: &[f32], b: &[f32], out: &mut [f32], i0: usize, i1: usize, k: usize, n: usize) {
    let mut p0 = 0;
    while p0 < k {
        let p1 = (p0 + KC).min(k);
        let mut i = i0;
        while i + 2 <= i1 {
            let (lo, hi) = out[(i - i0) * n..(i - i0 + 2) * n].split_at_mut(n);
            mm_tile2(&a[i * k..], &a[(i + 1) * k..], b, p0, p1, n, lo, hi);
            i += 2;
        }
        if i < i1 {
            let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
            mm_tile1(&a[i * k..], b, p0, p1, n, orow);
        }
        p0 = p1;
    }
}

/// Two-row register tile: both accumulator rows reuse every loaded B row.
#[inline]
fn mm_tile2(
    a0: &[f32],
    a1: &[f32],
    b: &[f32],
    p0: usize,
    p1: usize,
    n: usize,
    o0: &mut [f32],
    o1: &mut [f32],
) {
    let o0 = &mut o0[..n];
    let o1 = &mut o1[..n];
    for p in p0..p1 {
        let brow = &b[p * n..p * n + n];
        simd::axpy2(a0[p], a1[p], brow, o0, o1);
    }
}

/// Single-row tail of [`mm_tile2`].
#[inline]
fn mm_tile1(a0: &[f32], b: &[f32], p0: usize, p1: usize, n: usize, o0: &mut [f32]) {
    let o0 = &mut o0[..n];
    for p in p0..p1 {
        let brow = &b[p * n..p * n + n];
        simd::axpy(a0[p], brow, o0);
    }
}

/// a @ bᵀ over the row band [i0, i1): each output element is a row·row
/// dot product, computed with an 8-lane partial-sum tile so the reduction
/// vectorizes. b is (n, k) row-major.
fn mm_tb_rows(a: &[f32], b: &[f32], out: &mut [f32], i0: usize, i1: usize, k: usize, n: usize) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
        for j in 0..n {
            orow[j] = simd::dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_matches_ref_on_odd_shapes() {
        let mut rng = Rng::new(41);
        for &(m, k, n) in &[(1, 1, 1), (1, 9, 5), (5, 9, 1), (17, 31, 13), (33, 257, 29)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let got = a.matmul(&b);
            let want = a.matmul_ref(&b);
            assert!(got.max_abs_diff(&want) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_propagates_nan() {
        // the old `a == 0.0` skip silently dropped NaN from B
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Mat::from_vec(2, 1, vec![f32::NAN, 1.0]);
        assert!(a.matmul(&b).data[0].is_nan());
        assert!(a.matmul_ref(&b).data[0].is_nan());
    }

    #[test]
    fn matmul_transposed_matches_explicit_transpose() {
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1, 3, 1), (4, 27, 7), (19, 64, 33), (3, 100, 2)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let got = a.matmul_transposed(&b);
            let want = a.matmul_ref(&b.transpose());
            assert!(got.max_abs_diff(&want) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        let mut rng = Rng::new(43);
        let a = rand_mat(&mut rng, 8, 6);
        let b = rand_mat(&mut rng, 6, 10);
        let mut out = Mat::zeros(8, 10);
        let cap = out.data.capacity();
        a.matmul_into(&b, &mut out);
        assert_eq!(out.data.capacity(), cap, "no realloc for same-size out");
        assert!(out.max_abs_diff(&a.matmul_ref(&b)) < 1e-5);
        // stale contents must not leak into a smaller product
        let c = rand_mat(&mut rng, 3, 6);
        c.matmul_into(&b, &mut out);
        assert_eq!((out.rows, out.cols), (3, 10));
        assert!(out.max_abs_diff(&c.matmul_ref(&b)) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fro_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn damping_adds_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(0.5);
        assert_eq!(a.trace(), 1.5);
        assert_eq!(a.at(0, 1), 0.0);
    }

    #[test]
    fn symmetrize_works() {
        let mut a = Mat::from_vec(2, 2, vec![1., 2., 4., 3.]);
        a.symmetrize();
        assert_eq!(a.at(0, 1), 3.0);
        assert_eq!(a.at(1, 0), 3.0);
    }
}
