//! Row-major dense f32 matrix.

/// Row-major dense matrix of f32 (the training-path element type).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// self @ other — blocked ikj matmul (cache-friendly for our sizes).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// self + alpha * other (element-wise), shapes must match.
    pub fn axpy(&self, alpha: f32, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + alpha * b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Add `lambda` to the diagonal (Tikhonov damping), in place.
    pub fn add_diag(&mut self, lambda: f32) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            self.data[i * n + i] += lambda;
        }
    }

    pub fn trace(&self) -> f32 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.at(i, i)).sum()
    }

    /// Frobenius norm — the staleness metric of Algorithm 2 uses
    /// ||X - X₋₁||_F / ||X₋₁||_F.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// ||self - other||_F.
    pub fn fro_dist(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| {
                let d = (*a as f64) - (*b as f64);
                d * d
            })
            .sum::<f64>()
            .sqrt() as f32
    }

    /// Max |aᵢⱼ - bᵢⱼ|.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Symmetrize in place: X ← (X + Xᵀ)/2. Keeps accumulated factors
    /// numerically symmetric so packed communication is lossless.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = v;
                self.data[j * n + i] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fro_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn damping_adds_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(0.5);
        assert_eq!(a.trace(), 1.5);
        assert_eq!(a.at(0, 1), 0.0);
    }

    #[test]
    fn symmetrize_works() {
        let mut a = Mat::from_vec(2, 2, vec![1., 2., 4., 3.]);
        a.symmetrize();
        assert_eq!(a.at(0, 1), 3.0);
        assert_eq!(a.at(1, 0), 3.0);
    }
}
