//! Dense + packed-symmetric linear algebra substrate.
//!
//! The coordinator needs host-side matrix math for: Kronecker-factor
//! bookkeeping (damping, π split, staleness norms), the closed-form 2×2
//! BatchNorm inverse, symmetry-aware packing for communication, and
//! reference inverses to cross-check the HLO Newton-Schulz artifacts.

pub mod mat;
pub mod packed;
pub mod solve;

pub use mat::Mat;
pub use packed::{pack_upper, packed_len, unpack_upper};
