//! Dense + packed-symmetric linear algebra substrate.
//!
//! The coordinator needs host-side matrix math for: Kronecker-factor
//! bookkeeping (damping, π split, staleness norms), the closed-form 2×2
//! BatchNorm inverse, symmetry-aware packing for communication, and
//! reference inverses to cross-check the HLO Newton-Schulz artifacts.
//!
//! The product kernels are blocked and pool-parallel (see [`mat`]); the
//! single-threaded pre-refactor loops survive as `*_ref` oracles. The
//! [`set_reference_kernels`] switch routes every blocked/parallel kernel
//! back to its oracle — bench-only, for measuring the naive baseline the
//! speedups in `BENCH_native.json` are computed against.

pub mod mat;
pub mod packed;
pub mod scratch;
pub mod solve;

use std::sync::atomic::{AtomicBool, Ordering};

pub use mat::Mat;
pub use packed::{pack_upper, packed_len, unpack_upper};
pub use scratch::Scratch;

static REFERENCE_KERNELS: AtomicBool = AtomicBool::new(false);

/// Route the blocked/parallel kernels (matmul, SYRK, im2col/col2im,
/// Newton-Schulz) to their naive `*_ref` implementations. Bench-only:
/// flip it around a timed section to measure the naive baseline; never
/// leave it on in concurrent code.
pub fn set_reference_kernels(on: bool) {
    REFERENCE_KERNELS.store(on, Ordering::Relaxed);
}

/// Whether [`set_reference_kernels`] routing is active.
pub fn reference_kernels() -> bool {
    REFERENCE_KERNELS.load(Ordering::Relaxed)
}
