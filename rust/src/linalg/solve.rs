//! Inverses & factorizations: Gauss-Jordan (general), Cholesky (SPD),
//! Newton-Schulz iteration (SPD, matmul-only — the same scheme the L1
//! Pallas kernel uses on the MXU), and a power-iteration spectral-norm
//! estimate used to initialize Newton-Schulz.

use super::Mat;

/// Gauss-Jordan inverse with partial pivoting. O(n³); reference oracle for
/// validating the Newton-Schulz artifacts and for small host-side solves.
pub fn gauss_jordan_inverse(a: &Mat) -> Option<Mat> {
    assert!(a.is_square());
    let n = a.rows;
    // augmented [A | I] in f64 for accuracy
    let mut aug = vec![0.0f64; n * 2 * n];
    for i in 0..n {
        for j in 0..n {
            aug[i * 2 * n + j] = a.at(i, j) as f64;
        }
        aug[i * 2 * n + n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = aug[col * 2 * n + col].abs();
        for r in (col + 1)..n {
            let v = aug[r * 2 * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-300 {
            return None; // singular
        }
        if piv != col {
            for j in 0..2 * n {
                aug.swap(col * 2 * n + j, piv * 2 * n + j);
            }
        }
        let d = aug[col * 2 * n + col];
        for j in 0..2 * n {
            aug[col * 2 * n + j] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = aug[r * 2 * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..2 * n {
                aug[r * 2 * n + j] -= f * aug[col * 2 * n + j];
            }
        }
    }
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            inv.data[i * n + j] = aug[i * 2 * n + n + j] as f32;
        }
    }
    Some(inv)
}

/// Cholesky factor L (lower) of an SPD matrix, or None if not PD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert!(a.is_square());
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(Mat::from_vec(n, n, l.iter().map(|x| *x as f32).collect()))
}

/// SPD inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn cholesky_inverse(a: &Mat) -> Option<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    // invert lower-triangular L by forward substitution per unit vector
    let mut linv = Mat::zeros(n, n);
    for col in 0..n {
        for i in col..n {
            let mut s = if i == col { 1.0f64 } else { 0.0 };
            for k in col..i {
                s -= (l.at(i, k) as f64) * (linv.at(k, col) as f64);
            }
            *linv.at_mut(i, col) = (s / l.at(i, i) as f64) as f32;
        }
    }
    Some(linv.transpose().matmul(&linv))
}

/// Power-iteration estimate of the spectral norm (largest eigenvalue of a
/// symmetric PSD matrix). `iters`=16 gives ~3 digits for our factors.
pub fn spectral_norm_est(a: &Mat, iters: usize) -> f32 {
    assert!(a.is_square());
    let n = a.rows;
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    let mut lambda = 0.0f32;
    for _ in 0..iters {
        let mut w = vec![0.0f32; n];
        for i in 0..n {
            let row = &a.data[i * n..(i + 1) * n];
            let mut acc = 0.0;
            for j in 0..n {
                acc += row[j] * v[j];
            }
            w[i] = acc;
        }
        let norm = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lambda = norm;
        for i in 0..n {
            v[i] = w[i] / norm;
        }
    }
    lambda
}

/// Newton-Schulz iteration for the inverse of an SPD matrix.
///
/// X₀ = (1/σ) I with σ ≥ λ_max(M) guarantees convergence; each step is
/// X ← X (2I − M X) — two matmuls, exactly the MXU-friendly scheme of the
/// L1 `inverse.py` kernel. Returns after `iters` steps. The two inner
/// products run on the global pool and ping-pong between two reused
/// buffers instead of allocating per iteration.
pub fn newton_schulz_inverse(m: &Mat, iters: usize) -> Mat {
    assert!(m.is_square());
    let n = m.rows;
    let sigma = spectral_norm_est(m, 16).max(f32::MIN_POSITIVE);
    let mut x = Mat::eye(n).scale(1.0 / sigma);
    let mut t = Mat::zeros(n, n);
    let mut x2 = Mat::zeros(n, n);
    for _ in 0..iters {
        m.matmul_into(&x, &mut t);
        for v in t.data.iter_mut() {
            *v = -*v;
        }
        t.add_diag(2.0); // t = 2I - MX
        x.matmul_into(&t, &mut x2);
        std::mem::swap(&mut x, &mut x2);
    }
    x
}

/// Residual ||A X − I||_F / sqrt(n): convergence check for inverse quality.
pub fn inverse_residual(a: &Mat, x: &Mat) -> f32 {
    let n = a.rows;
    let ax = a.matmul(x);
    let mut acc = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = (ax.at(i, j) - target) as f64;
            acc += d * d;
        }
    }
    (acc.sqrt() / (n as f64).sqrt()) as f32
}

/// Closed-form 2×2 inverse (Eq. 17 of the paper) — unit-wise BatchNorm.
/// Returns None if the determinant is (numerically) zero.
pub fn inv2x2(a: f32, b: f32, c: f32, d: f32) -> Option<[f32; 4]> {
    let det = (a as f64) * (d as f64) - (b as f64) * (c as f64);
    if det.abs() < 1e-30 {
        return None;
    }
    let inv_det = 1.0 / det;
    Some([
        (d as f64 * inv_det) as f32,
        (-b as f64 * inv_det) as f32,
        (-c as f64 * inv_det) as f32,
        (a as f64 * inv_det) as f32,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gen};
    use crate::util::rng::Rng;

    fn spd_mat(rng: &mut Rng, n: usize, eps: f64) -> Mat {
        let d = gen::spd(rng, n, eps);
        Mat::from_vec(n, n, d.iter().map(|x| *x as f32).collect())
    }

    #[test]
    fn gj_inverse_known() {
        let a = Mat::from_vec(2, 2, vec![4., 7., 2., 6.]);
        let inv = gauss_jordan_inverse(&a).unwrap();
        assert!(inverse_residual(&a, &inv) < 1e-5);
    }

    #[test]
    fn gj_singular_none() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 4.]);
        assert!(gauss_jordan_inverse(&a).is_none());
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(3);
        let a = spd_mat(&mut rng, 8, 0.5);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-4);
    }

    #[test]
    fn cholesky_inverse_matches_gj() {
        let mut rng = Rng::new(4);
        let a = spd_mat(&mut rng, 10, 0.5);
        let i1 = cholesky_inverse(&a).unwrap();
        let i2 = gauss_jordan_inverse(&a).unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-3);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigvals 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spectral_norm_diag() {
        let mut a = Mat::zeros(3, 3);
        a.data[0] = 5.0;
        a.data[4] = 2.0;
        a.data[8] = 1.0;
        // power iteration from a non-aligned start still finds 5 after iters
        let est = spectral_norm_est(&a, 50);
        assert!((est - 5.0).abs() < 1e-3, "est={est}");
    }

    #[test]
    fn newton_schulz_converges_on_spd() {
        let mut rng = Rng::new(5);
        let mut a = spd_mat(&mut rng, 16, 0.0);
        a.add_diag(0.1); // damped, like the real factors
        let x = newton_schulz_inverse(&a, 30);
        let r = inverse_residual(&a, &x);
        assert!(r < 1e-3, "residual={r}");
    }

    #[test]
    fn prop_newton_schulz_matches_gj() {
        prop::check(
            7,
            25,
            24,
            |rng: &mut Rng, size| {
                let n = size.max(2);
                let mut m = spd_mat(rng, n, 0.0);
                m.add_diag(0.05 + m.trace() / n as f32 * 0.01);
                m
            },
            |m| {
                let ns = newton_schulz_inverse(m, 40);
                inverse_residual(m, &ns) < 5e-3
            },
        );
    }

    #[test]
    fn inv2x2_matches_gj() {
        let a = Mat::from_vec(2, 2, vec![3., 1., 1., 2.]);
        let gj = gauss_jordan_inverse(&a).unwrap();
        let f = inv2x2(3., 1., 1., 2.).unwrap();
        assert!((gj.data[0] - f[0]).abs() < 1e-6);
        assert!((gj.data[1] - f[1]).abs() < 1e-6);
        assert!((gj.data[3] - f[3]).abs() < 1e-6);
    }

    #[test]
    fn inv2x2_singular() {
        assert!(inv2x2(1., 2., 2., 4.).is_none());
    }
}
