//! [`TrainerBuilder`] — composes a model, a [`Preconditioner`], an
//! [`UpdateRule`], a [`SchedulePolicy`] and a dist engine into a
//! [`Trainer`]. This replaces raw `TrainerCfg` construction: execution
//! shape (workers, accumulation, dist mode, augment, seed) stays in the
//! slim [`TrainerCfg`], while everything optimizer-flavored lives behind
//! the optim traits.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use spngd::coordinator::TrainerBuilder;
//! use spngd::optim;
//!
//! let mut trainer = TrainerBuilder::new("mlp")
//!     .optimizer(optim::by_name("lars")?)
//!     .workers(4)
//!     .build()?;
//! trainer.step()?;
//! # Ok(())
//! # }
//! ```

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::trainer::{DistMode, Trainer, TrainerCfg};
use crate::data::{AugmentCfg, SynthDataset};
use crate::optim::{
    HyperParams, MomentumRule, Preconditioner, Schedule, SchedulePolicy, UpdateRule,
};
use crate::runtime::{native, Executor, Manifest};

pub struct TrainerBuilder {
    model: String,
    workers: usize,
    grad_accum: usize,
    augment: AugmentCfg,
    bn_momentum: f32,
    fp16_comm: bool,
    dist: DistMode,
    seed: u64,
    opt: Option<Arc<dyn Preconditioner>>,
    rule: Option<Arc<dyn UpdateRule>>,
    clip_update_ratio: f32,
    weight_rescale: bool,
    schedule: Option<Arc<dyn SchedulePolicy>>,
    hyperparams: Option<HyperParams>,
    steps_per_epoch: usize,
    dataset: Option<SynthDataset>,
    dataset_len: usize,
    data_seed: u64,
    runtime: Option<(Arc<Manifest>, Arc<dyn Executor>)>,
}

impl TrainerBuilder {
    /// A builder with the stock composition: SP-NGD (emp Fisher, unitBN,
    /// no stale scheduler), [`MomentumRule`] with a 0.3 trust-ratio clip,
    /// the optimizer's default polynomial schedule, 2 sequential workers,
    /// and the hermetic native runtime over a synthetic dataset.
    pub fn new(model: &str) -> Self {
        TrainerBuilder {
            model: model.to_string(),
            workers: 2,
            grad_accum: 1,
            augment: AugmentCfg::disabled(),
            bn_momentum: 0.9,
            fp16_comm: false,
            dist: DistMode::Sequential,
            seed: 7,
            opt: None,
            rule: None,
            clip_update_ratio: 0.3,
            weight_rescale: false,
            schedule: None,
            hyperparams: None,
            steps_per_epoch: 64,
            dataset: None,
            dataset_len: 4000,
            data_seed: 42,
            runtime: None,
        }
    }

    /// The preconditioner (default: `optim::spngd()`).
    pub fn optimizer(mut self, opt: Arc<dyn Preconditioner>) -> Self {
        self.opt = Some(opt);
        self
    }

    /// A custom update rule. Overrides
    /// [`clip_update_ratio`](Self::clip_update_ratio) /
    /// [`weight_rescale`](Self::weight_rescale), which configure the
    /// stock [`MomentumRule`].
    pub fn update_rule(mut self, rule: Arc<dyn UpdateRule>) -> Self {
        self.rule = Some(rule);
        self
    }

    /// Trust-ratio update clip for the stock rule (0 = off; default 0.3).
    pub fn clip_update_ratio(mut self, clip: f32) -> Self {
        self.clip_update_ratio = clip;
        self
    }

    /// Normalizing-Weights rescale (Eq. 24) in the stock rule.
    pub fn weight_rescale(mut self, on: bool) -> Self {
        self.weight_rescale = on;
        self
    }

    /// A fully custom lr/momentum policy. Overrides
    /// [`hyperparams`](Self::hyperparams) /
    /// [`steps_per_epoch`](Self::steps_per_epoch), which configure the
    /// stock polynomial [`Schedule`].
    pub fn schedule<S: SchedulePolicy + 'static>(mut self, schedule: S) -> Self {
        self.schedule = Some(Arc::new(schedule));
        self
    }

    /// Hyperparameters for the stock polynomial schedule (default: the
    /// optimizer's [`Preconditioner::default_hparams`]).
    pub fn hyperparams(mut self, hp: HyperParams) -> Self {
        self.hyperparams = Some(hp);
        self
    }

    /// Steps per epoch for the stock schedule's epoch clock (default 64).
    pub fn steps_per_epoch(mut self, steps: usize) -> Self {
        self.steps_per_epoch = steps;
        self
    }

    /// Data-parallel workers (default 2).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Gradient-accumulation micro-steps (default 1).
    pub fn grad_accum(mut self, accum: usize) -> Self {
        self.grad_accum = accum;
        self
    }

    /// Augmentation pipeline (default disabled).
    pub fn augment(mut self, augment: AugmentCfg) -> Self {
        self.augment = augment;
        self
    }

    /// BN running-stat EMA momentum (default 0.9).
    pub fn bn_momentum(mut self, bn_momentum: f32) -> Self {
        self.bn_momentum = bn_momentum;
        self
    }

    /// Half-precision wire format for collectives (§5.2).
    pub fn fp16_comm(mut self, on: bool) -> Self {
        self.fp16_comm = on;
        self
    }

    /// Worker execution engine (default sequential).
    pub fn dist(mut self, dist: DistMode) -> Self {
        self.dist = dist;
        self
    }

    /// Trainer RNG seed (default 7).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Synthetic-corpus size (default 4000) for the default dataset.
    pub fn dataset_len(mut self, len: usize) -> Self {
        self.dataset_len = len;
        self
    }

    /// Synthetic-corpus seed (default 42) for the default dataset.
    pub fn data_seed(mut self, seed: u64) -> Self {
        self.data_seed = seed;
        self
    }

    /// An explicit dataset (overrides dataset_len/data_seed).
    pub fn dataset(mut self, dataset: SynthDataset) -> Self {
        self.dataset = Some(dataset);
        self
    }

    /// An explicit runtime (default: the hermetic native CPU backend).
    pub fn runtime(mut self, manifest: Arc<Manifest>, engine: Arc<dyn Executor>) -> Self {
        self.runtime = Some((manifest, engine));
        self
    }

    pub fn build(self) -> Result<Trainer> {
        let opt = self.opt.unwrap_or_else(crate::optim::spngd);
        let rule: Arc<dyn UpdateRule> = self.rule.unwrap_or_else(|| {
            Arc::new(MomentumRule {
                clip_update_ratio: self.clip_update_ratio,
                weight_rescale: self.weight_rescale,
            })
        });
        let schedule: Arc<dyn SchedulePolicy> = match self.schedule {
            Some(s) => s,
            None => {
                let hp = self.hyperparams.unwrap_or_else(|| opt.default_hparams());
                Arc::new(Schedule::new(hp, self.steps_per_epoch))
            }
        };
        let (manifest, engine) = match self.runtime {
            Some(r) => r,
            None => {
                let (m, e) = native::build_default()?;
                (Arc::new(m), Arc::new(e) as Arc<dyn Executor>)
            }
        };
        let m = manifest.model(&self.model)?;
        let dataset = match self.dataset {
            Some(d) => d,
            None => {
                let (c, h, w) = (m.input_shape[1], m.input_shape[2], m.input_shape[3]);
                SynthDataset::new(m.num_classes, c, h, w, self.dataset_len, self.data_seed)
            }
        };
        let cfg = TrainerCfg {
            model: self.model,
            workers: self.workers,
            grad_accum: self.grad_accum,
            augment: self.augment,
            bn_momentum: self.bn_momentum,
            fp16_comm: self.fp16_comm,
            dist: self.dist,
            seed: self.seed,
        };
        Trainer::new(manifest, engine, cfg, opt, rule, schedule, dataset)
    }
}
