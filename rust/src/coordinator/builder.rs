//! [`TrainerBuilder`] — composes a model, a [`Preconditioner`], an
//! [`UpdateRule`], a [`SchedulePolicy`], a data pipeline (a registered
//! [`DataSource`] + per-lane [`TransformChain`]s behind a prefetching
//! [`Loader`]) and a dist engine into a [`Trainer`]. This replaces raw
//! `TrainerCfg` construction: execution shape (workers, accumulation,
//! dist mode, seed) stays in the slim [`TrainerCfg`], while everything
//! optimizer-flavored lives behind the optim traits and everything
//! data-flavored behind the data traits.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use spngd::coordinator::TrainerBuilder;
//! use spngd::optim;
//!
//! let mut trainer = TrainerBuilder::new("mlp")
//!     .optimizer(optim::by_name("lars")?)
//!     .workers(4)
//!     .build()?;
//! trainer.step()?;
//! # Ok(())
//! # }
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::collectives::comm::Precision;
use crate::coordinator::trainer::{DistMode, Trainer, TrainerCfg};
use crate::dist::ProcCfg;
use crate::data::{self, AugmentCfg, DataSource, Downsample, Loader, TransformChain};
use crate::optim::{
    HyperParams, MomentumRule, Preconditioner, Schedule, SchedulePolicy, UpdateRule,
};
use crate::runtime::{native, Executor, Manifest};

/// Per-lane chain customization hook: receives the lane index and the
/// standard chain (geometry fit + configured augmentations) and returns
/// the chain that lane will run.
type TransformHook = Box<dyn Fn(usize, TransformChain) -> TransformChain>;

pub struct TrainerBuilder {
    model: String,
    workers: usize,
    grad_accum: usize,
    augment: AugmentCfg,
    bn_momentum: f32,
    precision: Precision,
    dist: DistMode,
    proc: Option<ProcCfg>,
    seed: u64,
    opt: Option<Arc<dyn Preconditioner>>,
    rule: Option<Arc<dyn UpdateRule>>,
    clip_update_ratio: f32,
    weight_rescale: bool,
    schedule: Option<Arc<dyn SchedulePolicy>>,
    hyperparams: Option<HyperParams>,
    steps_per_epoch: usize,
    data: Option<String>,
    data_path: Option<PathBuf>,
    source: Option<Arc<dyn DataSource>>,
    transforms: Option<TransformHook>,
    prefetch: Option<bool>,
    dataset_len: usize,
    data_seed: u64,
    runtime: Option<(Arc<Manifest>, Arc<dyn Executor>)>,
}

impl TrainerBuilder {
    /// A builder with the stock composition: SP-NGD (emp Fisher, unitBN,
    /// no stale scheduler), [`MomentumRule`] with a 0.3 trust-ratio clip,
    /// the optimizer's default polynomial schedule, 2 sequential workers,
    /// and the hermetic native runtime over the `synth` data source with
    /// prefetch on.
    pub fn new(model: &str) -> Self {
        TrainerBuilder {
            model: model.to_string(),
            workers: 2,
            grad_accum: 1,
            augment: AugmentCfg::disabled(),
            bn_momentum: 0.9,
            precision: Precision::F32,
            dist: DistMode::Sequential,
            proc: None,
            seed: 7,
            opt: None,
            rule: None,
            clip_update_ratio: 0.3,
            weight_rescale: false,
            schedule: None,
            hyperparams: None,
            steps_per_epoch: 64,
            data: None,
            data_path: None,
            source: None,
            transforms: None,
            prefetch: None,
            dataset_len: 4000,
            data_seed: 42,
            runtime: None,
        }
    }

    /// The preconditioner (default: `optim::spngd()`).
    pub fn optimizer(mut self, opt: Arc<dyn Preconditioner>) -> Self {
        self.opt = Some(opt);
        self
    }

    /// A custom update rule. Overrides
    /// [`clip_update_ratio`](Self::clip_update_ratio) /
    /// [`weight_rescale`](Self::weight_rescale), which configure the
    /// stock [`MomentumRule`].
    pub fn update_rule(mut self, rule: Arc<dyn UpdateRule>) -> Self {
        self.rule = Some(rule);
        self
    }

    /// Trust-ratio update clip for the stock rule (0 = off; default 0.3).
    pub fn clip_update_ratio(mut self, clip: f32) -> Self {
        self.clip_update_ratio = clip;
        self
    }

    /// Normalizing-Weights rescale (Eq. 24) in the stock rule.
    pub fn weight_rescale(mut self, on: bool) -> Self {
        self.weight_rescale = on;
        self
    }

    /// A fully custom lr/momentum policy. Overrides
    /// [`hyperparams`](Self::hyperparams) /
    /// [`steps_per_epoch`](Self::steps_per_epoch), which configure the
    /// stock polynomial [`Schedule`].
    pub fn schedule<S: SchedulePolicy + 'static>(mut self, schedule: S) -> Self {
        self.schedule = Some(Arc::new(schedule));
        self
    }

    /// Hyperparameters for the stock polynomial schedule (default: the
    /// optimizer's [`Preconditioner::default_hparams`]).
    pub fn hyperparams(mut self, hp: HyperParams) -> Self {
        self.hyperparams = Some(hp);
        self
    }

    /// Steps per epoch for the stock schedule's epoch clock (default 64).
    pub fn steps_per_epoch(mut self, steps: usize) -> Self {
        self.steps_per_epoch = steps;
        self
    }

    /// Data-parallel workers (default 2).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Gradient-accumulation micro-steps (default 1).
    pub fn grad_accum(mut self, accum: usize) -> Self {
        self.grad_accum = accum;
        self
    }

    /// Augmentation pipeline (default disabled).
    pub fn augment(mut self, augment: AugmentCfg) -> Self {
        self.augment = augment;
        self
    }

    /// BN running-stat EMA momentum (default 0.9).
    pub fn bn_momentum(mut self, bn_momentum: f32) -> Self {
        self.bn_momentum = bn_momentum;
        self
    }

    /// Wire precision for the gradient/statistics collectives (§5.2's
    /// mixed-precision communication, default [`Precision::F32`]).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Back-compat alias for [`precision`](Self::precision):
    /// `fp16_comm(true)` selects [`Precision::Mixed`].
    pub fn fp16_comm(mut self, on: bool) -> Self {
        self.precision = if on { Precision::Mixed } else { Precision::F32 };
        self
    }

    /// Worker execution engine (default sequential).
    pub fn dist(mut self, dist: DistMode) -> Self {
        self.dist = dist;
        self
    }

    /// Multi-process transport knobs for [`DistMode::Proc`] (timeouts,
    /// respawn policy, fault plan). Default: [`ProcCfg::from_env`], so
    /// `SPNGD_FAULT_PLAN` / `SPNGD_PROC_*` work end-to-end.
    pub fn proc_cfg(mut self, proc: ProcCfg) -> Self {
        self.proc = Some(proc);
        self
    }

    /// Trainer RNG seed (default 7).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Synthetic-corpus size (default 4000) for the default dataset.
    pub fn dataset_len(mut self, len: usize) -> Self {
        self.dataset_len = len;
        self
    }

    /// Synthetic-corpus seed (default 42) for the default dataset.
    pub fn data_seed(mut self, seed: u64) -> Self {
        self.data_seed = seed;
        self
    }

    /// A data source by registry name (`synth` | `tensor` | `cifar10`,
    /// see [`data::by_name`]; default `synth`). Unknown names are a hard
    /// error at `build`.
    pub fn data(mut self, name: &str) -> Self {
        self.data = Some(name.to_string());
        self
    }

    /// Backing file for disk sources (`--data-path` / `SPNGD_DATA_PATH`).
    pub fn data_path<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.data_path = Some(path.into());
        self
    }

    /// An explicit [`DataSource`] (overrides `data`/`data_path`/
    /// `dataset_len`/`data_seed`).
    pub fn source(mut self, source: Arc<dyn DataSource>) -> Self {
        self.source = Some(source);
        self
    }

    /// Customize the per-lane transform chain: the hook receives each
    /// lane's standard chain (geometry fit + the configured
    /// [`augment`](Self::augment) stages) and returns the chain to use.
    pub fn transforms<F>(mut self, hook: F) -> Self
    where
        F: Fn(usize, TransformChain) -> TransformChain + 'static,
    {
        self.transforms = Some(Box::new(hook));
        self
    }

    /// Double-buffered batch prefetch on the process pool (default: on,
    /// or `SPNGD_PREFETCH`). Bitwise-neutral — only scheduling changes.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = Some(on);
        self
    }

    /// An explicit runtime (default: the hermetic native CPU backend).
    pub fn runtime(mut self, manifest: Arc<Manifest>, engine: Arc<dyn Executor>) -> Self {
        self.runtime = Some((manifest, engine));
        self
    }

    pub fn build(self) -> Result<Trainer> {
        let opt = self.opt.unwrap_or_else(crate::optim::spngd);
        let rule: Arc<dyn UpdateRule> = self.rule.unwrap_or_else(|| {
            Arc::new(MomentumRule {
                clip_update_ratio: self.clip_update_ratio,
                weight_rescale: self.weight_rescale,
            })
        });
        let schedule: Arc<dyn SchedulePolicy> = match self.schedule {
            Some(s) => s,
            None => {
                let hp = self.hyperparams.unwrap_or_else(|| opt.default_hparams());
                Arc::new(Schedule::new(hp, self.steps_per_epoch))
            }
        };
        let (manifest, engine) = match self.runtime {
            Some(r) => r,
            None => {
                let (m, e) = native::build_default()?;
                (Arc::new(m), Arc::new(e) as Arc<dyn Executor>)
            }
        };
        let m = manifest.model(&self.model)?;
        let (mc, mh, mw) = (m.input_shape[1], m.input_shape[2], m.input_shape[3]);
        let source: Arc<dyn DataSource> = match self.source {
            Some(s) => s,
            None => data::by_name(
                self.data.as_deref().unwrap_or("synth"),
                &data::SourceParams {
                    classes: m.num_classes,
                    channels: mc,
                    h: mh,
                    w: mw,
                    len: self.dataset_len,
                    seed: self.data_seed,
                    path: self.data_path.clone(),
                },
            )?,
        };

        // geometry fit: identical grids pass through; an integer-multiple
        // grid (e.g. CIFAR-10's 32×32 onto a 16×16 model) gets an
        // average-pool Downsample prepended to every lane chain
        let spec = source.spec();
        let fit: Option<usize> = if spec.shape() == (mc, mh, mw) {
            None
        } else if spec.channels == mc
            && mh > 0
            && mw > 0
            && spec.h % mh == 0
            && spec.w % mw == 0
            && spec.h / mh == spec.w / mw
        {
            Some(spec.h / mh)
        } else {
            bail!(
                "data source '{}' geometry {:?} does not fit model input {:?} \
                 (needs equal grids or an integer common downsample factor)",
                source.name(),
                spec.shape(),
                (mc, mh, mw),
            )
        };

        let lanes = self.workers.max(1) * self.grad_accum.max(1);
        let chains: Vec<TransformChain> = (0..lanes)
            .map(|g| {
                let mut chain = TransformChain::standard_for_lane(&self.augment, self.seed, g);
                if let Some(k) = fit {
                    chain.push_front(Box::new(Downsample::new(k)));
                }
                match &self.transforms {
                    Some(hook) => hook(g, chain),
                    None => chain,
                }
            })
            .collect();
        let prefetch = self.prefetch.unwrap_or_else(data::prefetch_from_env);
        let loader = Loader::new(source, chains, m.batch, self.seed, prefetch)?;

        let cfg = TrainerCfg {
            model: self.model,
            workers: self.workers,
            grad_accum: self.grad_accum,
            bn_momentum: self.bn_momentum,
            precision: self.precision,
            dist: self.dist,
            proc: self.proc.unwrap_or_else(ProcCfg::from_env),
            seed: self.seed,
        };
        Trainer::new(manifest, engine, cfg, opt, rule, schedule, loader)
    }
}
