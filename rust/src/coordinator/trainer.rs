//! The SP-NGD trainer: Algorithm 3 over data-parallel workers, driving a
//! pluggable optimizer.
//!
//! The optimizer is a composed triple (see [`crate::optim`]):
//! a [`Preconditioner`] trait object owning per-layer second-order state
//! (SP-NGD, SGD, LARS, …), an [`UpdateRule`] applying directions to
//! weights, and a [`SchedulePolicy`] for η(t)/m(t). The trainer itself
//! only knows the Stage pipeline; every `match` on optimizer behavior
//! lives behind the trait in `optim/`.
//!
//! The step pipeline is *lane-canonical*: the global batch is drawn in
//! global lane order `g = m·W + w` (micro-step major) from one data RNG,
//! every per-lane computation is independent, and every cross-lane
//! reduction runs in canonical lane order with f64 accumulators (the
//! [`Collective`] contract). Consequences the test suite asserts:
//!
//! - the same seed produces bit-identical batches, losses and updates
//!   for every worker count that factorizes the same lane total
//!   (`workers × grad_accum`), and
//! - the threaded dist engine ([`DistMode::Threaded`], real OS threads +
//!   `dist::RingComm`) is bit-identical to the sequential coordinator,
//!   so it can be differentially tested against it.
//!
//! Sequential and threaded modes share the same per-lane compute
//! ([`run_lane`]), and both call `Preconditioner::refresh` /
//! `optim::apply_layer_update` through the same trait object — one math
//! path, two schedules.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::ckpt::{
    self, ByteReader, ByteWriter, Checkpoint, MAX_SECTION, SEC_BN, SEC_CHAIN, SEC_LAYER,
    SEC_LOADER, SEC_META, SEC_PARAM, SEC_STASH, SEC_VELOCITY,
};
use crate::collectives::comm::{Collective, Precision, SimComm};
use crate::collectives::cost::StepProfile;
use crate::data::{Batch, IoStats, Loader, LoaderCkpt};
use crate::dist::{DistEngine, ProcCfg, ProcComm, RingComm};
use crate::linalg::Mat;
use crate::metrics::{RunLog, StageTimes, StepRecord};
use crate::optim::{
    self, Fisher, LayerStateBox, ParamSlot, Preconditioner, SchedulePolicy, StatKind, UpdateRule,
};
use crate::runtime::{Executor, HostTensor, Manifest, ModelManifest};
use crate::util::json::Json;
use crate::util::obs::{self, Cat};

/// How the data-parallel workers execute (§5, Alg. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistMode {
    /// iterate workers in the coordinator thread, `SimComm` accounting
    Sequential,
    /// one OS thread per worker, `dist::RingComm` collectives, factor
    /// communication and inversion overlapped with slower workers'
    /// compute (Alg. 3's schedule)
    Threaded,
    /// worker *processes* over the Unix-socket framed wire protocol
    /// (`dist::ProcComm`): the coordinator keeps the model and farms
    /// reductions out to stateless `spngd worker` reducers, with
    /// elastic membership and failure recovery
    Proc,
}

impl DistMode {
    /// `SPNGD_DIST=threads|threaded|1` selects the threaded engine,
    /// `SPNGD_DIST=proc` the multi-process transport; anything else
    /// (or unset) stays sequential.
    pub fn from_env() -> DistMode {
        match std::env::var("SPNGD_DIST") {
            Ok(v) if matches!(v.trim(), "threads" | "threaded" | "1") => DistMode::Threaded,
            Ok(v) if v.trim() == "proc" => DistMode::Proc,
            _ => DistMode::Sequential,
        }
    }
}

/// Execution-shape configuration — the part of a training run that is
/// *not* the optimizer. Optimizer, update rule and schedule are composed
/// separately by [`super::TrainerBuilder`].
#[derive(Clone, Debug)]
pub struct TrainerCfg {
    pub model: String,
    /// data-parallel workers (simulated GPUs; real OS threads under
    /// [`DistMode::Threaded`])
    pub workers: usize,
    /// micro-steps accumulated per update (extreme-BS mimicry, §7.1)
    pub grad_accum: usize,
    /// BN running-stat EMA momentum
    pub bn_momentum: f32,
    /// wire precision for the gradient/statistics collectives (§5.2's
    /// mixed-precision communication): `Mixed` moves those payloads as
    /// f16 (halved wire bytes, values pass through the exact f16
    /// round-trip) while parameters and every master copy stay f32 and
    /// reductions accumulate in f64
    pub precision: Precision,
    /// worker execution engine (sequential coordinator vs threaded dist
    /// vs multi-process transport)
    pub dist: DistMode,
    /// multi-process transport knobs (used only under [`DistMode::Proc`])
    pub proc: ProcCfg,
    pub seed: u64,
}

impl TrainerCfg {
    pub fn effective_batch(&self, per_worker: usize) -> usize {
        self.workers * self.grad_accum * per_worker
    }
}

/// Per-layer coordinator slot: Stage-4 ownership plus the optimizer's
/// per-layer state (owned by `owner`, mutated only there).
struct LayerSlot {
    /// owning process for the model-parallel Stage 4 (round-robin)
    owner: usize,
    state: LayerStateBox,
}

/// Per-lane scalar results of one step-executable run.
#[derive(Default)]
struct LaneOut {
    loss: f64,
    ncorrect: f64,
    /// per BN layer (bn_order): this lane's (batch mean, batch var)
    bn_stats: Vec<(Vec<f32>, Vec<f32>)>,
    t_exec: f64,
    t_factors: f64,
}

/// What one threaded worker hands back to the coordinator.
struct WorkerYield {
    lane_outs: Vec<(usize, LaneOut)>,
    /// this rank's copy of the (post-AllReduce) mean gradient vector
    grads: Vec<f32>,
    t_inverse: f64,
}

pub struct Trainer {
    pub cfg: TrainerCfg,
    model: ModelManifest,
    engine: Arc<dyn Executor>,
    opt: Arc<dyn Preconditioner>,
    rule: Arc<dyn UpdateRule>,
    schedule: Arc<dyn SchedulePolicy>,
    /// gradient estimator (cached from the preconditioner: picks the
    /// step executable and the 1mc sampling seeds)
    fisher: Fisher,
    /// sequential-mode communicator (byte accounting + reductions)
    comm: SimComm,
    /// threaded mode: per-worker executors + the ring communicator
    dist: Option<DistEngine>,
    /// proc mode: the multi-process transport (worker processes +
    /// membership; reductions go over the framed Unix-socket wire)
    proc: Option<ProcComm>,
    pub params: Vec<HostTensor>,
    velocity: Vec<HostTensor>,
    layers: Vec<LayerSlot>,
    bn_running: Vec<(HostTensor, HostTensor)>, // (mean, var) per bn_order
    /// the data pipeline: lane-canonical sharded batches with prefetch
    /// (owns the data/validation RNG streams and per-lane transforms)
    loader: Loader,
    step: u64,
    pub log: RunLog,
    // cumulative profile accumulators (full-refresh steps only)
    prof_exec_samples: Vec<f64>,
    prof_full_factors: Vec<f64>,
    prof_full_inverse: Vec<f64>,
    prof_update: Vec<f64>,
    prof_full_stats_bytes: Vec<f64>,
}

impl Trainer {
    /// Low-level constructor — prefer [`super::TrainerBuilder`], which
    /// composes the optimizer triple and defaults for you.
    pub fn new(
        manifest: Arc<Manifest>,
        engine: Arc<dyn Executor>,
        cfg: TrainerCfg,
        opt: Arc<dyn Preconditioner>,
        rule: Arc<dyn UpdateRule>,
        schedule: Arc<dyn SchedulePolicy>,
        loader: Loader,
    ) -> Result<Trainer> {
        // pick up SPNGD_TRACE / SPNGD_EVENTS for every construction path
        // (CLI flags route through the same switches in main.rs)
        obs::init_from_env();
        let model = manifest.model(&cfg.model)?.clone();
        let (classes, (c, h, w)) = loader.out_spec();
        anyhow::ensure!(
            model.input_shape[1..] == [c, h, w],
            "data pipeline output {:?} does not match model input {:?} \
             (source '{}' after transforms)",
            (c, h, w),
            model.input_shape,
            loader.source().name(),
        );
        anyhow::ensure!(
            classes == model.num_classes,
            "data source '{}' has {classes} classes, model '{}' expects {}",
            loader.source().name(),
            model.name,
            model.num_classes,
        );
        let lanes = cfg.workers.max(1) * cfg.grad_accum.max(1);
        anyhow::ensure!(
            loader.lanes() == lanes,
            "loader has {} lane chains, trainer shape needs {lanes} (workers × accum)",
            loader.lanes(),
        );
        let params = manifest.load_init_params(&model)?;
        let velocity = params.iter().map(|p| HostTensor::zeros(p.shape.clone())).collect();
        let layers = model
            .kfac_layers
            .iter()
            .enumerate()
            .map(|(i, _)| LayerSlot {
                owner: i % cfg.workers.max(1),
                state: opt.init_layer(&model, i),
            })
            .collect();
        let bn_running = model
            .bn_order
            .iter()
            .map(|n| {
                let c = model.layer(n).map(|l| l.channels).unwrap_or(0);
                (HostTensor::zeros(vec![c]), HostTensor::new(vec![c], vec![1.0; c]))
            })
            .collect();
        let mut comm = SimComm::new(cfg.workers);
        comm.precision = cfg.precision;
        let dist = match cfg.dist {
            DistMode::Threaded => {
                let mut de = DistEngine::new(&engine, cfg.workers);
                let ring = Arc::get_mut(&mut de.ring).expect("fresh ring communicator");
                ring.precision = cfg.precision;
                Some(de)
            }
            DistMode::Sequential | DistMode::Proc => None,
        };
        let proc = match cfg.dist {
            DistMode::Proc => {
                Some(ProcComm::launch(cfg.workers.max(1), cfg.precision, &cfg.proc)?)
            }
            _ => None,
        };
        let fisher = opt.fisher();
        Ok(Trainer {
            cfg,
            model,
            engine,
            opt,
            rule,
            schedule,
            fisher,
            comm,
            dist,
            proc,
            params,
            velocity,
            layers,
            bn_running,
            loader,
            step: 0,
            log: RunLog::default(),
            prof_exec_samples: Vec::new(),
            prof_full_factors: Vec::new(),
            prof_full_inverse: Vec::new(),
            prof_update: Vec::new(),
            prof_full_stats_bytes: Vec::new(),
        })
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// The composed preconditioner (registry name via `.name()`).
    pub fn optimizer(&self) -> &dyn Preconditioner {
        self.opt.as_ref()
    }

    /// The composed lr/momentum policy.
    pub fn schedule(&self) -> &dyn SchedulePolicy {
        self.schedule.as_ref()
    }

    /// The composed data pipeline (source + transforms + prefetch).
    pub fn loader(&self) -> &Loader {
        &self.loader
    }

    /// Cumulative data-path timing: per-batch prep cost and how much of
    /// it prefetch hid behind compute.
    pub fn data_stats(&self) -> IoStats {
        self.loader.io_stats()
    }

    /// The active communicator's byte accounting (SimComm sequentially,
    /// RingComm under the threaded dist engine, ProcComm under the
    /// multi-process transport).
    pub fn comm(&self) -> &dyn Collective {
        if let Some(p) = &self.proc {
            return p;
        }
        match &self.dist {
            Some(d) => d.ring.as_ref(),
            None => &self.comm,
        }
    }

    /// The multi-process transport, when running under
    /// [`DistMode::Proc`] (tests inspect membership events and the
    /// actual framed wire bytes through this).
    pub fn proc(&self) -> Option<&ProcComm> {
        self.proc.as_ref()
    }

    fn step_exe(&self) -> &str {
        match self.fisher {
            Fisher::Emp => &self.model.step_emp,
            Fisher::OneMc => &self.model.step_1mc,
        }
    }

    /// One training step (Alg. 3 + grad accumulation).
    ///
    /// An `Err` from a threaded step leaves the trainer poisoned: healthy
    /// workers may already have folded the failing worker's zero-payload
    /// keep-alive lanes into their owned factor caches and scheduler
    /// state (the protocol stays alive so peers never deadlock, see
    /// [`worker_step`]). Treat a step error as fatal for this trainer —
    /// don't retry-loop over it.
    pub fn step(&mut self) -> Result<StepRecord> {
        self.step += 1;
        let t = self.step;
        let _step_span = obs::span("step", Cat::Phase).arg("step", t as f64);
        // lint:allow(determinism) -- step wall-time telemetry, never step math
        let t_start = Instant::now();
        let w = self.cfg.workers.max(1);
        let micro = self.cfg.grad_accum.max(1);
        let lanes_n = w * micro;

        // ------------------------------------------------ refresh plan
        // Which statistics get refreshed this step (Alg. 1's `t == t_X`)?
        // The preconditioner consults its per-layer scheduler; first-order
        // optimizers plan nothing.
        let mut plan: Vec<(usize, StatKind)> = Vec::new();
        for (li, slot) in self.layers.iter_mut().enumerate() {
            for kind in self.opt.plan(&self.model, li, &mut slot.state, t) {
                plan.push((li, kind));
            }
        }

        // ---- the global batch, canonical lane order (usually prefetched
        // while the previous step computed — the loader's overlap)
        let seeds: Vec<Option<u32>> = (0..lanes_n)
            .map(|g| match self.fisher {
                Fisher::OneMc => Some(((t as u32) << 8) ^ (g as u32).wrapping_mul(0x9E37)),
                Fisher::Emp => None,
            })
            .collect();
        let batches: Vec<Batch> = self.loader.next()?;
        let exe = self.step_exe().to_string();
        let lr = self.schedule.lr(t) as f32;
        let mom = self.schedule.momentum(t) as f32;

        // ------------------------------ Stages 1-4 on the active engine
        if let Some(p) = &self.proc {
            p.round_start(t)?;
        }
        let (lane_outs, t_inverse, t_update) = if self.dist.is_some() {
            self.stages_threaded(t, &plan, batches, &seeds, &exe, lr, mom)?
        } else {
            self.stages_sequential(t, &plan, batches, &seeds, &exe, lr, mom)?
        };

        // --------------------------------- Stage 5: AllGatherV(params)
        self.comm().all_gather_v_params(self.model.total_param_count());

        // proc mode: close the round — the elastic window where late
        // joiners are admitted and dead workers are respawned; a run
        // that can no longer sustain membership fails here, loudly
        if let Some(p) = &self.proc {
            p.round_end(t)?;
        }

        // ------------------- loss / BN reductions (canonical lane order)
        let mut loss_sum = 0.0f64;
        let mut ncorrect_sum = 0.0f64;
        let mut bn_mean_acc: Vec<Vec<f32>> = Vec::new();
        let mut bn_var_acc: Vec<Vec<f32>> = Vec::new();
        let mut t_step_exec = 0.0f64;
        let mut t_factors = 0.0f64;
        for lo in &lane_outs {
            loss_sum += lo.loss;
            ncorrect_sum += lo.ncorrect;
            t_step_exec += lo.t_exec;
            t_factors += lo.t_factors;
            self.prof_exec_samples.push(lo.t_exec);
            for (bi, (m, v)) in lo.bn_stats.iter().enumerate() {
                if bn_mean_acc.len() <= bi {
                    bn_mean_acc.push(vec![0.0; m.len()]);
                    bn_var_acc.push(vec![0.0; v.len()]);
                }
                for (acc, x) in bn_mean_acc[bi].iter_mut().zip(m.iter()) {
                    *acc += *x;
                }
                for (acc, x) in bn_var_acc[bi].iter_mut().zip(v.iter()) {
                    *acc += *x;
                }
            }
        }

        // BN running stats EMA
        let denom = lanes_n as f32;
        for (bi, (rm, rv)) in self.bn_running.iter_mut().enumerate() {
            if bn_mean_acc.is_empty() {
                break;
            }
            let bm = self.cfg.bn_momentum;
            for i in 0..rm.data.len() {
                rm.data[i] = bm * rm.data[i] + (1.0 - bm) * bn_mean_acc[bi][i] / denom;
                rv.data[i] = bm * rv.data[i] + (1.0 - bm) * bn_var_acc[bi][i] / denom;
            }
        }

        // ------------------------------------------------- bookkeeping
        let comm_step = self.comm().take_step_stats();
        let denom_samples = lanes_n as f64 * self.model.batch as f64;
        let total_stats = self.total_stats();
        let times = StageTimes {
            t_step_exec,
            t_factors,
            t_inverse,
            t_update,
            t_total: t_start.elapsed().as_secs_f64(),
        };
        // profile capture
        self.prof_update.push(t_update);
        if total_stats > 0 && plan.len() == total_stats {
            self.prof_full_factors.push(t_factors / lanes_n as f64);
            self.prof_full_inverse.push(t_inverse);
            self.prof_full_stats_bytes
                .push(comm_step.stats_total() as f64 / micro as f64);
        }
        let rec = StepRecord {
            step: t,
            epoch: self.epoch(),
            loss: (loss_sum / lanes_n as f64) as f32,
            train_acc: (ncorrect_sum / denom_samples) as f32,
            lr: lr as f64,
            momentum: mom as f64,
            times,
            comm: comm_step,
            refreshed: plan.len(),
            total_stats,
        };
        self.log.push(rec.clone());
        // one hash instead of N tensors: equivalence suites and the
        // resume test compare runs by this digest of the updated params
        self.log.final_params_fnv = Some(self.params_digest());
        Ok(rec)
    }

    /// Stages 1-4, sequential engine: lanes iterated in canonical order
    /// on the coordinator thread, reductions through `SimComm`.
    #[allow(clippy::too_many_arguments)]
    fn stages_sequential(
        &mut self,
        t: u64,
        plan: &[(usize, StatKind)],
        batches: Vec<Batch>,
        seeds: &[Option<u32>],
        exe: &str,
        lr: f32,
        mom: f32,
    ) -> Result<(Vec<LaneOut>, f64, f64)> {
        let lanes_n = batches.len();
        let mut lane_outs: Vec<LaneOut> = Vec::with_capacity(lanes_n);
        let mut grad_lanes: Vec<Vec<f32>> = Vec::with_capacity(lanes_n);
        let mut factor_lanes: Vec<Vec<Mat>> = Vec::with_capacity(lanes_n);
        let s12 = obs::span("stage1_2", Cat::Phase);
        for (g, batch) in batches.iter().enumerate() {
            let mut factors: Vec<Mat> = Vec::with_capacity(plan.len());
            let (lo, grads) = run_lane(
                self.engine.as_ref(),
                &self.model,
                exe,
                self.opt.as_ref(),
                plan,
                &self.params,
                batch,
                seeds[g],
                |_, m| factors.push(m),
            )?;
            lane_outs.push(lo);
            grad_lanes.push(grads);
            factor_lanes.push(factors);
        }
        drop(s12);

        // ------------------------- Stage 3: gradient AllReduce (mean)
        // (through ProcComm's worker processes under DistMode::Proc —
        // same canonical-lane math, so the results are bit-identical)
        let s3 = obs::span("stage3_grad", Cat::Phase);
        let comm: &dyn Collective = match &self.proc {
            Some(p) => p,
            None => &self.comm,
        };
        comm.all_reduce_mean(&mut grad_lanes);
        let grads_flat = std::mem::take(&mut grad_lanes[0]);
        drop(s3);

        // ----------------- Stages 2-3: ReduceScatterV of the statistics
        let s23 = obs::span("stage2_3_stats", Cat::Phase);
        let reduced: Vec<Mat> = if plan.is_empty() {
            Vec::new()
        } else {
            let classes: Vec<_> = plan.iter().map(|&(_, k)| k.class()).collect();
            comm.reduce_scatter_v(&factor_lanes, &classes)
        };
        drop(s23);

        // ------------------- Stage 4a: model-parallel factor inversion
        let s4a = obs::span("stage4a_invert", Cat::Phase);
        // lint:allow(determinism) -- stage wall-time telemetry, never step math
        let t_inv_start = Instant::now();
        let mut layer_jobs: Vec<(usize, Vec<(StatKind, Mat)>)> = Vec::new();
        for (&(li, kind), m) in plan.iter().zip(reduced.into_iter()) {
            match layer_jobs.last_mut() {
                Some((last, items)) if *last == li => items.push((kind, m)),
                _ => layer_jobs.push((li, vec![(kind, m)])),
            }
        }
        for (li, items) in layer_jobs {
            let _inv = obs::span("invert", Cat::Compute).arg("layer", li as f64);
            let slot = &mut self.layers[li];
            self.opt
                .refresh(self.engine.as_ref(), &self.model, li, &mut slot.state, t, items)?;
        }
        let t_inverse = t_inv_start.elapsed().as_secs_f64();
        drop(s4a);

        // ------------------- Stage 4b: preconditioning + weight update
        let s4b = obs::span("stage4b_update", Cat::Phase);
        // lint:allow(determinism) -- stage wall-time telemetry, never step math
        let t_upd_start = Instant::now();
        let mut slots: BTreeMap<usize, ParamSlot> = self
            .params
            .iter_mut()
            .zip(self.velocity.iter_mut())
            .enumerate()
            .map(|(i, (p, v))| (i, ParamSlot { p, v }))
            .collect();
        for li in 0..self.model.kfac_layers.len() {
            let _upd = obs::span("update", Cat::Compute).arg("layer", li as f64);
            optim::apply_layer_update(
                self.engine.as_ref(),
                &self.model,
                self.opt.as_ref(),
                self.rule.as_ref(),
                li,
                &self.layers[li].state,
                &mut slots,
                &grads_flat,
                lr,
                mom,
            )?;
        }
        let t_update = t_upd_start.elapsed().as_secs_f64();
        drop(s4b);
        Ok((lane_outs, t_inverse, t_update))
    }

    /// Stages 1-4, threaded dist engine: one OS thread per worker, ring
    /// collectives, factor publish + gradient send overlapped with
    /// compute, owner-parallel inversion and updates. Owner threads call
    /// `refresh`/`direction` through the same trait object the
    /// sequential engine uses.
    #[allow(clippy::too_many_arguments)]
    fn stages_threaded(
        &mut self,
        t: u64,
        plan: &[(usize, StatKind)],
        batches: Vec<Batch>,
        seeds: &[Option<u32>],
        exe: &str,
        lr: f32,
        mom: f32,
    ) -> Result<(Vec<LaneOut>, f64, f64)> {
        let w = self.cfg.workers.max(1);
        let lanes_n = batches.len();
        let nlayers = self.model.kfac_layers.len();
        let dist = self.dist.as_ref().expect("threaded mode has a dist engine");
        let ring = dist.ring.as_ref();
        ring.begin_stats(plan.len(), lanes_n);

        // distribute lanes (g mod W) and layer ownership across workers
        let mut per_worker: Vec<Vec<(usize, Batch)>> = (0..w).map(|_| Vec::new()).collect();
        for (g, b) in batches.into_iter().enumerate() {
            per_worker[g % w].push((g, b));
        }
        let mut layer_groups: Vec<Vec<(usize, &mut LayerSlot)>> =
            (0..w).map(|_| Vec::new()).collect();
        for (li, l) in self.layers.iter_mut().enumerate() {
            let o = l.owner % w;
            layer_groups[o].push((li, l));
        }
        let mut layer_items: Vec<Vec<(usize, StatKind)>> = vec![Vec::new(); nlayers];
        for (idx, &(li, kind)) in plan.iter().enumerate() {
            layer_items[li].push((idx, kind));
        }

        let model = &self.model;
        let opt = self.opt.as_ref();
        let params = &self.params;
        let nparams_total = model.total_param_count();
        let layer_items = &layer_items;

        // -------- scope 1: Stage 1-2 compute + publish, Stage 3 send,
        // Stage 4a owner reduce+invert, Stage 3 finish
        let mut yields: Vec<Result<WorkerYield>> = Vec::with_capacity(w);
        let s14 = obs::span("stage1_4_workers", Cat::Phase);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(w);
            for rank in 0..w {
                let my_batches = std::mem::take(&mut per_worker[rank]);
                let group = std::mem::take(&mut layer_groups[rank]);
                let engine = dist.engine(rank).clone();
                let h = std::thread::Builder::new()
                    .name(format!("spngd-worker-{rank}"))
                    .spawn_scoped(s, move || {
                        // a panicking worker (e.g. inside a kernel)
                        // poisons the ring so peers abort with its rank
                        // named instead of hanging mid-collective
                        let _poison = ring.poison_guard(rank);
                        worker_step(
                            engine.as_ref(),
                            ring,
                            model,
                            opt,
                            t,
                            plan,
                            layer_items,
                            params,
                            nparams_total,
                            lanes_n,
                            exe,
                            seeds,
                            my_batches,
                            group,
                        )
                    })
                    .expect("spawn dist worker thread");
                handles.push(h);
            }
            for h in handles {
                yields.push(match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow::anyhow!("dist worker panicked")),
                });
            }
        });
        drop(s14);
        drop(layer_groups); // release the &mut borrows of self.layers
        let mut workers_out: Vec<WorkerYield> = Vec::with_capacity(w);
        for y in yields {
            workers_out.push(y?);
        }
        let t_inverse = workers_out.iter().map(|y| y.t_inverse).fold(0.0f64, f64::max);
        let grads_flat = std::mem::take(&mut workers_out[0].grads);
        let mut lane_outs_tagged: Vec<(usize, LaneOut)> = Vec::with_capacity(lanes_n);
        for y in workers_out {
            lane_outs_tagged.extend(y.lane_outs);
        }
        lane_outs_tagged.sort_by_key(|(g, _)| *g);
        let lane_outs: Vec<LaneOut> = lane_outs_tagged.into_iter().map(|(_, lo)| lo).collect();

        // -------- scope 2: Stage 4b owner-parallel updates (disjoint
        // parameter partition, layers now read-only)
        let s4b = obs::span("stage4b_update", Cat::Phase);
        // lint:allow(determinism) -- stage wall-time telemetry, never step math
        let t_upd_start = Instant::now();
        let mut powner = vec![usize::MAX; self.params.len()];
        for (li, ml) in self.model.kfac_layers.iter().enumerate() {
            let o = self.layers[li].owner % w;
            if ml.is_bn() {
                powner[self.model.param_index(&ml.gamma_param).context("gamma param")?] = o;
                powner[self.model.param_index(&ml.beta_param).context("beta param")?] = o;
            } else {
                powner[self.model.param_index(&ml.weight_param).context("weight param")?] = o;
            }
        }
        let mut slot_groups: Vec<BTreeMap<usize, ParamSlot>> =
            (0..w).map(|_| BTreeMap::new()).collect();
        for (pi, (p, v)) in self.params.iter_mut().zip(self.velocity.iter_mut()).enumerate() {
            let o = powner[pi];
            if o != usize::MAX {
                slot_groups[o].insert(pi, ParamSlot { p, v });
            }
        }
        let layers = &self.layers;
        let model = &self.model;
        let opt = self.opt.as_ref();
        let rule = self.rule.as_ref();
        let grads_ref = &grads_flat;
        let mut upd_results: Vec<Result<()>> = Vec::with_capacity(w);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(w);
            for rank in 0..w {
                let slots = std::mem::take(&mut slot_groups[rank]);
                let engine = dist.engine(rank).clone();
                let h = std::thread::Builder::new()
                    .name(format!("spngd-update-{rank}"))
                    .spawn_scoped(s, move || -> Result<()> {
                        let mut slots = slots;
                        for (li, layer) in layers.iter().enumerate() {
                            if layer.owner % w != rank {
                                continue;
                            }
                            let _upd =
                                obs::span("update", Cat::Compute).arg("layer", li as f64);
                            optim::apply_layer_update(
                                engine.as_ref(),
                                model,
                                opt,
                                rule,
                                li,
                                &layer.state,
                                &mut slots,
                                grads_ref,
                                lr,
                                mom,
                            )?;
                        }
                        Ok(())
                    })
                    .expect("spawn dist update thread");
                handles.push(h);
            }
            for h in handles {
                upd_results.push(match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow::anyhow!("dist update worker panicked")),
                });
            }
        });
        for r in upd_results {
            r?;
        }
        let t_update = t_upd_start.elapsed().as_secs_f64();
        drop(s4b);
        Ok((lane_outs, t_inverse, t_update))
    }

    /// Stage-4 layer→process ownership (round-robin, as in §5.1 when
    /// the layer count exceeds the process count).
    pub fn layer_owners(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.owner).collect()
    }

    /// Total statistics this optimizer refreshes at full cadence (0 for
    /// first-order optimizers, which publish nothing).
    fn total_stats(&self) -> usize {
        (0..self.model.kfac_layers.len())
            .map(|li| self.opt.stats_spec(&self.model, li).len())
            .sum()
    }

    pub fn epoch(&self) -> f64 {
        self.schedule.epoch_of(self.step)
    }

    /// Validation over `batches` held-out batches: (loss, accuracy).
    pub fn evaluate(&mut self, batches: usize) -> Result<(f32, f32)> {
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        for _ in 0..batches {
            let b = self.loader.val_batch();
            let mut inputs: Vec<&HostTensor> = self.params.iter().collect();
            inputs.push(&b.x);
            inputs.push(&b.t);
            for (m, _) in &self.bn_running {
                inputs.push(m);
            }
            for (_, v) in &self.bn_running {
                inputs.push(v);
            }
            let out = self.engine.execute(&self.model.eval_exe, &inputs)?;
            loss += out[0].data[0] as f64;
            correct += out[1].data[0] as f64;
            total += self.model.batch as f64;
        }
        Ok(((loss / batches as f64) as f32, (correct / total) as f32))
    }

    /// Measured single-GPU work profile for the cluster cost model
    /// (Fig. 5 / Table 1 extrapolation). Uses full-refresh steps for the
    /// factor/inversion components.
    pub fn profile(&self) -> StepProfile {
        // drop warmup samples (first executions pay lazy PJRT init)
        let mean = |v: &[f64]| {
            let skip = (v.len() / 4).min(2);
            let v = &v[skip.min(v.len().saturating_sub(1))..];
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        let t_fwd_bwd = mean(&self.prof_exec_samples);
        let n_params = self.model.total_param_count() as f64;
        // parameters always travel f32; gradients travel at the wire width
        let param_bytes = n_params * 4.0;
        let grad_bytes = n_params * self.cfg.precision.wire_elem_bytes() as f64;
        StepProfile {
            // fwd:bwd ≈ 1:2 for conv nets
            t_forward: t_fwd_bwd / 3.0,
            t_backward: t_fwd_bwd * 2.0 / 3.0,
            t_factors: mean(&self.prof_full_factors),
            t_inverse: mean(&self.prof_full_inverse),
            t_update: mean(&self.prof_update),
            t_extra_bwd: 0.0,
            stats_bytes: mean(&self.prof_full_stats_bytes).max(self.full_stats_bytes()),
            grad_bytes,
            param_bytes,
            n_stats: self.total_stats(),
        }
    }

    /// Analytic per-GPU statistics payload at full refresh (packed
    /// elements × the configured wire width).
    pub fn full_stats_bytes(&self) -> f64 {
        let mut elems = 0usize;
        for l in &self.model.kfac_layers {
            if l.is_bn() {
                elems += 3 * l.channels;
            } else {
                elems += l.a_dim * (l.a_dim + 1) / 2;
                elems += l.g_dim * (l.g_dim + 1) / 2;
            }
        }
        elems as f64 * self.cfg.precision.wire_elem_bytes() as f64
    }

    /// Per-statistic refresh fractions (for Table 2's reduction metric),
    /// weighted by communicated matrix size. 1.0 for optimizers that
    /// publish no statistics.
    pub fn comm_reduction(&self) -> f64 {
        let mut sent = 0.0f64;
        let mut full = 0.0f64;
        for (li, slot) in self.layers.iter().enumerate() {
            let spec = self.opt.stats_spec(&self.model, li);
            if spec.is_empty() {
                continue;
            }
            let fractions = self.opt.refresh_fractions(&self.model, li, &slot.state);
            for (&kind, f) in spec.iter().zip(fractions.into_iter()) {
                let sz = optim::stat_elems(&self.model, li, kind) as f64;
                sent += sz * f;
                full += sz;
            }
        }
        if full == 0.0 {
            1.0
        } else {
            sent / full
        }
    }
}

// --------------------------------------------------- checkpoint/restore
// The SPCK mapping of a training run (see `crate::ckpt` for the
// container format). One checkpoint captures *everything* a resumed run
// needs to be bit-identical to an uninterrupted one: step counter,
// params, velocity, BN running stats, per-layer optimizer state, and the
// full data-pipeline cursor (RNG streams, per-lane transform state, any
// drained in-flight prefetch batch). Quantities that are pure functions
// of the step — schedule lr/momentum, 1mc sampling seeds — need no
// sections.

fn precision_code(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::Mixed => 1,
    }
}

/// `Checkpoint::push` with the format's section cap enforced eagerly, so
/// an oversized model fails at save time with a named section instead of
/// at parse time with a corrupt file.
fn push_checked(
    ck: &mut Checkpoint,
    kind: u16,
    tag: u16,
    payload: Vec<u8>,
    what: &str,
) -> Result<()> {
    ensure!(
        payload.len() as u64 <= MAX_SECTION as u64,
        "{what} section is {} bytes — over the {MAX_SECTION}-byte SPCK section cap",
        payload.len()
    );
    ck.push(kind, tag, payload);
    Ok(())
}

impl Trainer {
    fn lanes(&self) -> usize {
        self.cfg.workers.max(1) * self.cfg.grad_accum.max(1)
    }

    /// [`ckpt::params_fnv`] over the current parameters in canonical
    /// order — the run's one-hash identity.
    pub fn params_digest(&self) -> u32 {
        ckpt::params_fnv(&self.params)
    }

    /// Serialize the full training state. `&mut` because the data
    /// pipeline drains any in-flight prefetch into its stash (the
    /// snapshot must include it; training then consumes the stash, so
    /// the save is bitwise-neutral to the run that continues).
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        ensure!(self.params.len() <= u16::MAX as usize, "too many params for SPCK tags");
        let loader_ck = self.loader.checkpoint_state()?;
        let mut ck = Checkpoint::new();

        let meta = ckpt::Meta {
            model: self.model.name.clone(),
            opt: self.opt.name().to_string(),
            precision: precision_code(self.cfg.precision),
            lanes: self.lanes() as u32,
            nparams: self.params.len() as u32,
            nlayers: self.layers.len() as u32,
            nbn: self.bn_running.len() as u32,
            seed: self.cfg.seed,
            step: self.step,
            params_fnv: self.params_digest(),
        };
        ck.push(SEC_META, 0, meta.encode());

        for (pi, p) in self.params.iter().enumerate() {
            let mut w = ByteWriter::new();
            w.f32s(&p.data);
            push_checked(&mut ck, SEC_PARAM, pi as u16, w.into_inner(), "param")?;
        }
        for (pi, v) in self.velocity.iter().enumerate() {
            let mut w = ByteWriter::new();
            w.f32s(&v.data);
            push_checked(&mut ck, SEC_VELOCITY, pi as u16, w.into_inner(), "velocity")?;
        }
        for (bi, (mean, var)) in self.bn_running.iter().enumerate() {
            let mut w = ByteWriter::new();
            w.u32(mean.data.len() as u32);
            w.f32s(&mean.data);
            w.f32s(&var.data);
            push_checked(&mut ck, SEC_BN, bi as u16, w.into_inner(), "bn")?;
        }
        for (li, slot) in self.layers.iter().enumerate() {
            let payload = self.opt.state_save(&self.model, li, &slot.state);
            push_checked(&mut ck, SEC_LAYER, li as u16, payload, "layer state")?;
        }

        let mut w = ByteWriter::new();
        w.rng_state(loader_ck.rng);
        w.rng_state(loader_ck.val_rng);
        w.u8(loader_ck.stash.is_some() as u8);
        ck.push(SEC_LOADER, 0, w.into_inner());
        for (g, chain) in loader_ck.chains.iter().enumerate() {
            push_checked(&mut ck, SEC_CHAIN, g as u16, chain.clone(), "lane chain")?;
        }
        if let Some(stash) = &loader_ck.stash {
            for (g, b) in stash.iter().enumerate() {
                let mut w = ByteWriter::new();
                b.state_save(&mut w);
                push_checked(&mut ck, SEC_STASH, g as u16, w.into_inner(), "stash batch")?;
            }
        }
        Ok(ck)
    }

    /// Write the current state atomically into `dir` as
    /// `ckpt-{step:012}.spck` and emit a `checkpoint_saved` event.
    pub fn save_checkpoint(&mut self, dir: &Path) -> Result<PathBuf> {
        let ck = self.checkpoint()?;
        let path = ckpt::step_path(dir, self.step);
        ckpt::write_atomic(&path, &ck)?;
        obs::emit(
            "checkpoint_saved",
            vec![
                ("step", Json::from(self.step as usize)),
                ("path", Json::from(path.display().to_string())),
            ],
        );
        Ok(path)
    }

    /// Restore a parsed checkpoint into this trainer. The run
    /// configuration (model, optimizer, precision, lane count, seed)
    /// must match the one that produced the checkpoint — the META
    /// fingerprint is validated before any state is touched. After a
    /// successful restore the trainer is bit-identical to the saved run
    /// at its `step` boundary, including a cured poisoned data pipeline
    /// (the fault-recovery path restores over a live trainer).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        let meta = ckpt::Meta::of(ck)?;
        ensure!(
            meta.model == self.model.name,
            "checkpoint is for model '{}', run is configured for '{}'",
            meta.model,
            self.model.name
        );
        ensure!(
            meta.opt == self.opt.name(),
            "checkpoint is for optimizer '{}', run is configured for '{}'",
            meta.opt,
            self.opt.name()
        );
        ensure!(
            meta.precision == precision_code(self.cfg.precision),
            "checkpoint precision ({}) differs from the run's ({:?})",
            if meta.precision == 0 { "f32" } else { "mixed" },
            self.cfg.precision
        );
        let lanes = meta.lanes as usize;
        ensure!(
            lanes == self.lanes(),
            "checkpoint has {lanes} lanes, run is configured for {} \
             (workers × grad-accum must factorize the same lane total)",
            self.lanes()
        );
        ensure!(
            meta.seed == self.cfg.seed,
            "checkpoint was produced with --seed {}, run uses {}",
            meta.seed,
            self.cfg.seed
        );
        let (nparams, nlayers, nbn) =
            (meta.nparams as usize, meta.nlayers as usize, meta.nbn as usize);
        ensure!(
            nparams == self.params.len()
                && nlayers == self.layers.len()
                && nbn == self.bn_running.len(),
            "checkpoint geometry ({nparams} params / {nlayers} layers / {nbn} bn) does not \
             match the model ({} / {} / {})",
            self.params.len(),
            self.layers.len(),
            self.bn_running.len()
        );

        for pi in 0..nparams {
            let bytes = ck.require(SEC_PARAM, pi as u16, "param section")?;
            let mut r = ByteReader::new(bytes);
            let data = r.f32s(self.params[pi].data.len())?;
            r.finish()?;
            self.params[pi].data = data;
        }
        for pi in 0..nparams {
            let bytes = ck.require(SEC_VELOCITY, pi as u16, "velocity section")?;
            let mut r = ByteReader::new(bytes);
            let data = r.f32s(self.velocity[pi].data.len())?;
            r.finish()?;
            self.velocity[pi].data = data;
        }
        for bi in 0..nbn {
            let bytes = ck.require(SEC_BN, bi as u16, "bn section")?;
            let mut r = ByteReader::new(bytes);
            let ch = r.u32()? as usize;
            ensure!(
                ch == self.bn_running[bi].0.data.len(),
                "bn section {bi} has {ch} channels, model expects {}",
                self.bn_running[bi].0.data.len()
            );
            let mean = r.f32s(ch)?;
            let var = r.f32s(ch)?;
            r.finish()?;
            self.bn_running[bi].0.data = mean;
            self.bn_running[bi].1.data = var;
        }
        for li in 0..nlayers {
            let bytes = ck.require(SEC_LAYER, li as u16, "layer-state section")?;
            self.opt
                .state_load(&self.model, li, &mut self.layers[li].state, bytes)
                .with_context(|| format!("layer {li} state"))?;
        }

        let mut r = ByteReader::new(ck.require(SEC_LOADER, 0, "loader section")?);
        let rng = r.rng_state()?;
        let val_rng = r.rng_state()?;
        let has_stash = match r.u8()? {
            0 => false,
            1 => true,
            f => bail!("bad stash flag {f} in loader section"),
        };
        r.finish()?;
        let chain_secs = ck.sections_of(SEC_CHAIN);
        for (g, (tag, _)) in chain_secs.iter().enumerate() {
            ensure!(*tag as usize == g, "lane-chain sections are not contiguous from 0");
        }
        let chains: Vec<Vec<u8>> = chain_secs.iter().map(|(_, b)| b.to_vec()).collect();
        let stash = if has_stash {
            let mut v = Vec::with_capacity(chains.len());
            for g in 0..chains.len() {
                let mut r = ByteReader::new(ck.require(SEC_STASH, g as u16, "stash section")?);
                let b = Batch::state_load(&mut r)?;
                r.finish()?;
                v.push(b);
            }
            Some(v)
        } else {
            None
        };
        self.loader.restore_state(LoaderCkpt { rng, val_rng, chains, stash })?;

        self.step = meta.step;
        ensure!(
            self.params_digest() == meta.params_fnv,
            "restored parameters do not hash to the checkpoint's digest — corrupt sections?"
        );
        self.log.final_params_fnv = Some(meta.params_fnv);
        Ok(())
    }

    /// Read + restore one checkpoint file and emit a `resumed` event.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let ck = ckpt::read_file(path)?;
        self.restore(&ck).with_context(|| format!("restoring {}", path.display()))?;
        obs::emit(
            "resumed",
            vec![
                ("step", Json::from(self.step as usize)),
                ("path", Json::from(path.display().to_string())),
            ],
        );
        Ok(())
    }

    /// Resume from the highest-step checkpoint under `dir`, if any.
    /// Returns the resumed step, or `None` when the directory holds no
    /// checkpoint (a fresh run).
    pub fn resume_latest(&mut self, dir: &Path) -> Result<Option<u64>> {
        match ckpt::latest(dir)? {
            Some(path) => {
                self.resume_from(&path)?;
                Ok(Some(self.step))
            }
            None => Ok(None),
        }
    }

    /// Fault recovery: after a fatal step error (e.g. the proc engine's
    /// respawn budget exhausted with zero survivors), relaunch the
    /// worker pool and rewind to the latest checkpoint under `dir`.
    /// Returns the step training resumes from. Unlike [`resume_latest`],
    /// a missing checkpoint is an error — there is nothing sound to
    /// continue from.
    ///
    /// [`resume_latest`]: Trainer::resume_latest
    pub fn recover_from_latest(&mut self, dir: &Path) -> Result<u64> {
        if self.proc.is_some() {
            // the old transport died with the fatal; a fresh pool picks
            // up membership from scratch
            self.proc =
                Some(ProcComm::launch(self.cfg.workers.max(1), self.cfg.precision, &self.cfg.proc)?);
        }
        let path = ckpt::latest(dir)?
            .with_context(|| format!("no checkpoint under {} to recover from", dir.display()))?;
        self.resume_from(&path)?;
        Ok(self.step)
    }
}

// ------------------------------------------------------ shared helpers
// One math path for both engines: run_lane is called by the sequential
// coordinator loop and by the dist worker threads, so the two schedules
// produce bit-identical results by construction.

/// Stage 1-2 for one lane: run the step executable, flatten the lane's
/// gradients, construct the planned statistics in plan order (via
/// `Preconditioner::build_stat`) and hand each to `on_factor` the moment
/// it is ready (the threaded engine publishes them to the ring there —
/// Alg. 3's overlap point).
#[allow(clippy::too_many_arguments)]
fn run_lane(
    engine: &dyn Executor,
    model: &ModelManifest,
    exe: &str,
    opt: &dyn Preconditioner,
    plan: &[(usize, StatKind)],
    params: &[HostTensor],
    batch: &Batch,
    seed: Option<u32>,
    mut on_factor: impl FnMut(usize, Mat),
) -> Result<(LaneOut, Vec<f32>)> {
    let mut inputs: Vec<&HostTensor> = params.iter().collect();
    inputs.push(&batch.x);
    inputs.push(&batch.t);
    // lint:allow(determinism) -- exec wall-time telemetry, never step math
    let te = Instant::now();
    let exec_span = obs::span("exec_fwd_bwd", Cat::Compute);
    let outs = engine.execute_seeded(exe, &inputs, seed).context("step exec")?;
    drop(exec_span);
    let t_exec = te.elapsed().as_secs_f64();

    // flatten grads (outputs 2..2+nparams) in canonical param order
    let nparams = params.len();
    let mut grads: Vec<f32> = Vec::with_capacity(model.total_param_count());
    for pi in 0..nparams {
        grads.extend_from_slice(&outs[2 + pi].data);
    }

    // BN batch statistics per bn_order entry
    let mut bn_stats = Vec::with_capacity(model.bn_order.len());
    for bname in &model.bn_order {
        let mi = model.output_index("bn_mean", Some(bname)).context("bn_mean index")?;
        let vi = model.output_index("bn_var", Some(bname)).context("bn_var index")?;
        bn_stats.push((outs[mi].data.clone(), outs[vi].data.clone()));
    }

    // statistics construction for planned refreshes
    // lint:allow(determinism) -- factor wall-time telemetry, never step math
    let tf = Instant::now();
    for (item, &(li, kind)) in plan.iter().enumerate() {
        // the compute span closes before on_factor: publishing to the
        // ring is comm and must not nest inside a compute interval (the
        // overlap accountant would miscount same-thread comm as hidden)
        let mat = {
            let _f = obs::span("factor_build", Cat::Compute).arg("layer", li as f64);
            opt.build_stat(engine, model, li, kind, &outs)?
        };
        on_factor(item, mat);
    }
    let t_factors = tf.elapsed().as_secs_f64();

    let lo = LaneOut {
        loss: outs[0].data[0] as f64,
        ncorrect: outs[1].data[0] as f64,
        bn_stats,
        t_exec,
        t_factors,
    };
    Ok((lo, grads))
}

/// The body of one dist worker thread: Stage 1-2 compute with
/// publish-as-ready factor statistics, the gradient AllReduce post
/// (lanes moved into the ring — no copy), Stage 4a reduce+invert for
/// owned layers (overlapping slower workers' compute), then the
/// AllReduce finish (one mean copy back per rank). On error the worker
/// keeps the collective protocol alive with zero payloads so its peers
/// never deadlock — the step then fails cleanly at the join.
#[allow(clippy::too_many_arguments)]
fn worker_step(
    engine: &dyn Executor,
    ring: &RingComm,
    model: &ModelManifest,
    opt: &dyn Preconditioner,
    t: u64,
    plan: &[(usize, StatKind)],
    layer_items: &[Vec<(usize, StatKind)>],
    params: &[HostTensor],
    nparams_total: usize,
    lanes_n: usize,
    exe: &str,
    seeds: &[Option<u32>],
    my_batches: Vec<(usize, Batch)>,
    group: Vec<(usize, &mut LayerSlot)>,
) -> Result<WorkerYield> {
    let mut first_err: Option<anyhow::Error> = None;
    let mut lane_outs: Vec<(usize, LaneOut)> = Vec::with_capacity(my_batches.len());
    let mut grad_lanes: Vec<(usize, Vec<f32>)> = Vec::with_capacity(my_batches.len());

    // Stage 1-2: compute lanes, publishing each factor as it is built
    for (g, batch) in my_batches {
        let mut published = 0usize;
        let res = if first_err.is_none() {
            Some(run_lane(
                engine,
                model,
                exe,
                opt,
                plan,
                params,
                &batch,
                seeds[g],
                |item, m| {
                    ring.publish_stat(item, g, m);
                    published += 1;
                },
            ))
        } else {
            None
        };
        match res {
            Some(Ok((lo, grads))) => {
                lane_outs.push((g, lo));
                grad_lanes.push((g, grads));
            }
            other => {
                if let Some(Err(e)) = other {
                    first_err = Some(e);
                }
                // keep peers unblocked: zero payloads for this lane
                for (item, &(li, kind)) in plan.iter().enumerate().skip(published) {
                    let (r, c) = opt.stat_shape(model, li, kind);
                    ring.publish_stat(item, g, Mat::zeros(r, c));
                }
                lane_outs.push((g, LaneOut::default()));
                grad_lanes.push((g, vec![0.0; nparams_total]));
            }
        }
    }

    // Stage 3 send: move gradient lanes into the AllReduce round
    let participating = !grad_lanes.is_empty();
    ring.grad_post(std::mem::take(&mut grad_lanes), lanes_n);

    // Stage 4a: reduce + invert owned layers (overlaps peers' compute)
    // lint:allow(determinism) -- stage wall-time telemetry, never step math
    let t_inv0 = Instant::now();
    for (li, slot) in group {
        let items = &layer_items[li];
        if items.is_empty() {
            continue;
        }
        let mut mats: Vec<(StatKind, Mat)> = Vec::with_capacity(items.len());
        for &(idx, kind) in items {
            mats.push((kind, ring.reduce_stat(idx, kind.class())));
        }
        if first_err.is_none() {
            let _inv = obs::span("invert", Cat::Compute).arg("layer", li as f64);
            if let Err(e) = opt.refresh(engine, model, li, &mut slot.state, t, mats) {
                first_err = Some(e);
            }
        }
    }
    let t_inverse = t_inv0.elapsed().as_secs_f64();

    // Stage 3 finish: chunked reduce, then this rank's mean copy
    let grads = if participating { ring.grad_finish() } else { Vec::new() };
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(WorkerYield { lane_outs, grads, t_inverse })
}
