//! The SP-NGD trainer: Algorithm 3 over simulated GPU workers.

use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::collectives::comm::{SimComm, StatClass};
use crate::collectives::cost::StepProfile;
use crate::data::{Augment, AugmentCfg, Batch, SynthDataset};
use crate::kfac::bn::{BnFisher, BnFullFisher};
use crate::kfac::damping::pi_split;
use crate::linalg::Mat;
use crate::metrics::{RunLog, StageTimes, StepRecord};
use crate::optim::{rescale_weight, spngd_update, Schedule};
use crate::runtime::{Executor, HostTensor, Manifest, ModelManifest};
use crate::util::rng::Rng;

/// Fisher estimation mode (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fisher {
    /// empirical Fisher captured in the ordinary bwd pass (`emp`)
    Emp,
    /// one-sample Monte-Carlo Fisher — extra backward pass (`1mc`)
    OneMc,
}

/// BatchNorm Fisher mode (§4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BnMode {
    /// unit-wise 2×2 blocks, closed-form inverse (`unitBN`)
    Unit,
    /// full (2C)² Fisher inverted like any factor (`fullBN`)
    Full,
}

/// Optimizer selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optim {
    SpNgd,
    Sgd,
}

#[derive(Clone, Debug)]
pub struct TrainerCfg {
    pub model: String,
    /// simulated GPUs (data-parallel workers)
    pub workers: usize,
    /// micro-steps accumulated per update (extreme-BS mimicry, §7.1)
    pub grad_accum: usize,
    pub fisher: Fisher,
    pub bn_mode: BnMode,
    /// adaptive stale-statistics scheduler (§4.3); false = refresh every step
    pub stale: bool,
    /// similarity threshold α (paper: 0.1)
    pub stale_alpha: f32,
    /// base damping λ
    pub lambda: f32,
    pub schedule: Schedule,
    pub optimizer: Optim,
    /// Normalizing-Weights rescale (Eq. 24) for conv layers
    pub weight_rescale: bool,
    /// trust-ratio clip: per-layer update norm <= clip * ||w|| (0 = off).
    /// Stabilizes the preconditioner when the Fisher collapses near zero
    /// training loss (a regime ImageNet-scale runs never reach).
    pub clip_update_ratio: f32,
    pub augment: AugmentCfg,
    /// BN running-stat EMA momentum
    pub bn_momentum: f32,
    /// half-precision (fp16) wire format for collectives (§5.2's
    /// mixed-precision communication) — affects byte accounting only;
    /// reductions stay f32 in this in-process simulation
    pub fp16_comm: bool,
    pub seed: u64,
}

impl TrainerCfg {
    pub fn effective_batch(&self, per_worker: usize) -> usize {
        self.workers * self.grad_accum * per_worker
    }
}

/// Which statistic of a layer a stale-scheduler entry tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StatKind {
    A,
    G,
    BnF,
}

/// Per-layer coordinator state (owned by `owner` in Stage 4).
struct LayerState {
    /// owning process for the model-parallel Stage 4 (round-robin)
    owner: usize,
    a_stale: StaleStateOpt,
    g_stale: StaleStateOpt,
    /// current reduced factors (owner's copy)
    a: Option<Mat>,
    g: Option<Mat>,
    /// cached damped inverses (padded-bucket sliced back)
    a_inv: Option<HostTensor>,
    g_inv: Option<HostTensor>,
    /// BN state
    bn_fisher: Option<BnFisher>,
    bn_full_inv: Option<Mat>,
}

type StaleStateOpt = super::stale::StaleState;

pub struct Trainer {
    pub cfg: TrainerCfg,
    model: ModelManifest,
    engine: Rc<dyn Executor>,
    comm: SimComm,
    pub params: Vec<HostTensor>,
    velocity: Vec<HostTensor>,
    layers: Vec<LayerState>,
    bn_running: Vec<(HostTensor, HostTensor)>, // (mean, var) per bn_order
    dataset: SynthDataset,
    augments: Vec<Augment>,
    worker_rngs: Vec<Rng>,
    val_rng: Rng,
    step: u64,
    pub log: RunLog,
    // cumulative profile accumulators (full-refresh steps only)
    prof_exec_samples: Vec<f64>,
    prof_full_factors: Vec<f64>,
    prof_full_inverse: Vec<f64>,
    prof_update: Vec<f64>,
    prof_full_stats_bytes: Vec<f64>,
}

impl Trainer {
    pub fn new(
        manifest: Rc<Manifest>,
        engine: Rc<dyn Executor>,
        cfg: TrainerCfg,
        dataset: SynthDataset,
    ) -> Result<Trainer> {
        let model = manifest.model(&cfg.model)?.clone();
        anyhow::ensure!(
            model.input_shape[1..] == [dataset.channels, dataset.h, dataset.w],
            "dataset dims {:?} do not match model input {:?}",
            (dataset.channels, dataset.h, dataset.w),
            model.input_shape,
        );
        let params = manifest.load_init_params(&model)?;
        let velocity = params.iter().map(|p| HostTensor::zeros(p.shape.clone())).collect();
        let mut rng = Rng::new(cfg.seed);
        let worker_rngs: Vec<Rng> = (0..cfg.workers).map(|w| rng.fork(w as u64)).collect();
        let augments = (0..cfg.workers)
            .map(|w| Augment::new(cfg.augment.clone(), cfg.seed ^ (w as u64) << 8))
            .collect();
        let layers = model
            .kfac_layers
            .iter()
            .enumerate()
            .map(|(i, _)| LayerState {
                owner: i % cfg.workers.max(1),
                a_stale: StaleStateOpt::new(cfg.stale_alpha),
                g_stale: StaleStateOpt::new(cfg.stale_alpha),
                a: None,
                g: None,
                a_inv: None,
                g_inv: None,
                bn_fisher: None,
                bn_full_inv: None,
            })
            .collect();
        let bn_running = model
            .bn_order
            .iter()
            .map(|n| {
                let c = model.layer(n).map(|l| l.channels).unwrap_or(0);
                (HostTensor::zeros(vec![c]), HostTensor::new(vec![c], vec![1.0; c]))
            })
            .collect();
        let mut comm = SimComm::new(cfg.workers);
        if cfg.fp16_comm {
            comm.wire_elem_bytes = 2;
        }
        Ok(Trainer {
            val_rng: rng.fork(0xEA1),
            cfg,
            model,
            engine,
            comm,
            params,
            velocity,
            layers,
            bn_running,
            dataset,
            augments,
            worker_rngs,
            step: 0,
            log: RunLog::default(),
            prof_exec_samples: Vec::new(),
            prof_full_factors: Vec::new(),
            prof_full_inverse: Vec::new(),
            prof_update: Vec::new(),
            prof_full_stats_bytes: Vec::new(),
        })
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    pub fn comm(&self) -> &SimComm {
        &self.comm
    }

    fn step_exe(&self) -> &str {
        match self.cfg.fisher {
            Fisher::Emp => &self.model.step_emp,
            Fisher::OneMc => &self.model.step_1mc,
        }
    }

    /// Is an NGD statistic refresh due this step for a given scheduler?
    fn ngd(&self) -> bool {
        self.cfg.optimizer == Optim::SpNgd
    }

    /// One SP-NGD training step (Alg. 3 + grad accumulation).
    pub fn step(&mut self) -> Result<StepRecord> {
        self.step += 1;
        let t = self.step;
        let t_start = Instant::now();
        let w = self.cfg.workers;
        let nparams = self.params.len();

        // ------------------------------------------------ refresh plan
        // Which statistics get refreshed this step (Alg. 1's `t == t_X`)?
        let mut plan: Vec<(usize, StatKind)> = Vec::new();
        if self.ngd() {
            for (li, l) in self.layers.iter_mut().enumerate() {
                let ml = &self.model.kfac_layers[li];
                let due_always = !self.cfg.stale;
                if ml.is_bn() {
                    if due_always || l.a_stale.due(t) {
                        plan.push((li, StatKind::BnF));
                    } else {
                        l.a_stale.note_skip();
                    }
                } else {
                    if due_always || l.a_stale.due(t) {
                        plan.push((li, StatKind::A));
                    } else {
                        l.a_stale.note_skip();
                    }
                    if due_always || l.g_stale.due(t) {
                        plan.push((li, StatKind::G));
                    } else {
                        l.g_stale.note_skip();
                    }
                }
            }
        }

        // ------------------------------------ Stages 1-2: compute (data ∥)
        let mut grad_accum: Vec<Vec<f32>> = vec![Vec::new(); w];
        let mut factor_accum: Vec<Vec<Mat>> = vec![Vec::new(); w];
        let mut loss_sum = 0.0f64;
        let mut ncorrect_sum = 0.0f64;
        let mut bn_mean_acc: Vec<Vec<f32>> = Vec::new();
        let mut bn_var_acc: Vec<Vec<f32>> = Vec::new();
        let mut t_step_exec = 0.0f64;
        let mut t_factors = 0.0f64;

        let micro = self.cfg.grad_accum.max(1);
        for m in 0..micro {
            // draw per-worker batches through the augmentation pipeline
            let batches: Vec<Batch> = (0..w)
                .map(|wi| {
                    let b = self.dataset.batch(self.model.batch, &mut self.worker_rngs[wi]);
                    self.augments[wi].apply(b)
                })
                .collect();

            // Stage 1+2 compute: every worker runs the step executable.
            // Simulated GPUs share this CPU, so execution is sequential;
            // per-worker durations are recorded individually and the
            // cluster cost model supplies the parallel semantics.
            let exe = self.step_exe().to_string();
            let seed_base = (t as u32) << 8 | m as u32;
            let mut outs: Vec<Vec<HostTensor>> = Vec::with_capacity(w);
            for wi in 0..w {
                let mut inputs: Vec<&HostTensor> = self.params.iter().collect();
                inputs.push(&batches[wi].x);
                inputs.push(&batches[wi].t);
                let seed = match self.cfg.fisher {
                    Fisher::OneMc => Some(seed_base ^ (wi as u32).wrapping_mul(0x9E37)),
                    Fisher::Emp => None,
                };
                let te = Instant::now();
                let o = self
                    .engine
                    .execute_seeded(&exe, &inputs, seed)
                    .context("step exec")?;
                let dt = te.elapsed().as_secs_f64();
                t_step_exec += dt;
                self.prof_exec_samples.push(dt);
                outs.push(o);
            }

            // accumulate loss/acc/grads
            for (wi, o) in outs.iter().enumerate() {
                loss_sum += o[0].data[0] as f64;
                ncorrect_sum += o[1].data[0] as f64;
                // flatten grads (outputs 2..2+nparams)
                if grad_accum[wi].is_empty() {
                    grad_accum[wi] = vec![0.0; self.model.total_param_count()];
                }
                let mut off = 0;
                for pi in 0..nparams {
                    let g = &o[2 + pi];
                    for (dst, src) in
                        grad_accum[wi][off..off + g.data.len()].iter_mut().zip(g.data.iter())
                    {
                        *dst += *src;
                    }
                    off += g.data.len();
                }
            }

            // BN batch stats (mean over workers, accumulated over micro)
            for (bi, bname) in self.model.bn_order.clone().iter().enumerate() {
                let mi = self.model.output_index("bn_mean", Some(bname)).unwrap();
                let vi = self.model.output_index("bn_var", Some(bname)).unwrap();
                let c = outs[0][mi].data.len();
                if bn_mean_acc.len() <= bi {
                    bn_mean_acc.push(vec![0.0; c]);
                    bn_var_acc.push(vec![0.0; c]);
                }
                for o in &outs {
                    for i in 0..c {
                        bn_mean_acc[bi][i] += o[mi].data[i];
                        bn_var_acc[bi][i] += o[vi].data[i];
                    }
                }
            }

            // statistics construction for planned refreshes (per worker)
            if !plan.is_empty() {
                let tf = Instant::now();
                let plan_ref = &plan;
                let model = &self.model;
                let engine2 = self.engine.clone();
                let bn_mode = self.cfg.bn_mode;
                let outs_ref = &outs;
                let per_worker: Vec<Result<Vec<Mat>>> = (0..w).map(|wi| {
                    let o = &outs_ref[wi];
                    let mut mats = Vec::with_capacity(plan_ref.len());
                    for &(li, kind) in plan_ref {
                        let ml = &model.kfac_layers[li];
                        let mat = match kind {
                            StatKind::A => {
                                let ti = model
                                    .output_index("a_tap", Some(&ml.name))
                                    .context("a_tap index")?;
                                let f = engine2.execute(&ml.factor_a, &[&o[ti]])?;
                                f[0].as_mat()
                            }
                            StatKind::G => {
                                let ti = model
                                    .output_index("g_tap", Some(&ml.name))
                                    .context("g_tap index")?;
                                let tap = &o[ti];
                                let f = if ml.kind == "conv" {
                                    let t2 = tap.nchw_to_rows_channels();
                                    engine2.execute(&ml.factor_g, &[&t2])?
                                } else {
                                    engine2.execute(&ml.factor_g, &[tap])?
                                };
                                f[0].as_mat()
                            }
                            StatKind::BnF => {
                                let gi = model
                                    .output_index("g_gamma", Some(&ml.name))
                                    .context("g_gamma index")?;
                                let bi = model
                                    .output_index("g_beta", Some(&ml.name))
                                    .context("g_beta index")?;
                                match bn_mode {
                                    BnMode::Unit => BnFisher::from_taps(
                                        &o[gi].data,
                                        &o[bi].data,
                                        model.batch,
                                        ml.channels,
                                    )
                                    .as_mat(),
                                    BnMode::Full => {
                                        let f = engine2
                                            .execute(&ml.bn_full, &[&o[gi], &o[bi]])?;
                                        f[0].as_mat()
                                    }
                                }
                            }
                        };
                        mats.push(mat);
                    }
                    Ok(mats)
                }).collect();
                t_factors += tf.elapsed().as_secs_f64();
                for (wi, mats) in per_worker.into_iter().enumerate() {
                    let mats = mats.context("factor construction")?;
                    if factor_accum[wi].is_empty() {
                        factor_accum[wi] = mats;
                    } else {
                        for (acc, m2) in factor_accum[wi].iter_mut().zip(mats) {
                            for (a, b) in acc.data.iter_mut().zip(m2.data.iter()) {
                                *a += *b;
                            }
                        }
                    }
                }
            }
        }

        // average accumulations over micro-steps
        let inv_micro = 1.0 / micro as f32;
        for g in grad_accum.iter_mut() {
            for v in g.iter_mut() {
                *v *= inv_micro;
            }
        }
        for mats in factor_accum.iter_mut() {
            for m in mats.iter_mut() {
                for v in m.data.iter_mut() {
                    *v *= inv_micro;
                }
            }
        }

        // ------------------------- Stage 3: gradient AllReduce (mean)
        self.comm.all_reduce_mean(&mut grad_accum);
        let grads_flat = std::mem::take(&mut grad_accum[0]);
        let grads = self.unflatten_grads(&grads_flat);

        // ----------------- Stages 2-3: ReduceScatterV of the statistics
        let reduced: Vec<Mat> = if plan.is_empty() {
            Vec::new()
        } else {
            let classes: Vec<StatClass> = plan
                .iter()
                .map(|&(_, kind)| match kind {
                    StatKind::A => StatClass::A,
                    _ => StatClass::GorF,
                })
                .collect();
            self.comm.reduce_scatter_v(&factor_accum, &classes)
        };

        // ------------------- Stage 4a: model-parallel factor inversion
        let t_inv_start = Instant::now();
        let mut inversion_jobs: Vec<(usize, StatKind, Mat)> = Vec::new();
        for (&(li, kind), mat) in plan.iter().zip(reduced.into_iter()) {
            // scheduler update (Alg. 2) happens at the owner
            let l = &mut self.layers[li];
            match kind {
                StatKind::A => {
                    l.a_stale.refresh(t, &mat);
                    l.a = Some(mat.clone());
                }
                StatKind::G => {
                    l.g_stale.refresh(t, &mat);
                    l.g = Some(mat.clone());
                }
                StatKind::BnF => {
                    l.a_stale.refresh(t, &mat);
                }
            }
            inversion_jobs.push((li, kind, mat));
        }
        // parallel inversion across owners (min(workers, jobs) threads —
        // the model-parallel Stage 4)
        {
            let engine = self.engine.clone();
            let model = &self.model;
            let lambda = self.cfg.lambda;
            let bn_mode = self.cfg.bn_mode;
            // snapshot traces for the π split
            let traces: Vec<(f32, f32)> = inversion_jobs
                .iter()
                .map(|&(li, _, _)| {
                    let l = &self.layers[li];
                    (
                        l.a.as_ref().map(|m| m.trace()).unwrap_or(0.0),
                        l.g.as_ref().map(|m| m.trace()).unwrap_or(0.0),
                    )
                })
                .collect();
            let jobs = &inversion_jobs;
            let results: Vec<Result<InvResult>> = (0..jobs.len()).map(|ji| {
                let (li, kind, ref mat) = jobs[ji];
                let ml = &model.kfac_layers[li];
                match kind {
                    StatKind::BnF if bn_mode == BnMode::Unit => {
                        // closed-form per-channel blocks — nothing to invert
                        let fisher = BnFisher {
                            channels: ml.channels,
                            blocks: (0..ml.channels)
                                .map(|c| {
                                    [mat.data[c * 3], mat.data[c * 3 + 1], mat.data[c * 3 + 2]]
                                })
                                .collect(),
                        };
                        Ok(InvResult::BnUnit(li, fisher))
                    }
                    StatKind::BnF => {
                        let padded =
                            HostTensor::from_mat(mat).pad_square(ml.full_bucket);
                        let damp = HostTensor::scalar(lambda);
                        let out = engine.execute(&ml.invert_full, &[&padded, &damp])?;
                        let inv = out[0].slice_square(2 * ml.channels);
                        Ok(InvResult::BnFull(li, inv.as_mat()))
                    }
                    StatKind::A | StatKind::G => {
                        let (tr_a, tr_g) = traces[ji];
                        let dims = (ml.a_dim as f32, ml.g_dim as f32);
                        let (da, dg) = pi_split_traces(tr_a, dims.0, tr_g, dims.1, lambda);
                        let (exe, bucket, dim, damp) = match kind {
                            StatKind::A => (&ml.invert_a, ml.a_bucket, ml.a_dim, da),
                            _ => (&ml.invert_g, ml.g_bucket, ml.g_dim, dg),
                        };
                        let padded = HostTensor::from_mat(mat).pad_square(bucket);
                        let damp = HostTensor::scalar(damp);
                        let out = engine.execute(exe, &[&padded, &damp])?;
                        let inv = out[0].slice_square(dim);
                        Ok(InvResult::Factor(li, kind, inv))
                    }
                }
            }).collect();
            for r in results {
                match r.context("inversion")? {
                    InvResult::BnUnit(li, f) => self.layers[li].bn_fisher = Some(f),
                    InvResult::BnFull(li, inv) => self.layers[li].bn_full_inv = Some(inv),
                    InvResult::Factor(li, StatKind::A, inv) => {
                        self.layers[li].a_inv = Some(inv)
                    }
                    InvResult::Factor(li, _, inv) => self.layers[li].g_inv = Some(inv),
                }
            }
        }
        let t_inverse = t_inv_start.elapsed().as_secs_f64();

        // ------------------- Stage 4b: preconditioning + weight update
        let t_upd_start = Instant::now();
        let lr = self.cfg.schedule.lr(t) as f32;
        let mom = self.cfg.schedule.momentum(t) as f32;
        self.apply_updates(&grads, lr, mom)?;
        let t_update = t_upd_start.elapsed().as_secs_f64();

        // --------------------------------- Stage 5: AllGatherV(params)
        self.comm.all_gather_v_params(self.model.total_param_count());

        // BN running stats EMA
        let denom = (w * micro) as f32;
        for (bi, (rm, rv)) in self.bn_running.iter_mut().enumerate() {
            if bn_mean_acc.is_empty() {
                break;
            }
            let bm = self.cfg.bn_momentum;
            for i in 0..rm.data.len() {
                rm.data[i] = bm * rm.data[i] + (1.0 - bm) * bn_mean_acc[bi][i] / denom;
                rv.data[i] = bm * rv.data[i] + (1.0 - bm) * bn_var_acc[bi][i] / denom;
            }
        }

        // ------------------------------------------------- bookkeeping
        let comm_step = self.comm.take_step_stats();
        let denom_samples = (w * micro) as f64 * self.model.batch as f64;
        let total_stats = self.total_stats();
        let times = StageTimes {
            t_step_exec,
            t_factors,
            t_inverse,
            t_update,
            t_total: t_start.elapsed().as_secs_f64(),
        };
        // profile capture
        self.prof_update.push(t_update);
        if self.ngd() && plan.len() == total_stats {
            self.prof_full_factors.push(t_factors / (micro * w) as f64);
            self.prof_full_inverse.push(t_inverse);
            self.prof_full_stats_bytes
                .push(comm_step.stats_total() as f64 / micro as f64);
        }
        let rec = StepRecord {
            step: t,
            epoch: self.epoch(),
            loss: (loss_sum / (w * micro) as f64) as f32,
            train_acc: (ncorrect_sum / denom_samples) as f32,
            lr: lr as f64,
            momentum: mom as f64,
            times,
            comm: comm_step,
            refreshed: plan.len(),
            total_stats,
        };
        self.log.push(rec.clone());
        Ok(rec)
    }

    /// Stage-4 layer→process ownership (round-robin, as in §5.1 when
    /// the layer count exceeds the process count).
    pub fn layer_owners(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.owner).collect()
    }

    fn total_stats(&self) -> usize {
        self.model
            .kfac_layers
            .iter()
            .map(|l| if l.is_bn() { 1 } else { 2 })
            .sum()
    }

    pub fn epoch(&self) -> f64 {
        self.cfg.schedule.epoch_of(self.step)
    }

    fn unflatten_grads(&self, flat: &[f32]) -> Vec<HostTensor> {
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0;
        for p in &self.model.params {
            let n: usize = p.shape.iter().product();
            out.push(HostTensor::new(p.shape.clone(), flat[off..off + n].to_vec()));
            off += n;
        }
        out
    }

    /// Stage 4b: per-layer preconditioned updates + momentum + rescale.
    fn apply_updates(&mut self, grads: &[HostTensor], lr: f32, mom: f32) -> Result<()> {
        let nlayers = self.model.kfac_layers.len();
        for li in 0..nlayers {
            let ml = self.model.kfac_layers[li].clone();
            if ml.is_bn() {
                let gi = self.model.param_index(&ml.gamma_param).context("gamma param")?;
                let bi = self.model.param_index(&ml.beta_param).context("beta param")?;
                let (dir_g, dir_b) = if self.ngd() {
                    match self.cfg.bn_mode {
                        BnMode::Unit => {
                            let f = self.layers[li]
                                .bn_fisher
                                .as_ref()
                                .context("bn fisher missing")?;
                            let (g, b) = f.precondition(
                                &grads[gi].data,
                                &grads[bi].data,
                                self.cfg.lambda,
                            );
                            (g, b)
                        }
                        BnMode::Full => {
                            let inv = self.layers[li]
                                .bn_full_inv
                                .as_ref()
                                .context("bn full inverse missing")?;
                            BnFullFisher::apply_inverse(inv, &grads[gi].data, &grads[bi].data)
                        }
                    }
                } else {
                    (grads[gi].data.clone(), grads[bi].data.clone())
                };
                let mut dg = HostTensor::new(grads[gi].shape.clone(), dir_g);
                let mut db = HostTensor::new(grads[bi].shape.clone(), dir_b);
                if !dg.norm().is_finite() {
                    dg = grads[gi].clone();
                }
                if !db.norm().is_finite() {
                    db = grads[bi].clone();
                }
                self.clip_direction(&mut dg, &self.params[gi].clone(), lr);
                self.clip_direction(&mut db, &self.params[bi].clone(), lr);
                spngd_update(&mut self.params[gi], &mut self.velocity[gi], &dg, lr, mom);
                spngd_update(&mut self.params[bi], &mut self.velocity[bi], &db, lr, mom);
            } else {
                let wi = self.model.param_index(&ml.weight_param).context("weight param")?;
                let (m, n) = ml.grad_shape;
                let gmat = grads[wi].clone().reshape(vec![m, n]);
                let mut dir = if self.ngd() {
                    let (ainv, ginv) = {
                        let l = &self.layers[li];
                        (
                            l.a_inv.clone().context("A inverse missing")?,
                            l.g_inv.clone().context("G inverse missing")?,
                        )
                    };
                    let out = self.engine.execute(&ml.precond, &[&ginv, &gmat, &ainv])?;
                    out[0].clone().reshape(grads[wi].shape.clone())
                } else {
                    grads[wi].clone()
                };
                // numerical guard: a degenerate Fisher (possible when the
                // loss approaches zero) can blow up the inverse — fall
                // back to the raw gradient for this step
                if !dir.norm().is_finite() {
                    dir = grads[wi].clone();
                }
                self.clip_direction(&mut dir, &self.params[wi].clone(), lr);
                spngd_update(&mut self.params[wi], &mut self.velocity[wi], &dir, lr, mom);
                // Normalizing Weights (Eq. 24) — conv layers (BN-covered);
                // the FC head keeps its scale (no BN follows it here).
                if self.cfg.weight_rescale && ml.kind == "conv" {
                    rescale_weight(&mut self.params[wi], m);
                }
            }
        }
        Ok(())
    }

    /// Trust-ratio clip (applied to the *preconditioned* direction):
    /// ensures ||lr * dir|| <= clip_update_ratio * ||w||.
    fn clip_direction(&self, dir: &mut HostTensor, w: &HostTensor, lr: f32) {
        let clip = self.cfg.clip_update_ratio;
        if clip <= 0.0 || lr <= 0.0 {
            return;
        }
        let wn = w.norm().max(1e-3);
        let dn = dir.norm() * lr;
        if dn > clip * wn {
            dir.scale_inplace(clip * wn / dn);
        }
    }

    /// Validation over `batches` held-out batches: (loss, accuracy).
    pub fn evaluate(&mut self, batches: usize) -> Result<(f32, f32)> {
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        for _ in 0..batches {
            let b = self.dataset.val_batch(self.model.batch, &mut self.val_rng);
            let mut inputs: Vec<&HostTensor> = self.params.iter().collect();
            inputs.push(&b.x);
            inputs.push(&b.t);
            for (m, _) in &self.bn_running {
                inputs.push(m);
            }
            for (_, v) in &self.bn_running {
                inputs.push(v);
            }
            let out = self.engine.execute(&self.model.eval_exe, &inputs)?;
            loss += out[0].data[0] as f64;
            correct += out[1].data[0] as f64;
            total += self.model.batch as f64;
        }
        Ok(((loss / batches as f64) as f32, (correct / total) as f32))
    }

    /// Measured single-GPU work profile for the cluster cost model
    /// (Fig. 5 / Table 1 extrapolation). Uses full-refresh steps for the
    /// factor/inversion components.
    pub fn profile(&self) -> StepProfile {
        // drop warmup samples (first executions pay lazy PJRT init)
        let mean = |v: &[f64]| {
            let skip = (v.len() / 4).min(2);
            let v = &v[skip.min(v.len().saturating_sub(1))..];
            if v.is_empty() {
                0.0
            } else {
                v.iter().sum::<f64>() / v.len() as f64
            }
        };
        let t_fwd_bwd = mean(&self.prof_exec_samples);
        let param_bytes = self.model.total_param_count() as f64 * 4.0;
        StepProfile {
            // fwd:bwd ≈ 1:2 for conv nets
            t_forward: t_fwd_bwd / 3.0,
            t_backward: t_fwd_bwd * 2.0 / 3.0,
            t_factors: mean(&self.prof_full_factors),
            t_inverse: mean(&self.prof_full_inverse),
            t_update: mean(&self.prof_update),
            t_extra_bwd: 0.0,
            stats_bytes: mean(&self.prof_full_stats_bytes).max(self.full_stats_bytes()),
            grad_bytes: param_bytes,
            param_bytes,
            n_stats: self.total_stats(),
        }
    }

    /// Analytic per-GPU statistics payload at full refresh (packed f32).
    pub fn full_stats_bytes(&self) -> f64 {
        let mut elems = 0usize;
        for l in &self.model.kfac_layers {
            if l.is_bn() {
                elems += 3 * l.channels;
            } else {
                elems += l.a_dim * (l.a_dim + 1) / 2;
                elems += l.g_dim * (l.g_dim + 1) / 2;
            }
        }
        elems as f64 * 4.0
    }

    /// Per-statistic refresh fractions (for Table 2's reduction metric),
    /// weighted by communicated matrix size.
    pub fn comm_reduction(&self) -> f64 {
        let mut sent = 0.0f64;
        let mut full = 0.0f64;
        for (l, ml) in self.layers.iter().zip(self.model.kfac_layers.iter()) {
            if ml.is_bn() {
                let sz = (3 * ml.channels) as f64;
                sent += sz * l.a_stale.refresh_fraction();
                full += sz;
            } else {
                let sa = (ml.a_dim * (ml.a_dim + 1) / 2) as f64;
                let sg = (ml.g_dim * (ml.g_dim + 1) / 2) as f64;
                sent += sa * l.a_stale.refresh_fraction() + sg * l.g_stale.refresh_fraction();
                full += sa + sg;
            }
        }
        if full == 0.0 {
            1.0
        } else {
            sent / full
        }
    }
}

enum InvResult {
    Factor(usize, StatKind, HostTensor),
    BnUnit(usize, BnFisher),
    BnFull(usize, Mat),
}

/// π split from cached traces (both factors' traces are known even when
/// only one refreshed this step).
fn pi_split_traces(tr_a: f32, dim_a: f32, tr_g: f32, dim_g: f32, lambda: f32) -> (f32, f32) {
    let a = Mat::from_vec(1, 1, vec![tr_a / dim_a.max(1.0)]);
    let g = Mat::from_vec(1, 1, vec![tr_g / dim_g.max(1.0)]);
    pi_split(&a, &g, lambda)
}
