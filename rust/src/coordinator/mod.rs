//! The SP-NGD coordinator — the paper's systems contribution (§5, Alg. 3).
//!
//! Drives the hybrid data/model-parallel training step over simulated GPU
//! workers:
//!
//! ```text
//! Stage 1  workers run fwd (+ A-statistics construction)          [data ||]
//! Stage 2  ReduceScatterV(A) overlapped with bwd (+ G, F_unitBN)  [data ||]
//! Stage 3  ReduceScatterV(G, F, grad L)
//! Stage 4  owners invert factors + apply NGD update               [model ||]
//! Stage 5  AllGatherV(w)
//! ```
//!
//! plus the practical-NGD machinery: empirical-vs-1mc Fisher, unit-wise
//! BatchNorm Fisher, and the adaptive stale-statistics scheduler.

//! The step runs on one of two engines sharing the same math path:
//! sequential (workers iterated in the coordinator thread, `SimComm`
//! accounting) or threaded (`dist` subsystem: one OS thread per worker,
//! real ring collectives, comm/compute overlap per Alg. 3) — selected by
//! [`trainer::DistMode`].

pub mod stale;
pub mod trainer;

pub use stale::StaleState;
pub use trainer::{BnMode, DistMode, Fisher, Optim, Trainer, TrainerCfg};
