//! The SP-NGD coordinator — the paper's systems contribution (§5, Alg. 3).
//!
//! Drives the hybrid data/model-parallel training step over simulated GPU
//! workers:
//!
//! ```text
//! Stage 1  workers run fwd (+ A-statistics construction)          [data ||]
//! Stage 2  ReduceScatterV(A) overlapped with bwd (+ G, F_unitBN)  [data ||]
//! Stage 3  ReduceScatterV(G, F, grad L)
//! Stage 4  owners invert factors + apply the update               [model ||]
//! Stage 5  AllGatherV(w)
//! ```
//!
//! The optimizer behind Stage 4 is pluggable: the trainer drives a
//! [`crate::optim::Preconditioner`] trait object (SP-NGD with all its
//! practical machinery, the SGD baseline, LARS, …) composed with an
//! update rule and a schedule by [`TrainerBuilder`].

//! The step runs on one of two engines sharing the same math path:
//! sequential (workers iterated in the coordinator thread, `SimComm`
//! accounting) or threaded (`dist` subsystem: one OS thread per worker,
//! real ring collectives, comm/compute overlap per Alg. 3) — selected by
//! [`trainer::DistMode`].

pub mod builder;
pub mod trainer;

pub use builder::TrainerBuilder;
pub use trainer::{DistMode, Trainer, TrainerCfg};

// re-exported for compatibility: these types moved into `optim` with the
// composable optimizer API
pub use crate::optim::{BnMode, Fisher, StaleState};
