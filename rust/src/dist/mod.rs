//! `dist` — the shared-memory multi-worker execution engine.
//!
//! The sequential coordinator iterates simulated GPUs in one thread, so
//! its Stage 1/2 wall-clock scales linearly with the worker count. This
//! subsystem makes each data-parallel worker a real OS thread with its
//! own [`crate::runtime::Executor`] instance (forked via
//! `Executor::fork_worker`, so scratch arenas never contend) and real
//! shared-memory collectives:
//!
//! ```text
//! coordinator   draw global batch (canonical lane order), plan refreshes
//! worker w      Stage 1+2: exec lanes g ≡ w (mod W); publish each factor
//!               to the statistic board the moment it is built  ── overlap
//! worker w      grad_post (the AllReduce send, lanes moved in) ── overlap
//! worker w      Stage 4a: reduce + invert owned layers while slower
//!               workers are still in their backward/factor phase
//! worker w      grad_finish (chunked reduce → one mean copy per rank)
//! worker w      Stage 4b: precondition + update owned layers
//! coordinator   Stage 5 AllGatherV accounting, loss/BN reductions, log
//! ```
//!
//! [`ring::RingComm`] implements the collectives behind the shared
//! [`crate::collectives::Collective`] trait, byte-for-byte compatible
//! with `SimComm`'s accounting and bit-for-bit compatible with its
//! canonical-lane reductions — the threaded engine therefore produces
//! the same step-by-step losses as the sequential coordinator (see
//! `tests/dist_engine.rs`).

//! The process ladder (`proc`/`membership`/`worker`/`fault`) extends the
//! same `Collective` seam across address spaces: a coordinator drives
//! `spngd worker` processes over the framed Unix-socket wire protocol
//! with explicit membership (`WaitingForMembers → Warmup → RoundStart →
//! RoundEnd`), heartbeat-based death detection, round-boundary
//! re-admission, and deterministic failure injection.

pub mod engine;
pub mod fault;
pub mod membership;
pub mod proc;
pub mod ring;
pub mod worker;

pub use engine::DistEngine;
pub use fault::{Fault, FaultKind, FaultPlan};
pub use membership::{MemberEvent, Membership, MembershipCfg, RespawnPolicy, RunState};
pub use proc::{ProcCfg, ProcComm, WireStats};
pub use ring::{PoisonGuard, RingComm};
