//! Coordinator-side membership for the multi-process transport: the
//! explicit state machine (`WaitingForMembers → Warmup → RoundStart →
//! RoundEnd`) that admits `spngd worker` processes over a Unix-domain
//! socket, watches their heartbeats, detects deaths mid-step with a
//! structured named-rank diagnostic, and re-admits late joiners or
//! respawned replacements at round boundaries (with exponential
//! backoff). Workers are stateless reducers, so "state resync" for a
//! late joiner is exactly the `Welcome` frame: rank, world size, the
//! coordinator's current step, and the heartbeat cadence.

use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::collectives::wire::{self, Frame, Kind, WelcomeMsg};
use crate::util::json::Json;
use crate::util::obs;
use crate::warn_;

/// The coordinator's run state — driven explicitly, logged on every
/// transition, and visible to tests through [`MemberEvent::State`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Waiting for the initial quorum to connect and handshake.
    WaitingForMembers,
    /// Quorum reached; ping/pong liveness probe before the first round.
    Warmup,
    /// A training round (one optimizer step) is in flight.
    RoundStart,
    /// Between rounds: the elastic window where joiners are admitted and
    /// replacements are respawned.
    RoundEnd,
}

impl RunState {
    pub fn name(self) -> &'static str {
        match self {
            RunState::WaitingForMembers => "WaitingForMembers",
            RunState::Warmup => "Warmup",
            RunState::RoundStart => "RoundStart",
            RunState::RoundEnd => "RoundEnd",
        }
    }
}

/// What to do when the membership drops below the target world size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RespawnPolicy {
    /// Spawn replacement workers at the next round boundary, with
    /// exponential backoff, up to `max` attempts; then fail loudly.
    Respawn { max: u32 },
    /// Keep going with the surviving workers (reductions redistribute;
    /// results are unchanged because lanes live on the coordinator).
    Shrink,
    /// Any death is fatal: terminate with the structured diagnostic.
    Strict,
}

/// Membership happenings, drained by tests and surfaced in diagnostics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MemberEvent {
    State { state: &'static str, step: u64 },
    Joined { rank: u32, uid: u64, step: u64 },
    Dead { rank: u32, step: u64, reason: String },
    Respawned { rank: u32, attempt: u32 },
}

/// Knobs the membership machinery runs on (subset of `ProcCfg`).
#[derive(Clone, Debug)]
pub struct MembershipCfg {
    /// Cadence workers must heartbeat at (told to them in `Welcome`).
    pub heartbeat_ms: u64,
    /// Silence longer than this marks a worker dead.
    pub heartbeat_timeout_ms: u64,
    /// A dispatched reduction job unanswered for this long (with
    /// heartbeats still arriving) marks the worker dead — catches
    /// drop-frame faults where the process is alive but useless.
    pub job_timeout_ms: u64,
    /// How long to wait for the initial quorum / respawned replacements.
    pub join_timeout_ms: u64,
    pub respawn: RespawnPolicy,
    /// Backoff before respawn attempt k is `backoff_base_ms << k`.
    pub backoff_base_ms: u64,
}

impl Default for MembershipCfg {
    fn default() -> Self {
        MembershipCfg {
            heartbeat_ms: 50,
            heartbeat_timeout_ms: 1000,
            job_timeout_ms: 5000,
            join_timeout_ms: 10_000,
            respawn: RespawnPolicy::Respawn { max: 2 },
            backoff_base_ms: 20,
        }
    }
}

/// A buffered framed connection to one worker.
pub struct Conn {
    stream: UnixStream,
    buf: Vec<u8>,
}

/// Why a connection-level receive failed.
#[derive(Debug)]
pub enum ConnError {
    /// Peer closed the stream (EOF) — the process exited.
    Closed,
    /// Framing/corruption error; the stream is unrecoverable.
    Wire(wire::WireError),
    Io(ErrorKind),
}

impl std::fmt::Display for ConnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConnError::Closed => write!(f, "connection closed (process exited)"),
            ConnError::Wire(e) => write!(f, "wire error: {e}"),
            ConnError::Io(k) => write!(f, "io error: {k:?}"),
        }
    }
}

impl Conn {
    pub fn new(stream: UnixStream) -> Conn {
        Conn { stream, buf: Vec::new() }
    }

    pub fn send(&mut self, f: &Frame) -> std::io::Result<()> {
        self.stream.write_all(&f.encode())
    }

    /// Pull one frame, waiting up to `wait` for bytes to arrive.
    /// `Ok(None)` = nothing complete within the window.
    pub fn poll_frame(&mut self, wait: Duration) -> Result<Option<Frame>, ConnError> {
        let deadline = Instant::now() + wait;
        loop {
            match Frame::parse(&self.buf) {
                Ok(Some((f, used))) => {
                    self.buf.drain(..used);
                    return Ok(Some(f));
                }
                Ok(None) => {}
                Err(e) => return Err(ConnError::Wire(e)),
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let slice = (deadline - now)
                .min(Duration::from_millis(25))
                .max(Duration::from_millis(1));
            if let Err(e) = self.stream.set_read_timeout(Some(slice)) {
                return Err(ConnError::Io(e.kind()));
            }
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp) {
                Ok(0) => return Err(ConnError::Closed),
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(ConnError::Io(e.kind())),
            }
        }
    }
}

/// How the coordinator launches worker processes (`spngd worker`).
#[derive(Clone, Debug)]
pub struct Spawner {
    /// Path to the `spngd` binary.
    pub program: String,
    pub socket: String,
    /// `SPNGD_FAULT_PLAN` spelling exported to first-generation workers;
    /// respawned replacements never inherit it (a replacement that
    /// immediately re-dies would defeat the recovery it exists to test).
    pub fault_env: String,
}

impl Spawner {
    fn spawn(&self, with_faults: bool) -> std::io::Result<Child> {
        let mut cmd = Command::new(&self.program);
        cmd.arg("worker").arg("--socket").arg(&self.socket);
        cmd.stdin(Stdio::null());
        if with_faults && !self.fault_env.is_empty() {
            cmd.env("SPNGD_FAULT_PLAN", &self.fault_env);
        } else {
            cmd.env_remove("SPNGD_FAULT_PLAN");
        }
        cmd.spawn()
    }
}

/// One admitted worker.
pub struct Member {
    pub rank: u32,
    pub uid: u64,
    pub conn: Conn,
    pub last_seen: Instant,
    /// Present when the coordinator spawned this process itself.
    pub child: Option<Child>,
}

/// The membership set + state machine. Owns the listening socket.
pub struct Membership {
    listener: UnixListener,
    members: Vec<Member>,
    state: RunState,
    step: u64,
    world: u32,
    next_rank: u32,
    free_ranks: Vec<u32>,
    respawn_attempts: u32,
    events: Vec<MemberEvent>,
    fatal: Option<String>,
    cfg: MembershipCfg,
    spawner: Option<Spawner>,
}

const LOG: &str = "dist::membership";

impl Membership {
    /// Bind the coordinator socket. `world` is the target member count.
    pub fn bind(
        socket: &str,
        world: u32,
        cfg: MembershipCfg,
        spawner: Option<Spawner>,
    ) -> std::io::Result<Membership> {
        let _ = std::fs::remove_file(socket);
        let listener = UnixListener::bind(socket)?;
        listener.set_nonblocking(true)?;
        obs::emit(
            "state",
            vec![
                ("state", Json::from(RunState::WaitingForMembers.name())),
                ("step", Json::from(0usize)),
            ],
        );
        Ok(Membership {
            listener,
            members: Vec::new(),
            state: RunState::WaitingForMembers,
            step: 0,
            world,
            next_rank: 0,
            free_ranks: Vec::new(),
            respawn_attempts: 0,
            events: vec![MemberEvent::State { state: RunState::WaitingForMembers.name(), step: 0 }],
            fatal: None,
            cfg,
            spawner,
        })
    }

    pub fn state(&self) -> RunState {
        self.state
    }

    pub fn live(&self) -> usize {
        self.members.len()
    }

    pub fn world(&self) -> u32 {
        self.world
    }

    pub fn members(&self) -> &[Member] {
        &self.members
    }

    /// Drain the event log (tests assert on this).
    pub fn take_events(&mut self) -> Vec<MemberEvent> {
        std::mem::take(&mut self.events)
    }

    /// The first fatal condition, if membership can no longer sustain
    /// the run. The caller must surface this as a hard error.
    pub fn fatal(&self) -> Option<&str> {
        self.fatal.as_deref()
    }

    fn set_state(&mut self, s: RunState) {
        if self.state != s {
            self.state = s;
            self.events.push(MemberEvent::State { state: s.name(), step: self.step });
            obs::emit(
                "state",
                vec![
                    ("state", Json::from(s.name())),
                    ("step", Json::from(self.step as usize)),
                ],
            );
        }
    }

    fn next_free_rank(&mut self) -> u32 {
        if let Some(r) = self.free_ranks.pop() {
            return r;
        }
        let r = self.next_rank;
        self.next_rank += 1;
        r
    }

    /// Accept and handshake every pending connection. Joiners mid-round
    /// simply wait in the accept queue until the next boundary calls
    /// this. Returns how many members were admitted.
    pub fn accept_pending(&mut self) -> usize {
        let mut admitted = 0;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.admit(stream) {
                        admitted += 1;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    warn_!(LOG, "accept failed: {e}");
                    break;
                }
            }
        }
        admitted
    }

    /// Hello/Welcome handshake on a fresh connection.
    fn admit(&mut self, stream: UnixStream) -> bool {
        if stream.set_nonblocking(false).is_err() {
            return false;
        }
        let mut conn = Conn::new(stream);
        let hello = match conn.poll_frame(Duration::from_millis(self.cfg.join_timeout_ms.min(500)))
        {
            Ok(Some(f)) if f.kind == Kind::Hello => f,
            Ok(_) => {
                warn_!(LOG, "joiner sent no Hello; rejected");
                return false;
            }
            Err(e) => {
                warn_!(LOG, "joiner handshake failed: {e}");
                return false;
            }
        };
        let uid = match wire::decode_hello(&hello) {
            Ok(u) => u,
            Err(e) => {
                warn_!(LOG, "joiner Hello malformed: {e}");
                return false;
            }
        };
        let rank = self.next_free_rank();
        let welcome = wire::encode_welcome(WelcomeMsg {
            rank,
            world: self.world,
            step: self.step,
            heartbeat_ms: self.cfg.heartbeat_ms as u32,
        });
        if let Err(e) = conn.send(&welcome) {
            warn_!(LOG, "welcome to rank {rank} failed: {e}");
            self.free_ranks.push(rank);
            return false;
        }
        self.events.push(MemberEvent::Joined { rank, uid, step: self.step });
        obs::emit(
            "joined",
            vec![
                ("rank", Json::from(rank as usize)),
                ("uid", Json::from(uid as usize)),
                ("step", Json::from(self.step as usize)),
            ],
        );
        self.members.push(Member { rank, uid, conn, last_seen: Instant::now(), child: None });
        self.members.sort_by_key(|m| m.rank);
        true
    }

    /// Spawn `n` worker processes through the configured spawner.
    pub fn spawn_workers(&mut self, n: usize, with_faults: bool) -> std::io::Result<Vec<Child>> {
        let spawner = self
            .spawner
            .clone()
            .ok_or_else(|| std::io::Error::new(ErrorKind::NotFound, "no spawner configured"))?;
        (0..n).map(|_| spawner.spawn(with_faults)).collect()
    }

    /// `WaitingForMembers`: block until the target world size is
    /// reached or the join timeout expires (a structured error).
    pub fn wait_for_members(&mut self, mut children: Vec<Child>) -> Result<(), String> {
        self.set_state(RunState::WaitingForMembers);
        let deadline = Instant::now() + Duration::from_millis(self.cfg.join_timeout_ms);
        while self.live() < self.world as usize {
            self.accept_pending();
            if self.live() >= self.world as usize {
                break;
            }
            if Instant::now() >= deadline {
                for c in &mut children {
                    let _ = c.kill();
                }
                return Err(format!(
                    "WaitingForMembers: {}/{} workers joined within {} ms",
                    self.live(),
                    self.world,
                    self.cfg.join_timeout_ms
                ));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // hand child ownership to the members that connected (uid = pid)
        for child in children {
            let pid = child.id() as u64;
            if let Some(m) = self.members.iter_mut().find(|m| m.uid == pid) {
                m.child = Some(child);
            }
        }
        Ok(())
    }

    /// `Warmup`: ping/pong probe of every member; anyone that fails to
    /// answer is marked dead before the first round starts.
    pub fn warmup(&mut self) -> Result<(), String> {
        self.set_state(RunState::Warmup);
        let ping = Frame::control(Kind::Ping);
        let timeout = Duration::from_millis(self.cfg.heartbeat_timeout_ms);
        let mut dead: Vec<(u32, String)> = Vec::new();
        for m in &mut self.members {
            let r = match m.conn.send(&ping) {
                Err(e) => Err(format!("ping send failed: {e}")),
                Ok(()) => loop {
                    match m.conn.poll_frame(timeout) {
                        Ok(Some(f)) if f.kind == Kind::Pong => break Ok(()),
                        Ok(Some(f)) if f.kind == Kind::Heartbeat => continue,
                        Ok(Some(f)) => break Err(format!("unexpected {:?} during warmup", f.kind)),
                        Ok(None) => break Err(format!("no Pong within {timeout:?}")),
                        Err(e) => break Err(e.to_string()),
                    }
                },
            };
            if let Err(reason) = r {
                dead.push((m.rank, reason));
            } else {
                m.last_seen = Instant::now();
            }
        }
        for (rank, reason) in dead {
            self.mark_dead(rank, &reason);
        }
        if self.live() == 0 {
            return Err(self.fatal.clone().unwrap_or_else(|| "warmup lost all workers".into()));
        }
        Ok(())
    }

    /// Broadcast `RoundStart(step)`. Send failures mark the member dead.
    pub fn round_start(&mut self, step: u64) {
        self.step = step;
        self.set_state(RunState::RoundStart);
        self.broadcast(wire::encode_step(Kind::RoundStart, step));
    }

    /// Broadcast `RoundEnd(step)`, then run the elastic window: admit
    /// late joiners and, if below target, apply the respawn policy.
    pub fn round_end(&mut self, step: u64) {
        self.step = step;
        self.set_state(RunState::RoundEnd);
        self.broadcast(wire::encode_step(Kind::RoundEnd, step));
        self.accept_pending();
        if self.live() < self.world as usize {
            self.recover();
        }
    }

    fn broadcast(&mut self, f: Frame) {
        let mut dead: Vec<(u32, String)> = Vec::new();
        for m in &mut self.members {
            if let Err(e) = m.conn.send(&f) {
                dead.push((m.rank, format!("send {:?} failed: {e}", f.kind)));
            }
        }
        for (rank, reason) in dead {
            self.mark_dead(rank, &reason);
        }
    }

    /// Apply the respawn policy when membership is below target.
    fn recover(&mut self) {
        let missing = self.world as usize - self.live();
        match self.cfg.respawn {
            RespawnPolicy::Shrink => {
                if self.live() == 0 {
                    self.fatal =
                        Some(format!("step {}: every worker is dead (policy Shrink)", self.step));
                }
            }
            RespawnPolicy::Strict => {
                self.fatal = Some(format!(
                    "step {}: {missing} worker(s) dead under policy Strict",
                    self.step
                ));
            }
            RespawnPolicy::Respawn { max } => {
                if self.spawner.is_none() {
                    // externally-launched workers: wait for re-connects only
                    if self.live() == 0 {
                        self.fatal = Some(format!(
                            "step {}: every worker is dead and no spawner is configured",
                            self.step
                        ));
                    }
                    return;
                }
                while self.live() < self.world as usize {
                    if self.respawn_attempts >= max {
                        self.fatal = Some(format!(
                            "step {}: {} respawn attempt(s) exhausted, {}/{} workers live",
                            self.step,
                            max,
                            self.live(),
                            self.world
                        ));
                        return;
                    }
                    let attempt = self.respawn_attempts;
                    self.respawn_attempts += 1;
                    let backoff = self.cfg.backoff_base_ms.saturating_mul(1 << attempt.min(10));
                    std::thread::sleep(Duration::from_millis(backoff));
                    let need = self.world as usize - self.live();
                    let children = match self.spawn_workers(need, false) {
                        Ok(c) => c,
                        Err(e) => {
                            self.fatal =
                                Some(format!("step {}: respawn spawn failed: {e}", self.step));
                            return;
                        }
                    };
                    let had: Vec<u32> = self.members.iter().map(|m| m.rank).collect();
                    if self.wait_join(need).is_ok() {
                        for child in children {
                            let pid = child.id() as u64;
                            if let Some(m) = self.members.iter_mut().find(|m| m.uid == pid) {
                                m.child = Some(child);
                            }
                        }
                        let fresh: Vec<u32> = self
                            .members
                            .iter()
                            .map(|m| m.rank)
                            .filter(|r| !had.contains(r))
                            .collect();
                        for rank in fresh {
                            warn_!(LOG, "rank {rank} respawned (attempt {attempt})");
                            self.events.push(MemberEvent::Respawned { rank, attempt });
                            obs::emit(
                                "respawned",
                                vec![
                                    ("rank", Json::from(rank as usize)),
                                    ("attempt", Json::from(attempt as usize)),
                                ],
                            );
                        }
                    }
                }
            }
        }
    }

    fn wait_join(&mut self, need: usize) -> Result<(), String> {
        let deadline = Instant::now() + Duration::from_millis(self.cfg.join_timeout_ms);
        let target = self.live() + need;
        while self.live() < target {
            self.accept_pending();
            if self.live() >= target {
                return Ok(());
            }
            if Instant::now() >= deadline {
                return Err(format!(
                    "replacement join timeout: {}/{} members",
                    self.live(),
                    target
                ));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(())
    }

    /// Remove a member with a structured, named-rank diagnostic; its
    /// rank returns to the free pool for a replacement to claim.
    pub fn mark_dead(&mut self, rank: u32, what: &str) {
        let Some(i) = self.members.iter().position(|m| m.rank == rank) else {
            return;
        };
        let mut m = self.members.remove(i);
        let reason = format!(
            "worker rank {} (uid {}) died at step {} in {}: {what}",
            m.rank,
            m.uid,
            self.step,
            self.state.name()
        );
        warn_!(LOG, "{reason}");
        if let Some(child) = m.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.free_ranks.push(rank);
        self.free_ranks.sort_unstable_by(|a, b| b.cmp(a)); // pop() yields smallest
        obs::emit(
            "dead",
            vec![
                ("rank", Json::from(rank as usize)),
                ("step", Json::from(self.step as usize)),
                ("reason", Json::from(reason.as_str())),
            ],
        );
        self.events.push(MemberEvent::Dead { rank, step: self.step, reason });
    }

    /// Rank of the member at position `i` (positions are rank-ordered
    /// but ephemeral — re-query after any death).
    pub fn rank_at(&self, i: usize) -> u32 {
        self.members[i].rank
    }

    /// Send a frame to the member at position `i` in rank order.
    pub fn send_to(&mut self, i: usize, f: &Frame) -> Result<(), String> {
        match self.members[i].conn.send(f) {
            Ok(()) => Ok(()),
            Err(e) => Err(format!("send {:?} failed: {e}", f.kind)),
        }
    }

    /// Wait for a *data* frame from member `i`: heartbeats are drained
    /// (refreshing liveness), and the wait enforces both the heartbeat
    /// timeout (process gone silent) and the job deadline (process alive
    /// but not answering — e.g. a drop-frame fault).
    pub fn recv_data(&mut self, i: usize, deadline: Instant) -> Result<Frame, String> {
        let hb_timeout = Duration::from_millis(self.cfg.heartbeat_timeout_ms);
        let m = &mut self.members[i];
        loop {
            match m.conn.poll_frame(Duration::from_millis(5)) {
                Ok(Some(f)) => {
                    m.last_seen = Instant::now();
                    match f.kind {
                        Kind::Heartbeat => continue,
                        _ => return Ok(f),
                    }
                }
                Ok(None) => {
                    if m.last_seen.elapsed() > hb_timeout {
                        return Err(format!(
                            "heartbeat timeout ({} ms silent)",
                            m.last_seen.elapsed().as_millis()
                        ));
                    }
                    if Instant::now() >= deadline {
                        return Err(format!(
                            "job timeout ({} ms) with heartbeats still arriving",
                            self.cfg.job_timeout_ms
                        ));
                    }
                }
                Err(e) => return Err(e.to_string()),
            }
        }
    }

    /// Job deadline helper for [`Membership::recv_data`].
    pub fn job_deadline(&self) -> Instant {
        Instant::now() + Duration::from_millis(self.cfg.job_timeout_ms)
    }

    /// Broadcast `Shutdown` and reap spawned children (bounded wait,
    /// then kill). Called from `ProcComm::drop`.
    pub fn shutdown(&mut self) {
        let f = Frame::control(Kind::Shutdown);
        for m in &mut self.members {
            let _ = m.conn.send(&f);
        }
        let grace = Instant::now() + Duration::from_millis(500);
        for m in &mut self.members {
            if let Some(child) = m.child.as_mut() {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < grace => {
                            std::thread::sleep(Duration::from_millis(5))
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
        }
        self.members.clear();
    }
}

impl Drop for Membership {
    fn drop(&mut self) {
        self.shutdown();
        if let Ok(addr) = self.listener.local_addr() {
            if let Some(p) = addr.as_pathname() {
                let _ = std::fs::remove_file(p);
            }
        }
    }
}
