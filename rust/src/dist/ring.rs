//! Shared-memory ring collectives for the threaded dist engine.
//!
//! [`RingComm`] is the concurrent counterpart of `collectives::SimComm`:
//! every data-parallel worker is a real OS thread, and the collectives
//! actually move data between them through chunked shared rounds —
//! publish-as-ready statistic slots (ReduceScatterV), a chunk-striped
//! gradient AllReduce, and an owner-segment AllGatherV. Byte accounting
//! is formula-identical to `SimComm` (per-GPU ring traffic, packed
//! symmetric sizes, wire precision), so the α-β cost model and the
//! Fig. 5/6 series keep working unchanged whichever communicator runs.
//!
//! ## Determinism contract
//!
//! A textbook ring reduce-scatter accumulates partial sums in ring-hop
//! order, which makes results depend on the worker count and the segment
//! rotation. Here the *movement* is concurrent and chunked, but every
//! reduction is performed by the receiving owner in canonical lane order
//! with f64 accumulators — the exact operation sequence `SimComm` runs.
//! That buys two properties the test suite asserts:
//!
//! - the threaded engine is bit-identical to the sequential coordinator
//!   at every step, and
//! - results are invariant to the worker count for a fixed global lane
//!   total (workers × grad-accumulation), so `workers=1` runs are ground
//!   truth for `workers=4` runs.
//!
//! Wire bytes are still charged at the ideal ring's `(p−1)/p` per-GPU
//! traffic — the accounting models the cluster, not the in-process copy.
//!
//! ## Overlap
//!
//! Statistic slots are published the moment a worker finishes each
//! factor product (`publish_stat`), so owners start reducing and
//! inverting early layers while slower workers are still in their
//! backward/factor phase — Alg. 3's comm/compute overlap. The gradient
//! AllReduce is split into [`RingComm::grad_post`] (the send — lane
//! buffers are **moved** into the round, issued right after the backward
//! pass) and [`RingComm::grad_finish`] (the chunked reduce, issued after
//! the owner's inversions, returning one mean copy per participating
//! rank), so gradient communication overlaps Stage-4a factor inversion.
//! Post-by-move plus the per-rank (not per-lane) drain cuts ~2× lanes of
//! full-gradient memcpys per threaded step relative to the original
//! clone-in/drain-back protocol; the wire-byte accounting is unchanged
//! (asserted against `SimComm` in `tests/dist_collectives.rs`).

use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::collectives::comm::{
    lane_mean, lane_mean_mats, ring_wire_bytes, wire_quantize_slice, Collective, CommStats,
    Precision, StatClass,
};
use crate::linalg::{packed_len, Mat};
use crate::util::json::Json;
use crate::util::obs::{self, Cat};

/// Default AllReduce chunk granularity (elements).
pub const DEFAULT_CHUNK_ELEMS: usize = 4096;

/// Upper bound on any intra-round wait (`SPNGD_STALL_TIMEOUT_MS`,
/// default 120 s). A peer thread that died can never satisfy the round,
/// so rather than hanging the step forever, waits convert to a loud
/// panic after this long. The error path proper never needs it —
/// `worker_step` keeps the protocol alive with zero payloads on `Err` —
/// this is the backstop of last resort; a panicking peer normally
/// poisons the round first (see [`RingComm::poison`]) and waiters abort
/// within one 50 ms wait slice with the dead rank named.
fn stall_timeout() -> Duration {
    static MS: OnceLock<u64> = OnceLock::new();
    let ms = *MS.get_or_init(|| {
        std::env::var("SPNGD_STALL_TIMEOUT_MS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(120_000)
    });
    Duration::from_millis(ms.max(1))
}

// ----------------------------------------------------------- rounds

/// Statistic board: `slots[item][lane]` published as factors finish,
/// reduced once per item by its owner.
#[derive(Default)]
struct StatCtl {
    active: bool,
    lanes: usize,
    n_items: usize,
    slots: Vec<Vec<Option<Mat>>>,
    posted: Vec<usize>,
    reduced_items: usize,
    elems_a: usize,
    elems_g: usize,
}

/// Gradient AllReduce round: lanes posted by move, the element range
/// reduced in chunks claimed off a self-scheduling cursor, then one mean
/// copy handed back per participating rank (the trainer consumes a
/// single copy — draining the mean into every lane would redo the full
/// per-lane memcpys the move-in already saved).
#[derive(Default)]
struct GradCtl {
    active: bool,
    n: usize,
    total_lanes: usize,
    posted: usize,
    /// ranks that posted lanes this round (each calls `grad_finish`
    /// exactly once, so the round closes at `drained == participants`)
    participants: usize,
    lanes: Vec<Option<Vec<f32>>>,
    /// posted lanes frozen behind an Arc once complete (shared read-only
    /// by the concurrent chunk reducers)
    frozen: Option<Arc<Vec<Option<Vec<f32>>>>>,
    reduced: Vec<f32>,
    /// self-scheduling chunk cursor (any participating rank claims the
    /// next unreduced chunk — no rank is load-bearing, so a rank with no
    /// lanes may skip the round entirely)
    next_chunk: usize,
    done_chunks: usize,
    nchunks: usize,
    drained: usize,
}

/// AllGatherV round: owners post their segments, everyone copies out.
#[derive(Default)]
struct GatherCtl {
    active: bool,
    n_segs: usize,
    posted: usize,
    segs: Vec<Option<Vec<f32>>>,
    /// ranks that entered this round (an ownerless rank must still join
    /// the *current* round during its drain phase, not queue for the next)
    joined: usize,
    drained: usize,
}

/// Reusable sense barrier.
#[derive(Default)]
struct BarCtl {
    count: usize,
    generation: u64,
}

/// Concurrent shared-memory communicator over `p` worker threads with
/// `SimComm`-parity byte accounting. See the module docs for the
/// determinism and overlap contracts.
pub struct RingComm {
    p: usize,
    /// AllReduce chunk granularity (elements); odd sizes are fine.
    pub chunk_elems: usize,
    /// communicate only the upper triangle of symmetric matrices (§5.2)
    pub symmetric_packing: bool,
    /// wire precision for gradient/statistics payloads (§5.2): under
    /// `Mixed`, published statistic mats and posted gradient lanes are
    /// f16-quantized at serialization time and the reduced gradient mean
    /// travels the AllGather half quantized — the same per-element op
    /// sequence `SimComm` runs, so the engines stay bit-identical per
    /// mode. Parameters always travel f32.
    pub precision: Precision,
    stats: Mutex<CommStats>,
    step_stats: Mutex<CommStats>,
    stat: Mutex<StatCtl>,
    stat_cv: Condvar,
    grad: Mutex<GradCtl>,
    grad_cv: Condvar,
    gather: Mutex<GatherCtl>,
    gather_cv: Condvar,
    bar: Mutex<BarCtl>,
    bar_cv: Condvar,
    /// Set when a worker dies mid-round (normally by a [`PoisonGuard`]
    /// observing a panic). Every waiter re-checks it each wait slice and
    /// converts the hang into a panic naming the dead rank.
    poison: Mutex<Option<String>>,
}

impl RingComm {
    pub fn new(p: usize) -> Self {
        RingComm {
            p: p.max(1),
            chunk_elems: DEFAULT_CHUNK_ELEMS,
            symmetric_packing: true,
            precision: Precision::F32,
            stats: Mutex::new(CommStats::default()),
            step_stats: Mutex::new(CommStats::default()),
            stat: Mutex::new(StatCtl::default()),
            stat_cv: Condvar::new(),
            grad: Mutex::new(GradCtl::default()),
            grad_cv: Condvar::new(),
            gather: Mutex::new(GatherCtl::default()),
            gather_cv: Condvar::new(),
            bar: Mutex::new(BarCtl::default()),
            bar_cv: Condvar::new(),
            poison: Mutex::new(None),
        }
    }

    /// Mark the communicator dead: `rank`'s worker can no longer satisfy
    /// any round. Every blocked waiter wakes and panics with a diagnostic
    /// naming the dead rank instead of deadlocking until the stall
    /// backstop. First death wins; later ones keep the original reason.
    pub fn poison(&self, rank: usize, what: &str) {
        {
            let mut p = self.poison.lock().unwrap();
            if p.is_none() {
                *p = Some(format!("worker rank {rank} died: {what}"));
                obs::emit(
                    "poison",
                    vec![("rank", Json::from(rank)), ("what", Json::from(what))],
                );
            }
        }
        self.stat_cv.notify_all();
        self.grad_cv.notify_all();
        self.gather_cv.notify_all();
        self.bar_cv.notify_all();
    }

    /// An RAII guard for worker-thread bodies: if the thread unwinds
    /// while the guard is live, the communicator is poisoned with the
    /// rank's name so peers abort loudly instead of hanging.
    pub fn poison_guard(&self, rank: usize) -> PoisonGuard<'_> {
        PoisonGuard { comm: self, rank }
    }

    /// `Condvar::wait` with death detection: waits in 50 ms slices,
    /// re-checking the poison flag each wakeup (so a peer's death cannot
    /// be lost to a notify race), and panics after the stall backstop if
    /// no progress signal ever arrives.
    fn wait_round<'a, T>(
        &self,
        cv: &Condvar,
        g: MutexGuard<'a, T>,
        what: &str,
    ) -> MutexGuard<'a, T> {
        let _w = obs::span("ring_wait", Cat::Comm);
        let stall = stall_timeout();
        // lint:allow(determinism) -- stall watchdog aborts instead of hanging; no step math
        let start = Instant::now();
        let mut g = g;
        loop {
            // clone + drop the poison lock before panicking, so the
            // message survives for every other waiter
            let dead: Option<String> = self.poison.lock().unwrap().clone();
            if let Some(who) = dead {
                panic!("dist collective aborted waiting for {what}: {who}");
            }
            let slice = Duration::from_millis(50).min(stall);
            let (g2, timeout) = cv.wait_timeout(g, slice).unwrap();
            g = g2;
            if !timeout.timed_out() {
                return g; // a real signal — the caller re-checks its predicate
            }
            assert!(
                start.elapsed() < stall,
                "dist collective stalled waiting for {what} — a peer worker thread likely died"
            );
        }
    }

    pub fn world(&self) -> usize {
        self.p
    }

    fn elems_to_bytes(&self, elems: usize) -> u64 {
        ring_wire_bytes(self.p, self.precision.wire_elem_bytes(), elems)
    }

    fn charge<F: Fn(&mut CommStats)>(&self, f: F) {
        f(&mut self.stats.lock().unwrap());
        f(&mut self.step_stats.lock().unwrap());
    }

    /// Block until all `p` workers arrive (reusable).
    pub fn barrier(&self) {
        let mut g = self.bar.lock().unwrap();
        let gen = g.generation;
        g.count += 1;
        if g.count == self.p {
            g.count = 0;
            g.generation += 1;
            self.bar_cv.notify_all();
        } else {
            while g.generation == gen {
                g = self.wait_round(&self.bar_cv, g, "barrier peers");
            }
        }
    }

    // ------------------------------------------- ReduceScatterV (stats)

    /// Open a statistic round: `n_items` statistics, each with `lanes`
    /// per-(micro-step × worker) contributions. Called once per step by
    /// the coordinator before the worker fan-out; a no-op when the step
    /// refreshes nothing.
    pub fn begin_stats(&self, n_items: usize, lanes: usize) {
        if n_items == 0 {
            return;
        }
        let mut st = self.stat.lock().unwrap();
        assert!(!st.active, "previous statistic round still open");
        st.active = true;
        st.lanes = lanes;
        st.n_items = n_items;
        st.slots = (0..n_items).map(|_| (0..lanes).map(|_| None).collect()).collect();
        st.posted = vec![0; n_items];
        st.reduced_items = 0;
        st.elems_a = 0;
        st.elems_g = 0;
    }

    /// Publish lane `lane`'s contribution to statistic `item` — called by
    /// a worker the moment the factor product finishes, which is what
    /// lets owners start reducing while other workers still compute.
    pub fn publish_stat(&self, item: usize, lane: usize, mut m: Mat) {
        let _s = obs::span("publish_stat", Cat::Comm).arg("item", item as f64);
        {
            // serialization point: the published copy is what travels the wire
            let _q = obs::span("wire_quantize", Cat::Wire);
            wire_quantize_slice(self.precision, &mut m.data);
        }
        let mut st = self.stat.lock().unwrap();
        assert!(st.active, "publish_stat outside a statistic round");
        assert!(st.slots[item][lane].is_none(), "duplicate publish for (item, lane)");
        st.slots[item][lane] = Some(m);
        st.posted[item] += 1;
        if st.posted[item] == st.lanes {
            self.stat_cv.notify_all();
        }
    }

    /// Owner-side reduction of statistic `item`: waits until every lane
    /// has published, then reduces in canonical lane order (f64). The
    /// last reduced item of the round closes it and charges the ring's
    /// ReduceScatterV wire bytes (packed symmetric sizes per class).
    pub fn reduce_stat(&self, item: usize, class: StatClass) -> Mat {
        let _s = obs::span("reduce_stat", Cat::Comm).arg("item", item as f64);
        let taken: Vec<Mat> = {
            let mut st = self.stat.lock().unwrap();
            assert!(st.active, "reduce_stat outside a statistic round");
            while st.posted[item] < st.lanes {
                st = self.wait_round(&self.stat_cv, st, "statistic lanes");
            }
            let slot = std::mem::take(&mut st.slots[item]);
            slot.into_iter().map(|m| m.expect("lane posted")).collect()
        };
        let lane_refs: Vec<&Mat> = taken.iter().collect();
        let reduced = lane_mean_mats(&lane_refs);
        let elems = if self.symmetric_packing && reduced.is_square() {
            packed_len(reduced.rows)
        } else {
            reduced.rows * reduced.cols
        };
        let mut st = self.stat.lock().unwrap();
        match class {
            StatClass::A => st.elems_a += elems,
            StatClass::GorF => st.elems_g += elems,
        }
        st.reduced_items += 1;
        if st.reduced_items == st.n_items {
            let (ea, eg) = (st.elems_a, st.elems_g);
            st.active = false;
            st.slots = Vec::new();
            drop(st);
            self.charge(|s| {
                s.rs_stats_a += self.elems_to_bytes(ea);
                s.rs_stats_g += self.elems_to_bytes(eg);
                s.num_ops += 2;
            });
        }
        reduced
    }

    // ----------------------------------------------- AllReduce (grads)

    /// Post this worker's gradient lanes (`(global_lane, buffer)` pairs,
    /// **moved** into the round — no copy) — the "send" half, issued
    /// right after the backward pass so gradient communication overlaps
    /// Stage-4a inversion. `total_lanes` is the global lane count
    /// (identical on every rank). Non-blocking. A rank that posts must
    /// call [`RingComm::grad_finish`] exactly once this round.
    pub fn grad_post(&self, mut my_lanes: Vec<(usize, Vec<f32>)>, total_lanes: usize) {
        if my_lanes.is_empty() {
            return; // nothing to contribute — other ranks carry the round
        }
        let _s = obs::span("grad_post", Cat::Comm);
        {
            // serialization point: posted lanes travel the wire
            let _q = obs::span("wire_quantize", Cat::Wire);
            for (_, buf) in my_lanes.iter_mut() {
                wire_quantize_slice(self.precision, buf);
            }
        }
        let n = my_lanes[0].1.len();
        let mut st = self.grad.lock().unwrap();
        loop {
            if !st.active {
                st.active = true;
                st.n = n;
                st.total_lanes = total_lanes;
                st.posted = 0;
                st.participants = 0;
                st.lanes = (0..total_lanes).map(|_| None).collect();
                st.frozen = None;
                st.reduced = vec![0.0; n];
                st.next_chunk = 0;
                st.done_chunks = 0;
                st.nchunks = if n == 0 { 0 } else { n.div_ceil(self.chunk_elems.max(1)) };
                st.drained = 0;
                break;
            }
            if st.posted < st.total_lanes {
                break; // joining the posting phase of the open round
            }
            // previous round still draining — wait for it to close
            st = self.wait_round(&self.grad_cv, st, "previous AllReduce round to close");
        }
        assert_eq!(st.total_lanes, total_lanes, "lane total mismatch across ranks");
        st.participants += 1;
        for (g, buf) in my_lanes {
            assert_eq!(buf.len(), st.n, "lane length mismatch");
            assert!(st.lanes[g].is_none(), "duplicate lane {g}");
            st.lanes[g] = Some(buf);
            st.posted += 1;
        }
        if st.posted == st.total_lanes {
            self.grad_cv.notify_all();
        }
    }

    /// Finish the AllReduce: wait for every lane, claim and reduce chunks
    /// (self-scheduling cursor; each chunk reduced once, in canonical
    /// lane order with f64 accumulators), then return this rank's copy of
    /// the lane-mean gradient (the last participant takes the reduction
    /// buffer by move). The last participant closes the round and charges
    /// the ring AllReduce's wire bytes — every lane was posted before any
    /// finisher can pass the posted-lanes wait, so `participants` is
    /// final by then.
    pub fn grad_finish(&self) -> Vec<f32> {
        let _s = obs::span("grad_finish", Cat::Comm);
        let (frozen, n, total_lanes) = {
            let mut st = self.grad.lock().unwrap();
            assert!(st.active, "grad_finish without grad_post");
            while st.posted < st.total_lanes {
                st = self.wait_round(&self.grad_cv, st, "gradient lanes");
            }
            if st.frozen.is_none() {
                let lanes = std::mem::take(&mut st.lanes);
                st.frozen = Some(Arc::new(lanes));
            }
            (st.frozen.clone().unwrap(), st.n, st.total_lanes)
        };
        // claim + reduce chunks outside the lock (the concurrent part);
        // per element, the shared `lane_mean` op sequence — bitwise
        // parity with SimComm::all_reduce_mean.
        let chunk = self.chunk_elems.max(1);
        loop {
            let c = {
                let mut st = self.grad.lock().unwrap();
                if st.next_chunk >= st.nchunks {
                    break;
                }
                st.next_chunk += 1;
                st.next_chunk - 1
            };
            let s = c * chunk;
            let e = (s + chunk).min(n);
            let mut out = vec![0.0f32; e - s];
            for (i, o) in out.iter_mut().enumerate() {
                let vals = frozen.iter().map(|lane| lane.as_ref().expect("lane posted")[s + i]);
                *o = lane_mean(vals, total_lanes);
            }
            // the mean travels the AllGather half of the ring AR —
            // per-element quantization, so chunking can't perturb it
            wire_quantize_slice(self.precision, &mut out);
            let mut st = self.grad.lock().unwrap();
            st.reduced[s..e].copy_from_slice(&out);
            st.done_chunks += 1;
            if st.done_chunks == st.nchunks {
                self.grad_cv.notify_all();
            }
        }
        drop(frozen);
        let mut st = self.grad.lock().unwrap();
        while st.done_chunks < st.nchunks {
            st = self.wait_round(&self.grad_cv, st, "AllReduce chunk reduction");
        }
        st.drained += 1;
        if st.drained == st.participants {
            let out = std::mem::take(&mut st.reduced);
            st.active = false;
            st.frozen = None;
            drop(st);
            self.charge(|s| {
                s.ar_grads += 2 * self.elems_to_bytes(n);
                s.num_ops += 1;
            });
            self.grad_cv.notify_all();
            out
        } else {
            st.reduced.clone()
        }
    }

    // ---------------------------------------------- AllGatherV (params)

    /// Rank-level AllGatherV over variable-size segments: each rank
    /// passes the full segment list and the owner map; owned segments are
    /// posted (the send), then every rank copies every segment back out.
    /// After the call all ranks hold identical segment contents.
    pub fn all_gather_v(&self, rank: usize, segs: &mut [Vec<f32>], owner_of: &[usize]) {
        let _s = obs::span("all_gather_v", Cat::Comm);
        assert_eq!(segs.len(), owner_of.len());
        let n_segs = segs.len();
        let mut st = self.gather.lock().unwrap();
        loop {
            if !st.active {
                st.active = true;
                st.n_segs = n_segs;
                st.posted = 0;
                st.segs = (0..n_segs).map(|_| None).collect();
                st.joined = 1;
                st.drained = 0;
                break;
            }
            if st.joined < self.p {
                st.joined += 1;
                break;
            }
            st = self.wait_round(&self.gather_cv, st, "previous AllGatherV round to close");
        }
        assert_eq!(st.n_segs, n_segs, "segment count mismatch across ranks");
        for (i, seg) in segs.iter().enumerate() {
            if owner_of[i] % self.p == rank {
                assert!(st.segs[i].is_none(), "segment {i} posted twice");
                st.segs[i] = Some(seg.clone());
                st.posted += 1;
            }
        }
        if st.posted == st.n_segs {
            self.gather_cv.notify_all();
        }
        while st.posted < st.n_segs {
            st = self.wait_round(&self.gather_cv, st, "owner segments");
        }
        let mut total_elems = 0usize;
        for (i, seg) in segs.iter_mut().enumerate() {
            let src = st.segs[i].as_ref().expect("segment posted");
            seg.resize(src.len(), 0.0);
            seg.copy_from_slice(src);
            total_elems += src.len();
        }
        st.drained += 1;
        if st.drained == self.p {
            st.active = false;
            st.segs = Vec::new();
            drop(st);
            self.charge(|s| {
                // parameters always travel f32 (§5.2)
                s.ag_params += ring_wire_bytes(self.p, 4, total_elems);
                s.num_ops += 1;
            });
            self.gather_cv.notify_all();
        }
    }
}

/// Poisons the communicator if the owning thread unwinds while the
/// guard is live (see [`RingComm::poison_guard`]). A clean exit drops
/// the guard silently.
pub struct PoisonGuard<'a> {
    comm: &'a RingComm,
    rank: usize,
}

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.comm.poison(self.rank, "panicked mid-collective");
        }
    }
}

/// God-view [`Collective`] adapter: the same lane-level semantics as
/// `SimComm`, executed by `p` scoped worker threads through the
/// rank-level ring entry points — one lane group per rank, lanes
/// assigned `g mod p` (the dist engine's canonical lane layout).
impl Collective for RingComm {
    fn world(&self) -> usize {
        self.p
    }

    fn all_reduce_mean(&self, lanes: &mut [Vec<f32>]) {
        if lanes.is_empty() {
            return;
        }
        let total = lanes.len();
        let mut groups: Vec<Vec<(usize, &mut Vec<f32>)>> =
            (0..self.p).map(|_| Vec::new()).collect();
        for (g, lane) in lanes.iter_mut().enumerate() {
            groups[g % self.p].push((g, lane));
        }
        std::thread::scope(|s| {
            for (rank, group) in groups.into_iter().enumerate() {
                std::thread::Builder::new()
                    .name(format!("spngd-worker-{rank}"))
                    .spawn_scoped(s, move || {
                    let _poison = self.poison_guard(rank);
                    let mut group = group;
                    let posts: Vec<(usize, Vec<f32>)> =
                        group.iter_mut().map(|(g, b)| (*g, std::mem::take(*b))).collect();
                    if posts.is_empty() {
                        return; // rank with no lanes skips the round
                    }
                    self.grad_post(posts, total);
                    // the trait contract fills every lane with the mean —
                    // copy this rank's finish result back out
                    let mean = self.grad_finish();
                    for (_, buf) in group.iter_mut() {
                        buf.extend_from_slice(&mean);
                    }
                })
                    .expect("spawn ring collective thread");
            }
        });
    }

    fn reduce_scatter_v(&self, lanes: &[Vec<Mat>], classes: &[StatClass]) -> Vec<Mat> {
        assert!(!lanes.is_empty());
        let n_items = lanes[0].len();
        assert_eq!(classes.len(), n_items);
        if n_items == 0 {
            return Vec::new();
        }
        self.begin_stats(n_items, lanes.len());
        let results: Vec<Mutex<Option<Mat>>> = (0..n_items).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for rank in 0..self.p {
                let results = &results;
                std::thread::Builder::new()
                    .name(format!("spngd-worker-{rank}"))
                    .spawn_scoped(s, move || {
                        let _poison = self.poison_guard(rank);
                        for (g, lane) in lanes.iter().enumerate() {
                            if g % self.p != rank {
                                continue;
                            }
                            for (i, m) in lane.iter().enumerate() {
                                self.publish_stat(i, g, m.clone());
                            }
                        }
                        let mut i = rank;
                        while i < n_items {
                            let m = self.reduce_stat(i, classes[i]);
                            *results[i].lock().unwrap() = Some(m);
                            i += self.p;
                        }
                    })
                    .expect("spawn ring collective thread");
            }
        });
        results.into_iter().map(|m| m.into_inner().unwrap().expect("item reduced")).collect()
    }

    fn all_gather_v_params(&self, total_elems: usize) {
        // parameters are shared in-process (owners write their layers in
        // place); this is the accounting-only form, parity with SimComm.
        // Parameters always travel f32 (§5.2).
        self.charge(|s| {
            s.ag_params += ring_wire_bytes(self.p, 4, total_elems);
            s.num_ops += 1;
        });
    }

    fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    fn take_step_stats(&self) -> CommStats {
        let mut ss = self.step_stats.lock().unwrap();
        let out = ss.clone();
        *ss = CommStats::default();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_reusable() {
        let c = Arc::new(RingComm::new(3));
        let hits = Arc::new(Mutex::new(0usize));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let c = c.clone();
                let hits = hits.clone();
                s.spawn(move || {
                    for _ in 0..5 {
                        c.barrier();
                        *hits.lock().unwrap() += 1;
                    }
                });
            }
        });
        assert_eq!(*hits.lock().unwrap(), 15);
    }

    #[test]
    fn grad_allreduce_means_lanes() {
        let c = RingComm::new(2);
        let mut lanes: Vec<Vec<f32>> = vec![
            vec![1.0, 2.0, 3.0],
            vec![3.0, 4.0, 5.0],
            vec![5.0, 6.0, 7.0],
            vec![7.0, 8.0, 9.0],
        ];
        Collective::all_reduce_mean(&c, &mut lanes);
        for lane in &lanes {
            assert_eq!(lane, &vec![4.0, 5.0, 6.0]);
        }
        // ring AR bytes: 2 * (1/2) * 3 elems * 4 bytes = 12
        assert_eq!(Collective::stats(&c).ar_grads, 12);
    }

    #[test]
    fn poison_converts_stall_into_named_panic() {
        let c = Arc::new(RingComm::new(2));
        let waiter = {
            let c = c.clone();
            std::thread::spawn(move || c.barrier()) // peer never arrives
        };
        std::thread::sleep(Duration::from_millis(30));
        c.poison(1, "synthetic death");
        let err = waiter.join().expect_err("waiter must panic, not hang");
        let msg = err.downcast_ref::<String>().expect("formatted panic");
        assert!(msg.contains("worker rank 1 died: synthetic death"), "got: {msg}");
        assert!(msg.contains("barrier peers"), "got: {msg}");
    }

    #[test]
    fn panicking_worker_poisons_the_round() {
        let c = Arc::new(RingComm::new(2));
        let waiter = {
            let c = c.clone();
            std::thread::spawn(move || c.barrier())
        };
        let dier = {
            let c = c.clone();
            std::thread::spawn(move || {
                let _guard = c.poison_guard(1);
                panic!("kernel exploded");
            })
        };
        assert!(dier.join().is_err());
        let err = waiter.join().expect_err("waiter must see the poison");
        let msg = err.downcast_ref::<String>().expect("formatted panic");
        assert!(msg.contains("worker rank 1 died: panicked mid-collective"), "got: {msg}");
    }

    #[test]
    fn stat_board_publish_out_of_order() {
        let c = RingComm::new(1);
        c.begin_stats(2, 3);
        c.publish_stat(1, 2, Mat::from_vec(1, 2, vec![3.0, 3.0]));
        c.publish_stat(0, 1, Mat::eye(2));
        c.publish_stat(1, 0, Mat::from_vec(1, 2, vec![0.0, 3.0]));
        c.publish_stat(0, 0, Mat::eye(2));
        c.publish_stat(1, 1, Mat::from_vec(1, 2, vec![0.0, 3.0]));
        c.publish_stat(0, 2, Mat::eye(2));
        let m0 = c.reduce_stat(0, StatClass::A);
        let m1 = c.reduce_stat(1, StatClass::GorF);
        assert_eq!(m0.data, Mat::eye(2).data);
        assert_eq!(m1.data, vec![1.0, 3.0]);
    }
}
