//! Per-worker execution resources for the threaded dist engine.
//!
//! The step pipeline itself lives in `coordinator::trainer` (it is the
//! same Stage 1–5 math whichever engine runs it); this module owns what
//! is *per worker*: one forked [`Executor`] per data-parallel worker
//! (own scratch arena — the per-step hot loops never contend) and the
//! [`RingComm`] the worker threads communicate through.

use std::sync::Arc;

use crate::dist::ring::RingComm;
use crate::runtime::Executor;

/// One communicator + one executor per data-parallel worker thread.
pub struct DistEngine {
    pub ring: Arc<RingComm>,
    engines: Vec<Arc<dyn Executor>>,
}

impl DistEngine {
    /// Fork `workers` executor instances off a prototype. Backends that
    /// cannot provide isolated instances (`fork_worker() == None`, e.g.
    /// the PJRT engine, whose compiled-executable cache is thread-safe
    /// and worth sharing) are shared across workers instead.
    pub fn new(prototype: &Arc<dyn Executor>, workers: usize) -> Self {
        let workers = workers.max(1);
        let engines = (0..workers)
            .map(|_| prototype.fork_worker().unwrap_or_else(|| prototype.clone()))
            .collect();
        DistEngine { ring: Arc::new(RingComm::new(workers)), engines }
    }

    pub fn workers(&self) -> usize {
        self.engines.len()
    }

    /// The executor dedicated to worker `rank`.
    pub fn engine(&self, rank: usize) -> &Arc<dyn Executor> {
        &self.engines[rank]
    }
}
