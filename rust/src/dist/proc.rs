//! `ProcComm` — the multi-process [`Collective`] transport.
//!
//! Worker processes (`spngd worker`) are *stateless reducers*: the
//! coordinator keeps the model, draws the lanes, and ships each
//! reduction job (a gradient segment or one statistic's lane matrices)
//! over the framed Unix-socket wire protocol (`collectives::wire`); a
//! worker decodes at wire precision, reduces with the shared
//! canonical-lane math, and replies. Because decoding real f16 bytes is
//! exactly `wire_quantize`, and workers reuse the same `lane_mean` /
//! reciprocal-multiply op sequence as `SimComm` and `RingComm`, the
//! healthy multi-process path is bit-identical to both in-process
//! engines — and stays bit-identical across worker deaths, because a
//! dead worker's jobs are recomputed, not skipped.
//!
//! Byte accounting is dual: the modeled per-GPU `CommStats` charge the
//! same `ring_wire_bytes` formulas as `SimComm` (the cost model must not
//! care which transport ran), while [`WireStats`] counts the *actual*
//! framed bytes moved, asserted against closed-form counters in tests
//! and `python/tools/ring_sim.py`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::collectives::comm::{
    lane_mean, lane_mean_mats_wire, ring_wire_bytes, wire_quantize, wire_quantize_slice,
    Collective, CommStats, Precision, StatClass,
};
use crate::collectives::wire::{self, Frame, Kind};
use crate::dist::fault::FaultPlan;
use crate::dist::membership::{
    MemberEvent, Membership, MembershipCfg, RespawnPolicy, RunState, Spawner,
};
use crate::linalg::{packed_len, Mat};
use crate::util::json::Json;
use crate::util::obs::{self, Cat};

/// Configuration for the multi-process transport.
#[derive(Clone, Debug)]
pub struct ProcCfg {
    /// Worker binary; defaults to the current executable.
    pub worker_bin: Option<String>,
    /// Spawn workers from the coordinator (default). When false, the
    /// coordinator binds the socket and waits for external joiners.
    pub spawn: bool,
    /// Explicit socket path; default is a fresh temp-dir socket.
    pub socket: Option<String>,
    pub heartbeat_ms: u64,
    pub heartbeat_timeout_ms: u64,
    pub job_timeout_ms: u64,
    pub join_timeout_ms: u64,
    pub respawn: RespawnPolicy,
    pub backoff_base_ms: u64,
    /// Deterministic failure script exported to first-generation workers.
    pub fault_plan: FaultPlan,
}

impl Default for ProcCfg {
    fn default() -> Self {
        ProcCfg {
            worker_bin: None,
            spawn: true,
            socket: None,
            heartbeat_ms: 50,
            heartbeat_timeout_ms: 1000,
            job_timeout_ms: 5000,
            join_timeout_ms: 10_000,
            respawn: RespawnPolicy::Respawn { max: 2 },
            backoff_base_ms: 20,
            fault_plan: FaultPlan::default(),
        }
    }
}

impl ProcCfg {
    /// Resolve from the environment: `SPNGD_FAULT_PLAN` (failure script),
    /// `SPNGD_PROC_RESPAWN` = `respawn` | `shrink` | `strict`, and
    /// `SPNGD_PROC_*_MS` timeout overrides. Invalid values are hard
    /// errors, mirroring the other env registries.
    pub fn from_env() -> ProcCfg {
        let mut cfg = ProcCfg { fault_plan: FaultPlan::from_env(), ..ProcCfg::default() };
        if let Ok(v) = std::env::var("SPNGD_PROC_RESPAWN") {
            cfg.respawn = Self::parse_respawn(&v)
                .unwrap_or_else(|e| panic!("SPNGD_PROC_RESPAWN: {e}"));
        }
        let ms = |name: &str, dst: &mut u64| {
            if let Ok(v) = std::env::var(name) {
                *dst = v.parse().unwrap_or_else(|_| panic!("{name}: bad ms value '{v}'"));
            }
        };
        ms("SPNGD_PROC_HEARTBEAT_MS", &mut cfg.heartbeat_ms);
        ms("SPNGD_PROC_HEARTBEAT_TIMEOUT_MS", &mut cfg.heartbeat_timeout_ms);
        ms("SPNGD_PROC_JOB_TIMEOUT_MS", &mut cfg.job_timeout_ms);
        ms("SPNGD_PROC_JOIN_TIMEOUT_MS", &mut cfg.join_timeout_ms);
        cfg
    }

    /// Parse a respawn-policy spelling: `respawn` (2 attempts),
    /// `respawn:N`, `shrink`, or `strict`.
    pub fn parse_respawn(s: &str) -> Result<RespawnPolicy, String> {
        match s {
            "respawn" => Ok(RespawnPolicy::Respawn { max: 2 }),
            "shrink" => Ok(RespawnPolicy::Shrink),
            "strict" => Ok(RespawnPolicy::Strict),
            other => match other.strip_prefix("respawn:") {
                Some(n) => n
                    .parse()
                    .map(|max| RespawnPolicy::Respawn { max })
                    .map_err(|_| format!("bad respawn count '{n}'")),
                None => Err(format!(
                    "unknown policy '{other}' (respawn | respawn:N | shrink | strict)"
                )),
            },
        }
    }

    fn membership_cfg(&self) -> MembershipCfg {
        MembershipCfg {
            heartbeat_ms: self.heartbeat_ms,
            heartbeat_timeout_ms: self.heartbeat_timeout_ms,
            job_timeout_ms: self.job_timeout_ms,
            join_timeout_ms: self.join_timeout_ms,
            respawn: self.respawn,
            backoff_base_ms: self.backoff_base_ms,
        }
    }
}

/// Actual framed bytes moved on the process wire (data frames only —
/// heartbeats/control are latency traffic, not payload). On the healthy
/// path these match the closed-form counters in `collectives::wire`;
/// fault recovery legitimately re-sends jobs, so faults inflate them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub grad_tx: u64,
    pub grad_rx: u64,
    pub stat_tx: u64,
    pub stat_rx: u64,
    pub data_frames: u64,
}

static SOCKET_SEQ: AtomicU64 = AtomicU64::new(0);

/// The multi-process transport. See the module docs for the contract.
pub struct ProcComm {
    p: usize,
    pub symmetric_packing: bool,
    precision: Precision,
    stats: Mutex<CommStats>,
    step_stats: Mutex<CommStats>,
    wire_stats: Mutex<WireStats>,
    membership: Mutex<Membership>,
    fatal: Mutex<Option<String>>,
    temp_dir: Option<PathBuf>,
}

const LOG: &str = "dist::proc";

impl ProcComm {
    /// Bind the coordinator socket, spawn (or await) `world` workers,
    /// run `WaitingForMembers → Warmup`, and return a transport ready
    /// for round 1.
    pub fn launch(world: usize, precision: Precision, cfg: &ProcCfg) -> anyhow::Result<ProcComm> {
        let world = world.max(1);
        let (socket, temp_dir) = match &cfg.socket {
            Some(s) => (s.clone(), None),
            None => {
                let dir = std::env::temp_dir().join(format!(
                    "spngd-proc-{}-{}",
                    std::process::id(),
                    SOCKET_SEQ.fetch_add(1, Ordering::Relaxed)
                ));
                std::fs::create_dir_all(&dir)
                    .map_err(|e| anyhow::anyhow!("create socket dir {dir:?}: {e}"))?;
                (dir.join("coord.sock").to_string_lossy().into_owned(), Some(dir))
            }
        };
        if socket.len() > 100 {
            anyhow::bail!("socket path '{socket}' exceeds the unix socket path limit");
        }
        let program = match &cfg.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| anyhow::anyhow!("resolve worker binary: {e}"))?
                .to_string_lossy()
                .into_owned(),
        };
        let spawner = cfg.spawn.then(|| Spawner {
            program,
            socket: socket.clone(),
            fault_env: cfg.fault_plan.to_env(),
        });
        let mut membership =
            Membership::bind(&socket, world as u32, cfg.membership_cfg(), spawner)
                .map_err(|e| anyhow::anyhow!("bind coordinator socket {socket}: {e}"))?;
        let children = if cfg.spawn {
            membership
                .spawn_workers(world, true)
                .map_err(|e| anyhow::anyhow!("spawn {world} workers: {e}"))?
        } else {
            Vec::new()
        };
        membership.wait_for_members(children).map_err(|e| anyhow::anyhow!("{e}"))?;
        membership.warmup().map_err(|e| anyhow::anyhow!("{e}"))?;
        crate::debug!(LOG, "{} workers admitted on {socket}", membership.live());
        if !cfg.fault_plan.is_empty() {
            obs::emit(
                "fault_plan",
                vec![
                    ("plan", Json::from(cfg.fault_plan.to_env())),
                    ("world", Json::from(world)),
                ],
            );
        }
        Ok(ProcComm {
            p: world,
            symmetric_packing: true,
            precision,
            stats: Mutex::new(CommStats::default()),
            step_stats: Mutex::new(CommStats::default()),
            wire_stats: Mutex::new(WireStats::default()),
            membership: Mutex::new(membership),
            fatal: Mutex::new(None),
            temp_dir,
        })
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Live worker count (shrinks on deaths, recovers on respawn).
    pub fn live(&self) -> usize {
        self.membership.lock().unwrap().live()
    }

    pub fn state(&self) -> RunState {
        self.membership.lock().unwrap().state()
    }

    /// Drain membership events (tests assert Dead/Respawned sequences).
    pub fn take_events(&self) -> Vec<MemberEvent> {
        self.membership.lock().unwrap().take_events()
    }

    /// Snapshot the actual framed wire bytes.
    pub fn wire_stats(&self) -> WireStats {
        self.wire_stats.lock().unwrap().clone()
    }

    /// Enter a round: broadcast `RoundStart(step)`. Errors out if a
    /// previous round left the run unsustainable.
    pub fn round_start(&self, step: u64) -> anyhow::Result<()> {
        self.check_fatal()?;
        let mut m = self.membership.lock().unwrap();
        m.round_start(step);
        drop(m);
        self.check_fatal()
    }

    /// Close a round: broadcast `RoundEnd(step)`, admit late joiners,
    /// and run the respawn policy if membership shrank.
    pub fn round_end(&self, step: u64) -> anyhow::Result<()> {
        let mut m = self.membership.lock().unwrap();
        m.round_end(step);
        drop(m);
        self.check_fatal()
    }

    /// Surface the first fatal membership condition as a structured
    /// hard error (named ranks, step, reason).
    pub fn check_fatal(&self) -> anyhow::Result<()> {
        if let Some(f) = self.fatal.lock().unwrap().as_ref() {
            anyhow::bail!("proc transport fatal: {f}");
        }
        if let Some(f) = self.membership.lock().unwrap().fatal() {
            anyhow::bail!("proc transport fatal: {f}");
        }
        Ok(())
    }

    fn set_fatal(&self, msg: String) {
        let mut f = self.fatal.lock().unwrap();
        if f.is_none() {
            *f = Some(msg);
        }
    }

    fn elems_to_bytes(&self, elems: usize) -> u64 {
        ring_wire_bytes(self.p, self.precision.wire_elem_bytes(), elems)
    }

    fn charge(&self, f: impl Fn(&mut CommStats)) {
        f(&mut self.stats.lock().unwrap());
        f(&mut self.step_stats.lock().unwrap());
    }

    fn count_tx(&self, grad: bool, payload_len: usize) {
        let mut w = self.wire_stats.lock().unwrap();
        let bytes = Frame::encoded_len(payload_len);
        if grad {
            w.grad_tx += bytes;
        } else {
            w.stat_tx += bytes;
        }
        w.data_frames += 1;
    }

    fn count_rx(&self, grad: bool, payload_len: usize) {
        let mut w = self.wire_stats.lock().unwrap();
        let bytes = Frame::encoded_len(payload_len);
        if grad {
            w.grad_rx += bytes;
        } else {
            w.stat_rx += bytes;
        }
        w.data_frames += 1;
    }

    /// Dispatch `frames[j]` one-per-live-worker in waves until every job
    /// has a decoded reply (routed through `on_reply`). Worker deaths
    /// re-queue the job; with zero workers left, `local[j]` computes the
    /// result in-process (bit-identically) and the transport goes fatal.
    fn run_jobs(
        &self,
        m: &mut Membership,
        grad: bool,
        frames: &[Frame],
        mut on_reply: impl FnMut(usize, Frame) -> Result<(), String>,
        mut local: impl FnMut(usize),
    ) {
        let _s = obs::span(if grad { "proc_grad_jobs" } else { "proc_stat_jobs" }, Cat::Comm)
            .arg("jobs", frames.len() as f64);
        let want = if grad { Kind::GradSeg } else { Kind::StatResult };
        let mut done = vec![false; frames.len()];
        loop {
            let todo: Vec<usize> = (0..frames.len()).filter(|&j| !done[j]).collect();
            if todo.is_empty() {
                return;
            }
            if m.live() == 0 {
                for &j in &todo {
                    local(j);
                }
                self.set_fatal(format!(
                    "every worker died mid-step; {} job(s) finished locally \
                     (see Dead events for per-rank reasons)",
                    todo.len()
                ));
                return;
            }
            let ranks: Vec<u32> = m.members().iter().map(|mm| mm.rank).collect();
            let wave: Vec<(usize, u32)> =
                todo.iter().zip(ranks.iter()).map(|(&j, &r)| (j, r)).collect();
            // send phase
            for &(j, rank) in &wave {
                let Some(i) = m.members().iter().position(|mm| mm.rank == rank) else {
                    continue;
                };
                match m.send_to(i, &frames[j]) {
                    Ok(()) => self.count_tx(grad, frames[j].payload.len()),
                    Err(e) => m.mark_dead(rank, &e),
                }
            }
            // receive phase
            for &(j, rank) in &wave {
                let Some(i) = m.members().iter().position(|mm| mm.rank == rank) else {
                    continue; // died during send; job stays queued
                };
                let deadline = m.job_deadline();
                match m.recv_data(i, deadline) {
                    Ok(f) if f.kind == want => {
                        let n = f.payload.len();
                        match on_reply(j, f) {
                            Ok(()) => {
                                self.count_rx(grad, n);
                                done[j] = true;
                            }
                            Err(e) => m.mark_dead(rank, &e),
                        }
                    }
                    Ok(f) => m.mark_dead(rank, &format!("unexpected {:?} reply", f.kind)),
                    Err(e) => m.mark_dead(rank, &e),
                }
            }
        }
    }
}

impl Collective for ProcComm {
    fn world(&self) -> usize {
        self.p
    }

    /// AllReduce(mean) with the reduction farmed out to worker
    /// processes: lanes are quantized at serialization (really — the
    /// encoder emits f16 bytes under `Mixed`), split into balanced
    /// contiguous segments (one per live worker), reduced remotely with
    /// the shared `lane_mean`, and the quantized mean lands back in
    /// every lane. Byte charging is identical to `SimComm`.
    fn all_reduce_mean(&self, lanes: &mut [Vec<f32>]) {
        assert!(!lanes.is_empty(), "at least one lane");
        let _s = obs::span("all_reduce_mean", Cat::Comm).arg("lanes", lanes.len() as f64);
        let n = lanes[0].len();
        let nlanes = lanes.len();
        let mut m = self.membership.lock().unwrap();
        let frames: Vec<Frame>;
        let segs;
        {
            let _q = obs::span("wire_encode", Cat::Wire);
            for b in lanes.iter_mut() {
                wire_quantize_slice(self.precision, b);
            }
            segs = wire::split_segments(n, m.live().max(1));
            frames = segs
                .iter()
                .enumerate()
                .map(|(j, &(start, len))| {
                    let slices: Vec<&[f32]> =
                        lanes.iter().map(|l| &l[start..start + len]).collect();
                    wire::encode_grad_job(self.precision, j as u32, &slices)
                })
                .collect();
        }
        let mut mean = vec![0.0f32; n];
        // split the borrow: `lanes` is read by the local fallback while
        // `mean` segments are written by replies
        let mean_cell = std::cell::RefCell::new(&mut mean);
        self.run_jobs(
            &mut m,
            true,
            &frames,
            |j, f| {
                let (jid, seg) =
                    wire::decode_grad_seg(&f).map_err(|e| format!("bad grad reply: {e}"))?;
                let (start, len) = segs[j];
                if jid as usize != j || seg.len() != len {
                    return Err(format!(
                        "grad reply mismatch: job {jid} len {} (want {j} len {len})",
                        seg.len()
                    ));
                }
                mean_cell.borrow_mut()[start..start + len].copy_from_slice(&seg);
                Ok(())
            },
            |j| {
                let (start, len) = segs[j];
                let mut out = mean_cell.borrow_mut();
                for i in start..start + len {
                    out[i] = wire_quantize(
                        self.precision,
                        lane_mean(lanes.iter().map(|l| l[i]), nlanes),
                    );
                }
            },
        );
        drop(m);
        for b in lanes.iter_mut() {
            b.copy_from_slice(&mean);
        }
        let bytes = 2 * self.elems_to_bytes(n);
        self.charge(|s| {
            s.ar_grads += bytes;
            s.num_ops += 1;
        });
    }

    /// ReduceScatterV with one job per statistic, round-robined over
    /// live workers; owner-side means come back as exact f32 (master
    /// copies are never re-quantized — §5.2).
    fn reduce_scatter_v(&self, items: &[Vec<Mat>], classes: &[StatClass]) -> Vec<Mat> {
        assert!(!items.is_empty(), "at least one lane");
        let _s = obs::span("reduce_scatter_v", Cat::Comm).arg("items", items[0].len() as f64);
        let n_items = items[0].len();
        assert_eq!(classes.len(), n_items);
        let frames: Vec<Frame> = {
            let _q = obs::span("wire_encode", Cat::Wire);
            (0..n_items)
                .map(|i| {
                    let (rows, cols) = (items[0][i].rows, items[0][i].cols);
                    let slices: Vec<&[f32]> =
                        items.iter().map(|lane| lane[i].data.as_slice()).collect();
                    wire::encode_stat_job(
                        self.precision,
                        i as u32,
                        rows as u32,
                        cols as u32,
                        &slices,
                    )
                })
                .collect()
        };
        let mut out: Vec<Option<Mat>> = (0..n_items).map(|_| None).collect();
        let out_cell = std::cell::RefCell::new(&mut out);
        let mut m = self.membership.lock().unwrap();
        self.run_jobs(
            &mut m,
            false,
            &frames,
            |j, f| {
                let (item, rows, cols, data) =
                    wire::decode_stat_result(&f).map_err(|e| format!("bad stat reply: {e}"))?;
                let (wr, wc) = (items[0][j].rows, items[0][j].cols);
                if item as usize != j || (rows as usize, cols as usize) != (wr, wc) {
                    return Err(format!(
                        "stat reply mismatch: item {item} {rows}x{cols} (want {j} {wr}x{wc})"
                    ));
                }
                out_cell.borrow_mut()[j] = Some(Mat::from_vec(wr, wc, data));
                Ok(())
            },
            |j| {
                let lane_mats: Vec<&Mat> = items.iter().map(|lane| &lane[j]).collect();
                out_cell.borrow_mut()[j] = Some(lane_mean_mats_wire(&lane_mats, self.precision));
            },
        );
        drop(m);
        let out: Vec<Mat> = out.into_iter().map(|o| o.expect("every job resolved")).collect();
        let mut elems_a = 0usize;
        let mut elems_g = 0usize;
        for (i, mat) in out.iter().enumerate() {
            let elems = if self.symmetric_packing && mat.is_square() {
                packed_len(mat.rows)
            } else {
                mat.rows * mat.cols
            };
            match classes[i] {
                StatClass::A => elems_a += elems,
                StatClass::GorF => elems_g += elems,
            }
        }
        let (ba, bg) = (self.elems_to_bytes(elems_a), self.elems_to_bytes(elems_g));
        self.charge(|s| {
            s.rs_stats_a += ba;
            s.rs_stats_g += bg;
            s.num_ops += 2;
        });
        out
    }

    /// Parameters live in the coordinator (workers are stateless), so
    /// this is accounting-only, exactly like `SimComm` — and always f32.
    fn all_gather_v_params(&self, total_elems: usize) {
        let bytes = ring_wire_bytes(self.p, 4, total_elems);
        self.charge(|s| {
            s.ag_params += bytes;
            s.num_ops += 1;
        });
    }

    fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    fn take_step_stats(&self) -> CommStats {
        let mut ss = self.step_stats.lock().unwrap();
        let out = ss.clone();
        *ss = CommStats::default();
        out
    }
}

impl Drop for ProcComm {
    fn drop(&mut self) {
        if let Ok(mut m) = self.membership.lock() {
            m.shutdown();
        }
        if let Some(dir) = &self.temp_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}
