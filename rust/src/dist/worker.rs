//! The `spngd worker` process body: a stateless reducer.
//!
//! A worker never loads the model or the data — it connects to the
//! coordinator socket, handshakes (`Hello` → `Welcome`), heartbeats on
//! the cadence the coordinator dictates, and serves reduction jobs:
//! decode lanes at wire precision, reduce with the *shared*
//! canonical-lane math from `collectives::comm` (`lane_mean` for
//! gradients, the reciprocal-multiply mean for statistics), reply.
//! Statelessness is what makes elasticity cheap: a replacement worker
//! is fully resynced by its `Welcome` frame.
//!
//! Deterministic faults (`SPNGD_FAULT_PLAN`, filtered to this worker's
//! rank after admission) fire at the first reduction job of their step.

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::collectives::comm::lane_mean;
use crate::collectives::wire::{self, Frame, Kind};
use crate::dist::fault::{ArmedFaults, Fault, FaultKind, FaultPlan};
use crate::dist::membership::{Conn, ConnError};

const LOG: &str = "dist::worker";

/// Shared write half: the serve loop and the heartbeat thread both send.
#[derive(Clone)]
struct Writer {
    stream: Arc<Mutex<UnixStream>>,
    muted: Arc<AtomicBool>,
}

impl Writer {
    fn send(&self, f: &Frame) -> std::io::Result<()> {
        self.send_raw(&f.encode())
    }

    fn send_raw(&self, bytes: &[u8]) -> std::io::Result<()> {
        if self.muted.load(Ordering::Relaxed) {
            return Ok(()); // a "hung" worker: swallow everything
        }
        self.stream.lock().unwrap().write_all(bytes)
    }
}

/// Run the worker against a coordinator socket until `Shutdown` or EOF.
pub fn run(socket: &str, plan: FaultPlan) -> anyhow::Result<()> {
    let stream = UnixStream::connect(socket)
        .map_err(|e| anyhow::anyhow!("connect to coordinator {socket}: {e}"))?;
    let write_half = stream
        .try_clone()
        .map_err(|e| anyhow::anyhow!("clone worker stream: {e}"))?;
    let writer = Writer {
        stream: Arc::new(Mutex::new(write_half)),
        muted: Arc::new(AtomicBool::new(false)),
    };
    let mut conn = Conn::new(stream);

    let uid = std::process::id() as u64;
    writer
        .send(&wire::encode_hello(uid))
        .map_err(|e| anyhow::anyhow!("send hello: {e}"))?;
    let welcome = match conn.poll_frame(Duration::from_secs(10)) {
        Ok(Some(f)) if f.kind == Kind::Welcome => wire::decode_welcome(&f)
            .map_err(|e| anyhow::anyhow!("malformed welcome: {e}"))?,
        Ok(Some(f)) => anyhow::bail!("expected Welcome, got {:?}", f.kind),
        Ok(None) => anyhow::bail!("no Welcome within 10s"),
        Err(e) => anyhow::bail!("handshake failed: {e}"),
    };
    crate::debug!(
        LOG,
        "admitted as rank {}/{} at step {} (uid {uid})",
        welcome.rank,
        welcome.world,
        welcome.step
    );
    let mut faults = ArmedFaults::new(plan.for_rank(welcome.rank));
    let step = Arc::new(AtomicU64::new(welcome.step));

    // heartbeat thread: fixed cadence from the Welcome frame
    {
        let writer = writer.clone();
        let step = Arc::clone(&step);
        let cadence = Duration::from_millis(welcome.heartbeat_ms.max(1) as u64);
        std::thread::Builder::new()
            .name("spngd-heartbeat".into())
            .spawn(move || loop {
                std::thread::sleep(cadence);
                let f = wire::encode_step(Kind::Heartbeat, step.load(Ordering::Relaxed));
                if writer.send(&f).is_err() {
                    return; // coordinator is gone; the serve loop will exit too
                }
            })
            .map_err(|e| anyhow::anyhow!("spawn heartbeat thread: {e}"))?;
    }

    loop {
        let frame = match conn.poll_frame(Duration::from_secs(60)) {
            Ok(Some(f)) => f,
            Ok(None) => continue,
            Err(ConnError::Closed) => return Ok(()), // coordinator exited
            Err(e) => anyhow::bail!("worker rank {} stream failed: {e}", welcome.rank),
        };
        match frame.kind {
            Kind::Ping => {
                let _ = writer.send(&Frame::control(Kind::Pong));
            }
            Kind::RoundStart => {
                if let Ok(s) = wire::decode_step(&frame) {
                    step.store(s, Ordering::Relaxed);
                }
            }
            Kind::RoundEnd | Kind::Heartbeat => {}
            Kind::Shutdown => return Ok(()),
            Kind::ReduceGrad => {
                let job = wire::decode_grad_job(&frame)
                    .map_err(|e| anyhow::anyhow!("rank {}: bad grad job: {e}", welcome.rank))?;
                let nlanes = job.lanes.len();
                let mean: Vec<f32> = (0..job.seg_len as usize)
                    .map(|i| lane_mean(job.lanes.iter().map(|l| l[i]), nlanes))
                    .collect();
                let reply = wire::encode_grad_seg(
                    wire::flags_precision(frame.flags),
                    job.job,
                    &mean,
                );
                apply_fault(&mut faults, &step, &writer, &reply)?;
            }
            Kind::ReduceStats => {
                let job = wire::decode_stat_job(&frame)
                    .map_err(|e| anyhow::anyhow!("rank {}: bad stat job: {e}", welcome.rank))?;
                // owner-side statistic mean: f64 accumulate in lane order,
                // multiply by the reciprocal — the lane_mean_mats_wire op
                // sequence (decoding already applied the wire quantization)
                let inv_l = 1.0 / job.lanes.len() as f64;
                let elems = (job.rows * job.cols) as usize;
                let mut mean = vec![0.0f32; elems];
                for (i, v) in mean.iter_mut().enumerate() {
                    let mut s = 0.0f64;
                    for lane in &job.lanes {
                        s += lane[i] as f64;
                    }
                    *v = (s * inv_l) as f32;
                }
                let reply = wire::encode_stat_result(job.item, job.rows, job.cols, &mean);
                apply_fault(&mut faults, &step, &writer, &reply)?;
            }
            Kind::Hello | Kind::Welcome | Kind::GradSeg | Kind::StatResult | Kind::Pong => {
                anyhow::bail!("rank {}: unexpected {:?} from coordinator", welcome.rank, frame.kind)
            }
        }
    }
}

/// Send a job reply, unless a scripted fault says otherwise. Faults
/// fire once, at the first reduction job of their step.
fn apply_fault(
    faults: &mut ArmedFaults,
    step: &AtomicU64,
    writer: &Writer,
    reply: &Frame,
) -> anyhow::Result<()> {
    let fault: Option<Fault> = faults.take(step.load(Ordering::Relaxed));
    match fault.map(|f| f.kind) {
        None => {
            writer.send(reply).map_err(|e| anyhow::anyhow!("send reply: {e}"))?;
        }
        Some(FaultKind::Kill) => {
            crate::warn_!(LOG, "fault: kill at step {}", step.load(Ordering::Relaxed));
            std::process::exit(9);
        }
        Some(FaultKind::Drop) => {
            crate::warn_!(LOG, "fault: dropping one reply frame");
        }
        Some(FaultKind::Delay) => {
            let ms = fault.map(|f| f.ms).unwrap_or(200);
            crate::warn_!(LOG, "fault: delaying reply by {ms} ms");
            std::thread::sleep(Duration::from_millis(ms));
            writer.send(reply).map_err(|e| anyhow::anyhow!("send reply: {e}"))?;
        }
        Some(FaultKind::Corrupt) => {
            crate::warn_!(LOG, "fault: corrupting one reply frame");
            let mut bytes = reply.encode();
            // flip a payload byte AFTER the checksum was computed: the
            // coordinator must detect this as a checksum mismatch
            let i = wire::HEADER_BYTES.min(bytes.len() - 1);
            bytes[i] ^= 0xff;
            writer.send_raw(&bytes).map_err(|e| anyhow::anyhow!("send reply: {e}"))?;
        }
        Some(FaultKind::Mute) => {
            crate::warn_!(LOG, "fault: going mute (no heartbeats, no replies)");
            writer.muted.store(true, Ordering::Relaxed);
        }
    }
    Ok(())
}
