//! Deterministic failure injection for the multi-process transport.
//!
//! A [`FaultPlan`] is a comma-separated list of `kind:step:rank[:ms]`
//! directives parsed from `SPNGD_FAULT_PLAN` (or `--fault-plan`). The
//! coordinator passes the plan to every worker it spawns through the
//! environment; each worker keeps only the directives addressed to its
//! rank and fires each one exactly once, at the first reduction job of
//! the named step — so a test can script "worker 1 dies at step 3" and
//! get the same failure on every run.
//!
//! Kinds:
//! - `kill`   — `process::exit(9)` before replying (a hard crash)
//! - `drop`   — swallow one job: never send the reply frame
//! - `delay`  — sleep `ms` (default 200) before replying
//! - `corrupt`— flip a payload byte after the checksum is computed, so
//!   the coordinator sees a checksum mismatch
//! - `mute`   — stop heartbeating and replying (a hung process)

use std::fmt;

/// What a directive does to the targeted worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Kill,
    Drop,
    Delay,
    Corrupt,
    Mute,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Kill => "kill",
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Mute => "mute",
        }
    }

    fn parse(s: &str) -> Result<FaultKind, String> {
        match s {
            "kill" => Ok(FaultKind::Kill),
            "drop" => Ok(FaultKind::Drop),
            "delay" => Ok(FaultKind::Delay),
            "corrupt" => Ok(FaultKind::Corrupt),
            "mute" => Ok(FaultKind::Mute),
            other => {
                Err(format!("unknown fault kind '{other}' (kill | drop | delay | corrupt | mute)"))
            }
        }
    }
}

/// One scripted fault: fire `kind` on worker `rank` at training `step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    pub kind: FaultKind,
    pub step: u64,
    pub rank: u32,
    /// delay duration in ms (only meaningful for `Delay`).
    pub ms: u64,
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}", self.kind.name(), self.step, self.rank)?;
        if self.kind == FaultKind::Delay {
            write!(f, ":{}", self.ms)?;
        }
        Ok(())
    }
}

/// A deterministic failure script, shared coordinator → workers via env.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse `kind:step:rank[:ms]` directives, comma-separated. Malformed
    /// plans are a hard error — a fault test that silently runs healthy
    /// is worse than one that fails to start.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                return Err(format!("fault '{part}': want kind:step:rank[:ms]"));
            }
            let kind = FaultKind::parse(fields[0])?;
            let step: u64 = fields[1]
                .parse()
                .map_err(|_| format!("fault '{part}': bad step '{}'", fields[1]))?;
            let rank: u32 = fields[2]
                .parse()
                .map_err(|_| format!("fault '{part}': bad rank '{}'", fields[2]))?;
            let ms = match fields.get(3) {
                Some(v) => {
                    v.parse().map_err(|_| format!("fault '{part}': bad ms '{v}'"))?
                }
                None => 200,
            };
            faults.push(Fault { kind, step, rank, ms });
        }
        Ok(FaultPlan { faults })
    }

    /// Resolve from `SPNGD_FAULT_PLAN` (empty plan when unset; malformed
    /// values are a hard error, mirroring the other env registries).
    pub fn from_env() -> FaultPlan {
        match std::env::var("SPNGD_FAULT_PLAN") {
            Ok(v) if !v.trim().is_empty() => {
                FaultPlan::parse(&v).unwrap_or_else(|e| panic!("SPNGD_FAULT_PLAN: {e}"))
            }
            _ => FaultPlan::default(),
        }
    }

    /// The env-var spelling of this plan (what the coordinator exports to
    /// spawned workers).
    pub fn to_env(&self) -> String {
        self.faults.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
    }

    /// The directives addressed to one worker rank.
    pub fn for_rank(&self, rank: u32) -> Vec<Fault> {
        self.faults.iter().copied().filter(|f| f.rank == rank).collect()
    }
}

/// A worker's armed directives: each fires at most once, at the first
/// matching job of its step.
#[derive(Debug, Default)]
pub struct ArmedFaults {
    pending: Vec<Fault>,
}

impl ArmedFaults {
    pub fn new(faults: Vec<Fault>) -> ArmedFaults {
        ArmedFaults { pending: faults }
    }

    /// Take the fault scheduled for `step`, if any (fire-once: the
    /// directive is removed).
    pub fn take(&mut self, step: u64) -> Option<Fault> {
        let i = self.pending.iter().position(|f| f.step == step)?;
        Some(self.pending.swap_remove(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_through_env_spelling() {
        let p = FaultPlan::parse("kill:3:1, drop:2:0,delay:4:1:150,corrupt:5:0,mute:4:2").unwrap();
        assert_eq!(p.faults.len(), 5);
        assert_eq!(p.faults[0], Fault { kind: FaultKind::Kill, step: 3, rank: 1, ms: 200 });
        assert_eq!(p.faults[2], Fault { kind: FaultKind::Delay, step: 4, rank: 1, ms: 150 });
        let p2 = FaultPlan::parse(&p.to_env()).unwrap();
        assert_eq!(p, p2);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ,  ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for bad in [
            "explode:1:0",
            "kill:one:0",
            "kill:1:two",
            "kill:1",
            "kill:1:0:5:9",
            "delay:1:0:soon",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn rank_filter_and_fire_once() {
        let p = FaultPlan::parse("kill:3:1,drop:2:0,delay:3:1:50").unwrap();
        assert!(p.for_rank(2).is_empty());
        let mut armed = ArmedFaults::new(p.for_rank(1));
        assert!(armed.take(2).is_none());
        let first = armed.take(3).unwrap();
        let second = armed.take(3).unwrap();
        assert_ne!(first.kind, second.kind, "both step-3 directives fire, once each");
        assert!(armed.take(3).is_none(), "fire-once");
    }
}
