//! Engine: PJRT CPU client + compiled-executable cache (cargo feature
//! `pjrt`).
//!
//! Artifacts are HLO text; compilation happens once at startup (or lazily
//! on first use) and the compiled executables are shared by all simulated
//! workers. Execution is behind `&self` — the PJRT CPU client is
//! thread-safe — so Stage-1/Stage-4 work can run from the worker pool.
//!
//! The default build ships the vendored `xla` *stub* (see
//! `rust/vendor/xla`): this module compiles, but [`Engine::new`] reports
//! that real PJRT bindings are required. Swap the path dependency to run
//! actual HLO artifacts.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Cumulative execution accounting (for the perf pass + benches).
#[derive(Default, Debug)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub exec_nanos: AtomicU64,
    pub compile_nanos: AtomicU64,
}

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    files: BTreeMap<String, String>,
    exes: RwLock<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    compile_lock: Mutex<()>,
    pub stats: EngineStats,
}

impl Engine {
    /// Create an engine over a parsed manifest (CPU PJRT client).
    pub fn new(manifest: &Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: manifest.dir.clone(),
            files: manifest.executables.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            exes: RwLock::new(BTreeMap::new()),
            compile_lock: Mutex::new(()),
            stats: EngineStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure an executable is compiled; returns whether it was a cache miss.
    pub fn ensure_compiled(&self, name: &str) -> Result<bool> {
        if self.exes.read().unwrap().contains_key(name) {
            return Ok(false);
        }
        // serialize compilation (PJRT compile is heavyweight); re-check
        // under the lock to avoid duplicate compiles.
        let _g = self.compile_lock.lock().unwrap();
        if self.exes.read().unwrap().contains_key(name) {
            return Ok(false);
        }
        let file = self
            .files
            .get(name)
            .with_context(|| format!("executable '{name}' not in manifest"))?;
        let path = self.dir.join(file);
        // lint:allow(determinism) -- compile-time accounting only, never step math
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats
            .compile_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.exes.write().unwrap().insert(name.to_string(), exe);
        Ok(true)
    }

    /// Compile every executable named in the manifest (warm start).
    pub fn compile_all(&self) -> Result<usize> {
        let names: Vec<String> = self.files.keys().cloned().collect();
        let mut n = 0;
        for name in names {
            if self.ensure_compiled(&name)? {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Execute an artifact by name. Inputs are f32 host tensors (plus
    /// `extra_u32` appended as scalar u32 literals, e.g. the 1mc seed).
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute_seeded(name, inputs, None)
    }

    pub fn execute_seeded(
        &self,
        name: &str,
        inputs: &[&HostTensor],
        seed: Option<u32>,
    ) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        // lint:allow(determinism) -- exec-time accounting only, never step math
        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(inputs.len() + 1);
        for t in inputs {
            lits.push(t.to_literal()?);
        }
        if let Some(s) = seed {
            lits.push(xla::Literal::scalar(s));
        }
        let guard = self.exes.read().unwrap();
        let exe = guard.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        drop(guard);
        // All artifacts are lowered with return_tuple=True.
        let parts = lit.to_tuple().context("decomposing output tuple")?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(HostTensor::from_literal(&p)?);
        }
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Total seconds spent inside PJRT execute calls.
    pub fn exec_seconds(&self) -> f64 {
        self.stats.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

impl super::Executor for Engine {
    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn execute_seeded(
        &self,
        name: &str,
        inputs: &[&HostTensor],
        seed: Option<u32>,
    ) -> Result<Vec<HostTensor>> {
        Engine::execute_seeded(self, name, inputs, seed)
    }

    fn ensure_compiled(&self, name: &str) -> Result<bool> {
        Engine::ensure_compiled(self, name)
    }

    fn exec_seconds(&self) -> f64 {
        Engine::exec_seconds(self)
    }
}
