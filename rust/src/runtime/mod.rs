//! Runtime: loads the AOT artifacts (HLO text + manifest) and executes
//! them on the PJRT CPU client via the `xla` crate.
//!
//! This is the only module that touches PJRT; the coordinator sees
//! [`Engine`] (execute-by-name over [`HostTensor`]s) and the parsed
//! [`manifest::Manifest`].

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{KfacLayer, Manifest, ModelManifest, OutputSpec};
pub use tensor::HostTensor;
