//! Runtime: pluggable execution backends behind the [`Executor`] trait.
//!
//! The coordinator addresses compute by *executable name* (the contract
//! recorded in [`manifest::Manifest`]) and never sees a backend type:
//!
//! - [`native`] — pure-rust CPU backend (default). Implements the full
//!   SP-NGD training path (model fwd/bwd with K-FAC statistics capture,
//!   im2col/SYRK factor construction, Newton-Schulz inversion,
//!   preconditioning) on top of `linalg`, and synthesizes the manifest
//!   in-process — no artifacts, no XLA toolchain, no network.
//! - [`engine`] (cargo feature `pjrt`) — loads the AOT HLO artifacts
//!   produced by `python/compile` and executes them through the PJRT C
//!   API (`xla` crate).

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{KfacLayer, Manifest, ModelManifest, OutputSpec};
pub use native::NativeBackend;
pub use tensor::HostTensor;

use std::sync::Arc;

use anyhow::Result;

/// Execute-by-name over [`HostTensor`]s — the seam between the
/// coordinator (L3) and whichever kernel substrate (L1/L2) is compiled
/// in. Object-safe so the trainer can hold an `Arc<dyn Executor>`;
/// `Send + Sync` so the `dist` engine can drive one executor per worker
/// OS thread.
pub trait Executor: Send + Sync {
    /// Backend identifier (e.g. "native-cpu", PJRT platform name).
    fn platform(&self) -> String;

    /// Execute an executable by manifest name. `seed` feeds stochastic
    /// executables (the 1mc Fisher's Monte-Carlo label sample).
    fn execute_seeded(
        &self,
        name: &str,
        inputs: &[&HostTensor],
        seed: Option<u32>,
    ) -> Result<Vec<HostTensor>>;

    /// Execute without a seed.
    fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute_seeded(name, inputs, None)
    }

    /// Prepare an executable ahead of time; returns whether work happened
    /// (PJRT compiles HLO here; the native backend only validates the
    /// name). Whole-manifest warmup stays backend-specific — see
    /// `Engine::compile_all`.
    fn ensure_compiled(&self, name: &str) -> Result<bool>;

    /// Cumulative seconds spent executing (perf instrumentation).
    fn exec_seconds(&self) -> f64;

    /// A backend instance dedicated to one `dist` worker thread (own
    /// scratch arena / caches, zero shared mutable state with `self`).
    /// `None` means the backend has no per-worker state worth isolating —
    /// callers then share `self` across workers (it is `Sync`).
    fn fork_worker(&self) -> Option<Arc<dyn Executor>> {
        None
    }
}
