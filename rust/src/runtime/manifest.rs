//! Parsed view of `artifacts/manifest.json` — the contract between the
//! python AOT pipeline (L2) and the rust coordinator (L3).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One tensor output of the step executable.
#[derive(Clone, Debug)]
pub struct OutputSpec {
    pub name: String,
    /// loss | ncorrect | grad | a_tap | g_tap | g_gamma | g_beta |
    /// bn_mean | bn_var
    pub role: String,
    pub layer: Option<String>,
    pub param: Option<String>,
    pub shape: Vec<usize>,
}

/// A K-FAC-tracked layer (conv / fc / bn).
#[derive(Clone, Debug)]
pub struct KfacLayer {
    pub name: String,
    pub kind: String, // "conv" | "fc" | "bn"
    // conv/fc:
    pub a_dim: usize,
    pub g_dim: usize,
    pub a_bucket: usize,
    pub g_bucket: usize,
    pub grad_shape: (usize, usize),
    pub factor_a: String,
    pub factor_g: String,
    pub invert_a: String,
    pub invert_g: String,
    pub precond: String,
    pub weight_param: String,
    // bn:
    pub channels: usize,
    pub bn_inv: String,
    pub bn_full: String,
    pub invert_full: String,
    pub full_bucket: usize,
    pub gamma_param: String,
    pub beta_param: String,
}

impl KfacLayer {
    pub fn is_bn(&self) -> bool {
        self.kind == "bn"
    }
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub batch: usize,
    pub params: Vec<ParamSpec>,
    pub init_file: String,
    pub kfac_layers: Vec<KfacLayer>,
    pub bn_order: Vec<String>,
    pub step_outputs: Vec<OutputSpec>,
    pub step_emp: String,
    pub step_1mc: String,
    pub eval_exe: String,
    /// inference-only forward executable ((params…, x, bn stats) →
    /// logits), used by `spngd serve`; empty when the manifest predates
    /// the predict contract (AOT manifests without an `executables.predict`
    /// entry)
    pub predict_exe: String,
}

impl ModelManifest {
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    pub fn layer(&self, name: &str) -> Option<&KfacLayer> {
        self.kfac_layers.iter().find(|l| l.name == name)
    }

    /// Indices into the step output tuple by (role, layer/param key).
    pub fn output_index(&self, role: &str, key: Option<&str>) -> Option<usize> {
        self.step_outputs.iter().position(|o| {
            o.role == role
                && match key {
                    None => true,
                    Some(k) => {
                        o.layer.as_deref() == Some(k) || o.param.as_deref() == Some(k)
                    }
                }
        })
    }

    pub fn total_param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

/// The whole manifest: models + the executable table.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub ns_iters: usize,
    pub models: BTreeMap<String, ModelManifest>,
    /// executable name -> artifact file name (native backends map a name
    /// to itself — there is no file)
    pub executables: BTreeMap<String, String>,
    /// in-memory initial parameters by model name (native backend);
    /// consulted before `init_file` by [`Manifest::load_init_params`]
    pub init_params: BTreeMap<String, Vec<super::HostTensor>>,
}

fn as_usize(j: &Json, what: &str) -> Result<usize> {
    j.as_usize().with_context(|| format!("manifest: {what} not a usize: {j:?}"))
}

fn as_str(j: &Json, what: &str) -> Result<String> {
    Ok(j.as_str().with_context(|| format!("manifest: {what} not a string"))?.to_string())
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| as_usize(d, "shape dim"))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut executables = BTreeMap::new();
        for (name, e) in root.get("executables").as_obj().context("executables")? {
            executables.insert(name.clone(), as_str(e.get("file"), "file")?);
        }

        let mut models = BTreeMap::new();
        for (mname, m) in root.get("models").as_obj().context("models")? {
            let mut params = Vec::new();
            for p in m.get("params").as_arr().context("params")? {
                params.push(ParamSpec {
                    name: as_str(p.get("name"), "param name")?,
                    shape: shape_of(p.get("shape"))?,
                });
            }
            let mut kfac_layers = Vec::new();
            for l in m.get("kfac_layers").as_arr().context("kfac_layers")? {
                let name = as_str(l.get("name"), "layer name")?;
                let kind = as_str(l.get("kind"), "kind")?;
                // required-per-kind fields: a missing or mistyped one is a
                // hard parse error naming the layer and the field — never
                // a silent 0 / "" that fails later at execution time
                let req_usize = |field: &str| -> Result<usize> {
                    l.get(field).as_usize().with_context(|| {
                        format!("manifest: layer '{name}' ({kind}): missing field '{field}'")
                    })
                };
                let req_str = |field: &str| -> Result<String> {
                    match l.get(field).as_str() {
                        Some(s) if !s.is_empty() => Ok(s.to_string()),
                        _ => bail!("manifest: layer '{name}' ({kind}): missing field '{field}'"),
                    }
                };
                let layer = match kind.as_str() {
                    "bn" => KfacLayer {
                        name: name.clone(),
                        kind: kind.clone(),
                        a_dim: 0,
                        g_dim: 0,
                        a_bucket: 0,
                        g_bucket: 0,
                        grad_shape: (0, 0),
                        factor_a: String::new(),
                        factor_g: String::new(),
                        invert_a: String::new(),
                        invert_g: String::new(),
                        precond: String::new(),
                        weight_param: String::new(),
                        channels: req_usize("channels")?,
                        bn_inv: req_str("bn_inv")?,
                        bn_full: req_str("bn_full")?,
                        invert_full: req_str("invert_full")?,
                        full_bucket: req_usize("full_bucket")?,
                        gamma_param: req_str("gamma_param")?,
                        beta_param: req_str("beta_param")?,
                    },
                    "conv" | "fc" => {
                        let gs = l.get("grad_shape");
                        KfacLayer {
                            name: name.clone(),
                            kind: kind.clone(),
                            a_dim: req_usize("a_dim")?,
                            g_dim: req_usize("g_dim")?,
                            a_bucket: req_usize("a_bucket")?,
                            g_bucket: req_usize("g_bucket")?,
                            grad_shape: (
                                as_usize(gs.at(0), "grad rows").with_context(|| {
                                    format!("manifest: layer '{name}' ({kind}): grad_shape")
                                })?,
                                as_usize(gs.at(1), "grad cols").with_context(|| {
                                    format!("manifest: layer '{name}' ({kind}): grad_shape")
                                })?,
                            ),
                            factor_a: req_str("factor_a")?,
                            factor_g: req_str("factor_g")?,
                            invert_a: req_str("invert_a")?,
                            invert_g: req_str("invert_g")?,
                            precond: req_str("precond")?,
                            weight_param: req_str("weight_param")?,
                            channels: 0,
                            bn_inv: String::new(),
                            bn_full: String::new(),
                            invert_full: String::new(),
                            full_bucket: 0,
                            gamma_param: String::new(),
                            beta_param: String::new(),
                        }
                    }
                    other => bail!(
                        "manifest: layer '{name}': unknown kind '{other}' (expected conv | fc | bn)"
                    ),
                };
                kfac_layers.push(layer);
            }
            let mut step_outputs = Vec::new();
            for o in m.get("step_outputs").as_arr().context("step_outputs")? {
                step_outputs.push(OutputSpec {
                    name: as_str(o.get("name"), "output name")?,
                    role: as_str(o.get("role"), "output role")?,
                    layer: o.get("layer").as_str().map(|s| s.to_string()),
                    param: o.get("param").as_str().map(|s| s.to_string()),
                    shape: shape_of(o.get("shape"))?,
                });
            }
            let exes = m.get("executables");
            let bn_order = m
                .get("bn_order")
                .as_arr()
                .context("bn_order")?
                .iter()
                .map(|b| as_str(b, "bn name"))
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                mname.clone(),
                ModelManifest {
                    name: mname.clone(),
                    input_shape: shape_of(m.get("input_shape"))?,
                    num_classes: as_usize(m.get("num_classes"), "num_classes")?,
                    batch: as_usize(m.get("batch"), "batch")?,
                    params,
                    init_file: as_str(m.get("init_file"), "init_file")?,
                    kfac_layers,
                    bn_order,
                    step_outputs,
                    step_emp: as_str(exes.get("step_emp"), "step_emp")?,
                    step_1mc: as_str(exes.get("step_1mc"), "step_1mc")?,
                    eval_exe: as_str(exes.get("eval"), "eval")?,
                    // optional: manifests predating the predict contract
                    // simply have no inference executable
                    predict_exe: exes
                        .get("predict")
                        .as_str()
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            ns_iters: root.get("ns_iters").as_usize().unwrap_or(20),
            models,
            executables,
            init_params: BTreeMap::new(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        match self.models.get(name) {
            Some(m) => Ok(m),
            None => bail!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            ),
        }
    }

    /// Load the initial parameters for a model: the in-memory table
    /// (native backend) if present, else the raw f32-LE `init_file`
    /// artifact (param order).
    pub fn load_init_params(&self, model: &ModelManifest) -> Result<Vec<super::HostTensor>> {
        if let Some(params) = self.init_params.get(&model.name) {
            return Ok(params.clone());
        }
        let bytes = std::fs::read(self.dir.join(&model.init_file))
            .with_context(|| format!("reading {}", model.init_file))?;
        let mut floats = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            floats.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        let mut out = Vec::new();
        let mut off = 0;
        for p in &model.params {
            let n: usize = p.shape.iter().product();
            anyhow::ensure!(off + n <= floats.len(), "init file too short at {}", p.name);
            out.push(super::HostTensor::new(p.shape.clone(), floats[off..off + n].to_vec()));
            off += n;
        }
        anyhow::ensure!(off == floats.len(), "init file has trailing bytes");
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal synthetic manifest exercising the parser.
    fn sample() -> String {
        r#"{
 "version": 1, "ns_iters": 22,
 "executables": {"step_m_emp": {"file": "step_m_emp.hlo.txt"},
                 "invert_16": {"file": "invert_16.hlo.txt"}},
 "models": {"m": {
   "input_shape": [4,3,8,8], "num_classes": 10, "batch": 4,
   "params": [{"name":"fc.w","shape":[10,192]}],
   "init_file": "init_m.bin",
   "bn_order": [],
   "kfac_layers": [{"name":"fc","kind":"fc","a_dim":192,"g_dim":10,
     "a_bucket":192,"g_bucket":16,"grad_shape":[10,192],
     "factor_a":"fa","factor_g":"fg","invert_a":"invert_192",
     "invert_g":"invert_16","precond":"precond_10x192",
     "weight_param":"fc.w"}],
   "step_outputs": [
     {"name":"loss","role":"loss","shape":[]},
     {"name":"ncorrect","role":"ncorrect","shape":[]},
     {"name":"grad:fc.w","role":"grad","param":"fc.w","shape":[10,192]},
     {"name":"a_tap:fc","role":"a_tap","layer":"fc","shape":[4,192]},
     {"name":"g_tap:fc","role":"g_tap","layer":"fc","shape":[4,10]}],
   "executables": {"step_emp":"step_m_emp","step_1mc":"step_m_1mc","eval":"eval_m"}
 }}}"#
            .to_string()
    }

    #[test]
    fn parse_sample_manifest() {
        let dir = std::env::temp_dir().join("spngd_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.ns_iters, 22);
        let model = m.model("m").unwrap();
        assert_eq!(model.batch, 4);
        assert_eq!(model.params[0].shape, vec![10, 192]);
        let l = model.layer("fc").unwrap();
        assert_eq!(l.grad_shape, (10, 192));
        assert!(!l.is_bn());
        assert_eq!(model.output_index("loss", None), Some(0));
        assert_eq!(model.output_index("g_tap", Some("fc")), Some(4));
        assert_eq!(model.output_index("grad", Some("fc.w")), Some(2));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_required_layer_field_is_hard_error_naming_it() {
        // drop a required conv/fc field: the parse must fail and the
        // error must name both the layer and the field
        let broken = sample().replace(r#""precond":"precond_10x192","#, "");
        let dir = std::env::temp_dir().join("spngd_manifest_test_neg");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), broken).unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("layer 'fc'"), "{err}");
        assert!(err.contains("'precond'"), "{err}");

        // a bn layer with no channels is equally fatal
        let bn_broken = sample().replace(
            r#""kfac_layers": [{"name":"fc","kind":"fc""#,
            r#""kfac_layers": [{"name":"bad_bn","kind":"bn"}, {"name":"fc","kind":"fc""#,
        );
        std::fs::write(dir.join("manifest.json"), bn_broken).unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("layer 'bad_bn'"), "{err}");
        assert!(err.contains("'channels'"), "{err}");

        // unknown layer kinds are rejected, not defaulted
        let kind_broken = sample().replace(r#""kind":"fc""#, r#""kind":"dense""#);
        std::fs::write(dir.join("manifest.json"), kind_broken).unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("unknown kind 'dense'"), "{err}");
    }

    #[test]
    fn init_params_roundtrip() {
        let dir = std::env::temp_dir().join("spngd_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample()).unwrap();
        let vals: Vec<f32> = (0..1920).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("init_m.bin"), bytes).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let model = m.model("m").unwrap();
        let params = m.load_init_params(model).unwrap();
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].shape, vec![10, 192]);
        assert_eq!(params[0].data[5], 5.0);
    }
}
