//! Host-side tensor: the coordinator's currency for model state, taps,
//! factors and gradients. Conversion to/from `xla::Literal` lives here so
//! nothing else needs the xla crate's types.

use crate::linalg::Mat;

/// Dense f32 tensor with row-major layout.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Interpret as a 2-D matrix (requires rank 2).
    pub fn as_mat(&self) -> Mat {
        assert_eq!(self.rank(), 2, "as_mat requires rank-2 tensor");
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_mat(m: &Mat) -> Self {
        HostTensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Flatten a 4-D (B, C, H, W) tap into (B*H*W, C) — the layout the
    /// conv-G factor executable's syrk consumed at build time (transpose
    /// to channel-last then collapse). Parallel over the batch axis on
    /// the global pool (per-image chunks are contiguous and disjoint).
    pub fn nchw_to_rows_channels(&self) -> HostTensor {
        assert_eq!(self.rank(), 4);
        let (b, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let mut out = vec![0.0f32; b * h * w * c];
        let per_image = h * w * c;
        let image = |bi: usize, chunk: &mut [f32]| {
            for ci in 0..c {
                for hi in 0..h {
                    for wi in 0..w {
                        let src = ((bi * c + ci) * h + hi) * w + wi;
                        chunk[(hi * w + wi) * c + ci] = self.data[src];
                    }
                }
            }
        };
        let pool = crate::util::pool::global();
        if b <= 1 || pool.size() <= 1 || crate::linalg::reference_kernels() {
            for (bi, chunk) in out.chunks_mut(per_image.max(1)).enumerate() {
                image(bi, chunk);
            }
        } else {
            pool.parallel_for_mut(&mut out, per_image, image);
        }
        HostTensor::new(vec![b * h * w, c], out)
    }

    /// Elementwise AXPY: self += alpha * other.
    pub fn axpy_inplace(&mut self, alpha: f32, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// L2 norm of the flattened tensor.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Pad a square (n, n) matrix tensor into (nb, nb) (top-left block);
    /// used to feed bucketed inversion executables.
    pub fn pad_square(&self, nb: usize) -> HostTensor {
        assert_eq!(self.rank(), 2);
        let n = self.shape[0];
        assert_eq!(n, self.shape[1]);
        assert!(nb >= n);
        if nb == n {
            return self.clone();
        }
        let mut out = vec![0.0f32; nb * nb];
        for i in 0..n {
            out[i * nb..i * nb + n].copy_from_slice(&self.data[i * n..(i + 1) * n]);
        }
        HostTensor::new(vec![nb, nb], out)
    }

    /// Slice the top-left (n, n) block out of a square matrix tensor.
    pub fn slice_square(&self, n: usize) -> HostTensor {
        assert_eq!(self.rank(), 2);
        let nb = self.shape[0];
        assert!(n <= nb);
        if nb == n {
            return self.clone();
        }
        let mut out = vec![0.0f32; n * n];
        for i in 0..n {
            out[i * n..(i + 1) * n].copy_from_slice(&self.data[i * nb..i * nb + n]);
        }
        HostTensor::new(vec![n, n], out)
    }
}

/// PJRT interop: conversion to/from `xla::Literal` lives here so nothing
/// else needs the xla crate's types.
#[cfg(feature = "pjrt")]
impl HostTensor {
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match lit.ty()? {
            xla::ElementType::F32 => lit.to_vec::<f32>()?,
            other => anyhow::bail!("unsupported output element type {other:?}"),
        };
        Ok(HostTensor::new(dims, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nchw_conversion_layout() {
        // B=1, C=2, H=1, W=2: [[c0: a b], [c1: c d]] -> rows (hw) x channels
        let t = HostTensor::new(vec![1, 2, 1, 2], vec![1., 2., 3., 4.]);
        let r = t.nchw_to_rows_channels();
        assert_eq!(r.shape, vec![2, 2]);
        // position (h0,w0): channels (1,3); (h0,w1): (2,4)
        assert_eq!(r.data, vec![1., 3., 2., 4.]);
    }

    #[test]
    fn pad_slice_roundtrip() {
        let t = HostTensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let p = t.pad_square(5);
        assert_eq!(p.shape, vec![5, 5]);
        assert_eq!(p.data[0], 1.0);
        assert_eq!(p.data[6], 4.0); // (1,1)
        assert_eq!(p.slice_square(2), t);
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = HostTensor::new(vec![3], vec![1., 2., 2.]);
        let b = HostTensor::new(vec![3], vec![1., 0., 0.]);
        a.axpy_inplace(2.0, &b);
        assert_eq!(a.data, vec![3., 2., 2.]);
        assert!((a.norm() - (17.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 2], vec![1.0]);
    }
}
