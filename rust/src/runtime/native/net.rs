//! Native training-path network: forward/backward over the op program
//! with K-FAC statistics capture — the rust analogue of the L2 JAX model
//! (`python/compile/model.py`).
//!
//! The JAX model obtains per-sample output gradients with the zero-probe
//! trick; here the backward pass materializes dL/ds at every conv/fc/bn
//! pre-activation anyway, which is exactly the probe gradient. Scaling by
//! B recovers the per-sample d log p / ds taps. This implementation is
//! validated against the JAX reference (f64) to ~3e-7 max relative error
//! across all step outputs of `convnet_small`.
//!
//! The per-step hot loop runs on the blocked pool-parallel linalg
//! substrate and a [`Scratch`] arena owned by the backend: conv/fc
//! products use the fused `matmul_transposed` form, patch matrices and
//! flow tensors come from recycled buffers, and the tape returns its
//! buffers to the arena at the end of every step.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::kernels::{col2im_into_with, im2col_into_with};
use super::model::{BnSpec, ConvSpec, FcSpec, LayerGeo, NativeModelCfg, Op};
use crate::linalg::{self, Mat, Scratch};
use crate::runtime::HostTensor;
use crate::util::pool;
use crate::util::rng::Rng;

const BN_EPS: f32 = 1e-5;

/// Elementwise work below which the channel-parallel BN paths dispatch
/// serially (pool fan-out costs more than it saves).
const BN_PAR_CUTOFF: usize = 1 << 14;

/// Run `f(ci)` for every channel 0..c — in parallel on the global pool
/// when the total elementwise `work` is large enough. Channels are
/// independent, so the parallel path is bit-identical to the serial one;
/// `linalg::set_reference_kernels` still forces the serial path so the
/// naive bench baseline stays single-threaded.
fn for_each_channel<F: Fn(usize) + Sync>(work: usize, c: usize, f: F) {
    let pool = pool::global();
    if c <= 1 || pool.size() <= 1 || work < BN_PAR_CUTOFF || linalg::reference_kernels() {
        for ci in 0..c {
            f(ci);
        }
    } else {
        pool.parallel_for(c, 1, |c0, c1| {
            for ci in c0..c1 {
                f(ci);
            }
        });
    }
}

type PDict<'a> = BTreeMap<&'a str, &'a HostTensor>;

fn param<'a>(pdict: &PDict<'a>, name: &str) -> Result<&'a HostTensor> {
    pdict.get(name).copied().with_context(|| format!("missing parameter '{name}'"))
}

// ---------------------------------------------------------------- tape

struct ConvRec {
    spec: ConvSpec,
    patches: Mat,
    xshape: [usize; 4],
    ho: usize,
    wo: usize,
}

struct BnRec {
    spec: BnSpec,
    xhat: HostTensor,
    var: Vec<f32>,
}

enum Tape {
    Save(String),
    Conv(ConvRec),
    Bn(BnRec),
    Relu { out: HostTensor },
    Add { from_save: String, proj: Option<Box<(ConvRec, BnRec)>> },
    GlobalPool { h: usize, w: usize },
    Flatten { shape: Vec<usize> },
    Fc { spec: FcSpec, a: Mat },
}

/// Return every tape-held buffer to the arena (end of step, after the
/// backward pass(es) have consumed the records).
fn recycle_tape(tape: Vec<Tape>, scratch: &mut Scratch) {
    for entry in tape {
        match entry {
            Tape::Conv(rec) => scratch.recycle_mat(rec.patches),
            Tape::Bn(rec) => scratch.recycle(rec.xhat.data),
            Tape::Relu { out } => scratch.recycle(out.data),
            Tape::Fc { a, .. } => scratch.recycle_mat(a),
            Tape::Add { proj: Some(p), .. } => {
                let (crec, brec) = *p;
                scratch.recycle_mat(crec.patches);
                scratch.recycle(brec.xhat.data);
            }
            _ => {}
        }
    }
}

// ------------------------------------------------------------- forward

fn conv_fwd(
    x: &HostTensor,
    w: &HostTensor,
    spec: &ConvSpec,
    scratch: &mut Scratch,
) -> (HostTensor, ConvRec) {
    let (b, h, wd) = (x.shape[0], x.shape[2], x.shape[3]);
    let (ho, wo) = spec.spatial_out(h, wd);
    let ckk = spec.cin * spec.k * spec.k;
    let mut patches = scratch.mat_spare(b * ho * wo, ckk);
    im2col_into_with(pool::global(), x, spec.k, spec.stride, spec.pad, &mut patches);
    let wm = scratch.mat_from(spec.cout, ckk, &w.data);
    let mut s_rows = scratch.mat_spare(b * ho * wo, spec.cout);
    patches.matmul_transposed_into(&wm, &mut s_rows); // (B*ho*wo, cout)
    scratch.recycle_mat(wm);
    // rows→NCHW transpose, parallel over the batch axis (per-image
    // chunks are contiguous and disjoint)
    let mut out = scratch.take(b * spec.cout * ho * wo);
    let per_image = spec.cout * ho * wo;
    let rows_to_nchw = |bi: usize, chunk: &mut [f32]| {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((bi * ho + oy) * wo + ox) * spec.cout;
                for co in 0..spec.cout {
                    chunk[(co * ho + oy) * wo + ox] = s_rows.data[row + co];
                }
            }
        }
    };
    let pool = pool::global();
    if b <= 1 || pool.size() <= 1 || linalg::reference_kernels() {
        for (bi, chunk) in out.chunks_mut(per_image.max(1)).enumerate() {
            rows_to_nchw(bi, chunk);
        }
    } else {
        pool.parallel_for_mut(&mut out, per_image, rows_to_nchw);
    }
    scratch.recycle_mat(s_rows);
    let rec = ConvRec { spec: spec.clone(), patches, xshape: [b, spec.cin, h, wd], ho, wo };
    (HostTensor::new(vec![b, spec.cout, ho, wo], out), rec)
}

/// Training-mode BN: batch statistics; returns (out, rec, mean, var).
fn bn_fwd_train(
    x: &HostTensor,
    gamma: &HostTensor,
    beta: &HostTensor,
    spec: &BnSpec,
    scratch: &mut Scratch,
) -> (HostTensor, BnRec, Vec<f32>, Vec<f32>) {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let n = (b * h * w) as f64;
    let hw = h * w;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    let mut xhat = scratch.take(x.data.len());
    let mut out = scratch.take(x.data.len());
    {
        let meanp = mean.as_mut_ptr() as usize;
        let varp = var.as_mut_ptr() as usize;
        let xhatp = xhat.as_mut_ptr() as usize;
        let outp = out.as_mut_ptr() as usize;
        let xd = &x.data;
        for_each_channel(b * c * hw, c, |ci| {
            let mut acc = 0.0f64;
            for bi in 0..b {
                let base = (bi * c + ci) * hw;
                for i in 0..hw {
                    acc += xd[base + i] as f64;
                }
            }
            let mf = (acc / n) as f32;
            let m = mf as f64;
            let mut vacc = 0.0f64;
            for bi in 0..b {
                let base = (bi * c + ci) * hw;
                for i in 0..hw {
                    let d = xd[base + i] as f64 - m;
                    vacc += d * d;
                }
            }
            let vf = (vacc / n) as f32;
            let rstd = 1.0 / (vf + BN_EPS).sqrt();
            let (g, bt) = (gamma.data[ci], beta.data[ci]);
            // SAFETY: channel ci is visited by exactly one task; the
            // per-channel scalar slots and the (bi, ci, ·) strides are
            // pairwise disjoint across channels, and for_each_channel
            // joins before the enclosing borrows end.
            unsafe {
                *(meanp as *mut f32).add(ci) = mf;
                *(varp as *mut f32).add(ci) = vf;
                for bi in 0..b {
                    let base = (bi * c + ci) * hw;
                    for i in 0..hw {
                        let xh = (xd[base + i] - mf) * rstd;
                        *(xhatp as *mut f32).add(base + i) = xh;
                        *(outp as *mut f32).add(base + i) = g * xh + bt;
                    }
                }
            }
        });
    }
    let shape = x.shape.clone();
    let rec = BnRec {
        spec: spec.clone(),
        xhat: HostTensor::new(shape.clone(), xhat),
        var: var.clone(),
    };
    (HostTensor::new(shape, out), rec, mean, var)
}

/// Eval-mode BN: normalize with running statistics.
fn bn_fwd_eval(
    x: &HostTensor,
    gamma: &HostTensor,
    beta: &HostTensor,
    mean: &HostTensor,
    var: &HostTensor,
    scratch: &mut Scratch,
) -> HostTensor {
    let (b, c, hw) = (x.shape[0], x.shape[1], x.shape[2] * x.shape[3]);
    let mut out = scratch.take(x.data.len());
    {
        let outp = out.as_mut_ptr() as usize;
        let xd = &x.data;
        for_each_channel(b * c * hw, c, |ci| {
            let rstd = 1.0 / (var.data[ci] + BN_EPS).sqrt();
            let (g, bt) = (gamma.data[ci], beta.data[ci]);
            let m = mean.data[ci];
            // SAFETY: the (bi, ci, ·) strides are pairwise disjoint
            // across channels; for_each_channel joins before `out` is
            // used again.
            unsafe {
                for bi in 0..b {
                    let base = (bi * c + ci) * hw;
                    for i in 0..hw {
                        *(outp as *mut f32).add(base + i) = g * (xd[base + i] - m) * rstd + bt;
                    }
                }
            }
        });
    }
    HostTensor::new(x.shape.clone(), out)
}

struct Forward {
    logits: Mat,
    tape: Vec<Tape>,
    a_taps: BTreeMap<String, HostTensor>,
    bn_stats: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
}

/// Conv application shared by the main path and Add projections:
/// captures the a-tap and the tape record in training mode.
fn apply_conv(
    flow: &HostTensor,
    cs: &ConvSpec,
    pdict: &PDict,
    train: bool,
    a_taps: &mut BTreeMap<String, HostTensor>,
    scratch: &mut Scratch,
) -> Result<(HostTensor, Option<ConvRec>)> {
    let w = param(pdict, &format!("{}.w", cs.name))?;
    if train {
        a_taps.insert(cs.name.clone(), flow.clone());
    }
    let (out, rec) = conv_fwd(flow, w, cs, scratch);
    if train {
        Ok((out, Some(rec)))
    } else {
        scratch.recycle_mat(rec.patches);
        Ok((out, None))
    }
}

/// Replace `flow` with `next`, returning the dead buffer to the arena.
fn advance(flow: &mut HostTensor, next: HostTensor, scratch: &mut Scratch) {
    let prev = std::mem::replace(flow, next);
    scratch.recycle(prev.data);
}

/// Run the op program. `bn_running` selects eval mode (running BN stats,
/// no tape/tap capture); `None` is training mode with full capture.
fn forward(
    cfg: &NativeModelCfg,
    pdict: &PDict,
    x: &HostTensor,
    bn_running: Option<&BTreeMap<&str, (&HostTensor, &HostTensor)>>,
    scratch: &mut Scratch,
) -> Result<Forward> {
    let train = bn_running.is_none();
    let mut flow = x.clone();
    let mut tape = Vec::new();
    let mut a_taps = BTreeMap::new();
    let mut bn_stats = BTreeMap::new();
    let mut saved: Vec<(String, HostTensor)> = Vec::new();

    for op in &cfg.ops {
        match op {
            Op::Save(name) => {
                saved.push((name.clone(), flow.clone()));
                if train {
                    tape.push(Tape::Save(name.clone()));
                }
            }
            Op::Conv(cs) => {
                let (out, rec) = apply_conv(&flow, cs, pdict, train, &mut a_taps, scratch)?;
                if let Some(rec) = rec {
                    tape.push(Tape::Conv(rec));
                }
                advance(&mut flow, out, scratch);
            }
            Op::Bn(bs) => {
                let gamma = param(pdict, &format!("{}.gamma", bs.name))?;
                let beta = param(pdict, &format!("{}.beta", bs.name))?;
                match bn_running {
                    Some(run) => {
                        let (m, v) = *run
                            .get(bs.name.as_str())
                            .with_context(|| format!("missing running stats for {}", bs.name))?;
                        let out = bn_fwd_eval(&flow, gamma, beta, m, v, scratch);
                        advance(&mut flow, out, scratch);
                    }
                    None => {
                        let (out, rec, mean, var) = bn_fwd_train(&flow, gamma, beta, bs, scratch);
                        bn_stats.insert(bs.name.clone(), (mean, var));
                        tape.push(Tape::Bn(rec));
                        advance(&mut flow, out, scratch);
                    }
                }
            }
            Op::Relu => {
                let mut out = HostTensor::new(flow.shape.clone(), scratch.take_from(&flow.data));
                for v in out.data.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                if train {
                    let copy = HostTensor::new(out.shape.clone(), scratch.take_from(&out.data));
                    tape.push(Tape::Relu { out: copy });
                }
                advance(&mut flow, out, scratch);
            }
            Op::Add { from_save, proj } => {
                let mut shortcut = saved
                    .iter()
                    .rev()
                    .find(|(n, _)| n == from_save)
                    .with_context(|| format!("add from unknown save '{from_save}'"))?
                    .1
                    .clone();
                let mut tape_proj = None;
                if let Some(p) = proj {
                    let (out, crec) =
                        apply_conv(&shortcut, &p.0, pdict, train, &mut a_taps, scratch)?;
                    let gamma = param(pdict, &format!("{}.gamma", p.1.name))?;
                    let beta = param(pdict, &format!("{}.beta", p.1.name))?;
                    scratch.recycle(std::mem::replace(&mut shortcut, out).data);
                    let bn_out = match bn_running {
                        Some(run) => {
                            let (m, v) = *run.get(p.1.name.as_str()).with_context(|| {
                                format!("missing running stats for {}", p.1.name)
                            })?;
                            bn_fwd_eval(&shortcut, gamma, beta, m, v, scratch)
                        }
                        None => {
                            let (bn_out, brec, mean, var) =
                                bn_fwd_train(&shortcut, gamma, beta, &p.1, scratch);
                            bn_stats.insert(p.1.name.clone(), (mean, var));
                            tape_proj = Some(Box::new((
                                crec.expect("training mode records conv"),
                                brec,
                            )));
                            bn_out
                        }
                    };
                    scratch.recycle(std::mem::replace(&mut shortcut, bn_out).data);
                }
                flow.axpy_inplace(1.0, &shortcut);
                scratch.recycle(shortcut.data);
                if train {
                    tape.push(Tape::Add { from_save: from_save.clone(), proj: tape_proj });
                }
            }
            Op::GlobalPool => {
                let (b, c, h, w) = (flow.shape[0], flow.shape[1], flow.shape[2], flow.shape[3]);
                let inv = 1.0 / (h * w) as f32;
                let mut out = scratch.take(b * c);
                for bi in 0..b {
                    for ci in 0..c {
                        let base = (bi * c + ci) * h * w;
                        let mut acc = 0.0f64;
                        for i in 0..h * w {
                            acc += flow.data[base + i] as f64;
                        }
                        out[bi * c + ci] = acc as f32 * inv;
                    }
                }
                if train {
                    tape.push(Tape::GlobalPool { h, w });
                }
                advance(&mut flow, HostTensor::new(vec![b, c, 1, 1], out), scratch);
            }
            Op::Flatten => {
                if train {
                    tape.push(Tape::Flatten { shape: flow.shape.clone() });
                }
                let b = flow.shape[0];
                let d = flow.len() / b;
                flow = flow.reshape(vec![b, d]);
            }
            Op::Fc(fs) => {
                let w = param(pdict, &format!("{}.w", fs.name))?;
                let a = scratch.mat_from(flow.shape[0], flow.shape[1], &flow.data);
                let wm = scratch.mat_from(fs.dout, fs.din, &w.data);
                let mut out = scratch.mat_spare(a.rows, fs.dout);
                a.matmul_transposed_into(&wm, &mut out); // (B, dout)
                scratch.recycle_mat(wm);
                if train {
                    a_taps.insert(fs.name.clone(), flow.clone());
                    tape.push(Tape::Fc { spec: fs.clone(), a });
                } else {
                    scratch.recycle_mat(a);
                }
                let next = HostTensor::new(vec![out.rows, out.cols], out.data);
                advance(&mut flow, next, scratch);
            }
        }
    }
    anyhow::ensure!(
        flow.rank() == 2 && flow.shape[1] == cfg.num_classes,
        "program did not end at the logits (shape {:?})",
        flow.shape
    );
    Ok(Forward { logits: flow.as_mat(), tape, a_taps, bn_stats })
}

// ------------------------------------------------------------ backward

#[derive(Default)]
struct Captured {
    grads: BTreeMap<String, HostTensor>,
    g_taps: BTreeMap<String, HostTensor>,
    /// per-sample (B, C) taps: (g_gamma, g_beta)
    bn_taps: BTreeMap<String, (HostTensor, HostTensor)>,
}

fn scaled(t: &HostTensor, s: f32) -> HostTensor {
    let mut out = t.clone();
    out.scale_inplace(s);
    out
}

/// Shared read-only context of one backward pass.
struct BwdCtx<'a, 'p> {
    pdict: &'a PDict<'p>,
    batch: usize,
    record_grads: bool,
    record_taps: bool,
}

fn conv_bwd_step(
    rec: &ConvRec,
    g: &HostTensor,
    ctx: &BwdCtx,
    cap: &mut Captured,
    scratch: &mut Scratch,
) -> Result<HostTensor> {
    let spec = &rec.spec;
    if ctx.record_taps {
        cap.g_taps.insert(spec.name.clone(), scaled(g, ctx.batch as f32));
    }
    let (b, ho, wo) = (rec.xshape[0], rec.ho, rec.wo);
    // NCHW→rows transpose, parallel over the batch axis (per-image
    // chunks are contiguous and disjoint)
    let mut g_rows = scratch.mat(b * ho * wo, spec.cout);
    let per_image = ho * wo * spec.cout;
    let nchw_to_rows = |bi: usize, chunk: &mut [f32]| {
        for co in 0..spec.cout {
            let src = ((bi * spec.cout + co) * ho) * wo;
            for oy in 0..ho {
                for ox in 0..wo {
                    chunk[(oy * wo + ox) * spec.cout + co] = g.data[src + oy * wo + ox];
                }
            }
        }
    };
    let pool = pool::global();
    if b <= 1 || pool.size() <= 1 || linalg::reference_kernels() {
        for (bi, chunk) in g_rows.data.chunks_mut(per_image.max(1)).enumerate() {
            nchw_to_rows(bi, chunk);
        }
    } else {
        pool.parallel_for_mut(&mut g_rows.data, per_image, nchw_to_rows);
    }
    let w = param(ctx.pdict, &format!("{}.w", spec.name))?;
    let ckk = spec.cin * spec.k * spec.k;
    if ctx.record_grads {
        let mut gt = scratch.mat_spare(g_rows.cols, g_rows.rows);
        g_rows.transpose_into(&mut gt);
        let mut dw = scratch.mat_spare(spec.cout, ckk);
        gt.matmul_into(&rec.patches, &mut dw); // (cout, ckk)
        scratch.recycle_mat(gt);
        cap.grads.insert(
            format!("{}.w", spec.name),
            HostTensor::new(vec![spec.cout, spec.cin, spec.k, spec.k], dw.data),
        );
    }
    let wm = scratch.mat_from(spec.cout, ckk, &w.data);
    let mut dpatches = scratch.mat_spare(b * ho * wo, ckk);
    g_rows.matmul_into(&wm, &mut dpatches);
    scratch.recycle_mat(wm);
    scratch.recycle_mat(g_rows);
    let [xb, xc, xh, xw] = rec.xshape;
    let mut dx = HostTensor::new(vec![xb, xc, xh, xw], scratch.take(xb * xc * xh * xw));
    let (k, s, p) = (spec.k, spec.stride, spec.pad);
    col2im_into_with(pool::global(), &dpatches, &rec.xshape, k, s, p, ho, wo, &mut dx);
    scratch.recycle_mat(dpatches);
    Ok(dx)
}

fn bn_bwd_step(
    rec: &BnRec,
    g: &HostTensor,
    ctx: &BwdCtx,
    cap: &mut Captured,
    scratch: &mut Scratch,
) -> Result<HostTensor> {
    let spec = &rec.spec;
    let (b, c, hw) = (g.shape[0], g.shape[1], g.shape[2] * g.shape[3]);
    let n = (b * hw) as f64;
    let gamma = param(ctx.pdict, &format!("{}.gamma", spec.name))?;

    // one pass over g/xhat: per-sample spatial partials, from which both
    // the (B, C) taps and the per-channel reductions derive — channel-
    // parallel, each (bi, ci) partial is independent
    let mut part_g = vec![0.0f64; b * c];
    let mut part_g_xhat = vec![0.0f64; b * c];
    {
        let pg = part_g.as_mut_ptr() as usize;
        let pgx = part_g_xhat.as_mut_ptr() as usize;
        let gd = &g.data;
        let xh = &rec.xhat.data;
        for_each_channel(b * c * hw, c, |ci| {
            for bi in 0..b {
                let base = (bi * c + ci) * hw;
                let (mut ag, mut ab) = (0.0f64, 0.0f64);
                for i in 0..hw {
                    let gv = gd[base + i] as f64;
                    ag += gv * xh[base + i] as f64;
                    ab += gv;
                }
                // SAFETY: slot (bi, ci) is written only by channel ci's
                // task; for_each_channel joins before the partials are
                // read below.
                unsafe {
                    *(pgx as *mut f64).add(bi * c + ci) = ag;
                    *(pg as *mut f64).add(bi * c + ci) = ab;
                }
            }
        });
    }
    if ctx.record_taps {
        let scale = ctx.batch as f32;
        let gg: Vec<f32> = part_g_xhat.iter().map(|&v| v as f32 * scale).collect();
        let gb: Vec<f32> = part_g.iter().map(|&v| v as f32 * scale).collect();
        cap.bn_taps.insert(
            spec.name.clone(),
            (HostTensor::new(vec![b, c], gg), HostTensor::new(vec![b, c], gb)),
        );
    }
    let mut sum_g = vec![0.0f64; c];
    let mut sum_g_xhat = vec![0.0f64; c];
    for bi in 0..b {
        for ci in 0..c {
            sum_g[ci] += part_g[bi * c + ci];
            sum_g_xhat[ci] += part_g_xhat[bi * c + ci];
        }
    }
    if ctx.record_grads {
        let dgamma: Vec<f32> = sum_g_xhat.iter().map(|&v| v as f32).collect();
        let dbeta: Vec<f32> = sum_g.iter().map(|&v| v as f32).collect();
        cap.grads
            .insert(format!("{}.gamma", spec.name), HostTensor::new(vec![c], dgamma));
        cap.grads.insert(format!("{}.beta", spec.name), HostTensor::new(vec![c], dbeta));
    }

    // dxhat = g * gamma; dx = rstd/n * (n*dxhat - Σdxhat - xhat * Σ(dxhat·xhat))
    let mut dx = scratch.take(g.data.len());
    {
        let dxp = dx.as_mut_ptr() as usize;
        let gd = &g.data;
        let xhd = &rec.xhat.data;
        let sum_g = &sum_g;
        let sum_g_xhat = &sum_g_xhat;
        for_each_channel(b * c * hw, c, |ci| {
            let gm = gamma.data[ci] as f64;
            let rstd = 1.0 / ((rec.var[ci] + BN_EPS) as f64).sqrt();
            let sum_dxhat = sum_g[ci] * gm;
            let sum_dxhat_xhat = sum_g_xhat[ci] * gm;
            // SAFETY: the (bi, ci, ·) strides are pairwise disjoint
            // across channels; for_each_channel joins before `dx` is
            // used again.
            unsafe {
                for bi in 0..b {
                    let base = (bi * c + ci) * hw;
                    for i in 0..hw {
                        let dxhat = gd[base + i] as f64 * gm;
                        let xh = xhd[base + i] as f64;
                        *(dxp as *mut f32).add(base + i) =
                            ((rstd / n) * (n * dxhat - sum_dxhat - xh * sum_dxhat_xhat)) as f32;
                    }
                }
            }
        });
    }
    Ok(HostTensor::new(g.shape.clone(), dx))
}

/// Reverse pass over the tape starting from dL/dlogits.
fn backward(
    tape: &[Tape],
    pdict: &PDict,
    dlogits: &Mat,
    batch: usize,
    record_grads: bool,
    record_taps: bool,
    scratch: &mut Scratch,
) -> Result<Captured> {
    let ctx = BwdCtx { pdict, batch, record_grads, record_taps };
    let mut cap = Captured::default();
    let mut g = HostTensor::new(vec![dlogits.rows, dlogits.cols], dlogits.data.clone());
    let mut saved_grads: BTreeMap<String, HostTensor> = BTreeMap::new();

    for entry in tape.iter().rev() {
        match entry {
            Tape::Fc { spec, a } => {
                if record_taps {
                    cap.g_taps.insert(spec.name.clone(), scaled(&g, batch as f32));
                }
                let gm = scratch.mat_from(g.shape[0], g.shape[1], &g.data); // (B, dout)
                if record_grads {
                    let mut gt = scratch.mat_spare(gm.cols, gm.rows);
                    gm.transpose_into(&mut gt);
                    let mut dw = scratch.mat_spare(spec.dout, spec.din);
                    gt.matmul_into(a, &mut dw); // (dout, din)
                    scratch.recycle_mat(gt);
                    cap.grads.insert(
                        format!("{}.w", spec.name),
                        HostTensor::new(vec![spec.dout, spec.din], dw.data),
                    );
                }
                let w = param(pdict, &format!("{}.w", spec.name))?;
                let wm = scratch.mat_from(spec.dout, spec.din, &w.data);
                let mut da = scratch.mat_spare(gm.rows, spec.din);
                gm.matmul_into(&wm, &mut da); // (B, din)
                scratch.recycle_mat(wm);
                scratch.recycle_mat(gm);
                let next = HostTensor::new(vec![batch, spec.din], da.data);
                scratch.recycle(std::mem::replace(&mut g, next).data);
            }
            Tape::Flatten { shape } => {
                g = g.reshape(shape.clone());
            }
            Tape::GlobalPool { h, w } => {
                let (b, c) = (g.shape[0], g.shape[1]);
                let inv = 1.0 / (h * w) as f32;
                let mut out = scratch.take(b * c * h * w);
                for bi in 0..b {
                    for ci in 0..c {
                        let v = g.data[bi * c + ci] * inv;
                        let base = (bi * c + ci) * h * w;
                        for i in 0..h * w {
                            out[base + i] = v;
                        }
                    }
                }
                let next = HostTensor::new(vec![b, c, *h, *w], out);
                scratch.recycle(std::mem::replace(&mut g, next).data);
            }
            Tape::Relu { out } => {
                for (gv, ov) in g.data.iter_mut().zip(out.data.iter()) {
                    if *ov <= 0.0 {
                        *gv = 0.0;
                    }
                }
            }
            Tape::Add { from_save, proj } => {
                let mut branch = HostTensor::new(g.shape.clone(), scratch.take_from(&g.data));
                if let Some(p) = proj {
                    let b2 = bn_bwd_step(&p.1, &branch, &ctx, &mut cap, scratch)?;
                    scratch.recycle(std::mem::replace(&mut branch, b2).data);
                    let b3 = conv_bwd_step(&p.0, &branch, &ctx, &mut cap, scratch)?;
                    scratch.recycle(std::mem::replace(&mut branch, b3).data);
                }
                match saved_grads.get_mut(from_save) {
                    Some(acc) => {
                        acc.axpy_inplace(1.0, &branch);
                        scratch.recycle(branch.data);
                    }
                    None => {
                        saved_grads.insert(from_save.clone(), branch);
                    }
                }
            }
            Tape::Save(name) => {
                if let Some(extra) = saved_grads.remove(name) {
                    g.axpy_inplace(1.0, &extra);
                    scratch.recycle(extra.data);
                }
            }
            Tape::Bn(rec) => {
                let next = bn_bwd_step(rec, &g, &ctx, &mut cap, scratch)?;
                scratch.recycle(std::mem::replace(&mut g, next).data);
            }
            Tape::Conv(rec) => {
                let next = conv_bwd_step(rec, &g, &ctx, &mut cap, scratch)?;
                scratch.recycle(std::mem::replace(&mut g, next).data);
            }
        }
    }
    scratch.recycle(g.data);
    Ok(cap)
}

// ----------------------------------------------------- loss & sampling

/// Softmax cross-entropy over soft labels: (loss, ncorrect, softmax).
fn softmax_xent(logits: &Mat, t: &HostTensor) -> (f32, f32, Mat) {
    let (b, k) = (logits.rows, logits.cols);
    let mut p = Mat::zeros(b, k);
    let mut loss = 0.0f64;
    let mut ncorrect = 0.0f32;
    for bi in 0..b {
        let row = &logits.data[bi * k..(bi + 1) * k];
        let trow = &t.data[bi * k..(bi + 1) * k];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - m) as f64).exp();
        }
        let logsum = m as f64 + sum.ln();
        for j in 0..k {
            p.data[bi * k + j] = (((row[j] - m) as f64).exp() / sum) as f32;
            loss -= trow[j] as f64 * (row[j] as f64 - logsum);
        }
        let am = |xs: &[f32]| {
            xs.iter().enumerate().fold((0usize, f32::NEG_INFINITY), |(ai, av), (i, &v)| {
                if v > av {
                    (i, v)
                } else {
                    (ai, av)
                }
            })
        };
        if am(row).0 == am(trow).0 {
            ncorrect += 1.0;
        }
    }
    ((loss / b as f64) as f32, ncorrect, p)
}

/// dL/dlogits for soft labels: (p − t)/B.
fn dlogits_from(p: &Mat, t: &[f32], batch: usize) -> Mat {
    let inv_b = 1.0 / batch as f32;
    let data = p.data.iter().zip(t.iter()).map(|(pv, tv)| (pv - tv) * inv_b).collect();
    Mat { rows: p.rows, cols: p.cols, data }
}

/// One Monte-Carlo label sample per row: y ~ Categorical(p) (the 1mc
/// Fisher estimate of Eq. 5). Deterministic per seed.
fn sample_labels(p: &Mat, seed: u32) -> Vec<f32> {
    let (b, k) = (p.rows, p.cols);
    let mut rng = Rng::new(seed as u64 ^ 0x1AC5_EED0);
    let mut t = vec![0.0f32; b * k];
    for bi in 0..b {
        let u = rng.f64();
        let mut acc = 0.0f64;
        let mut pick = k - 1;
        for j in 0..k {
            acc += p.data[bi * k + j] as f64;
            if u < acc {
                pick = j;
                break;
            }
        }
        t[bi * k + pick] = 1.0;
    }
    t
}

// --------------------------------------------------------- entrypoints

/// Validate the (x, t) batch inputs against the model config — malformed
/// shapes must surface as errors, not slice panics mid-forward.
fn check_batch_shapes(cfg: &NativeModelCfg, x: &HostTensor, t: &HostTensor) -> Result<()> {
    let (c, h, w) = cfg.in_shape;
    anyhow::ensure!(
        x.shape == [cfg.batch, c, h, w],
        "input shape {:?} != ({}, {c}, {h}, {w})",
        x.shape,
        cfg.batch
    );
    anyhow::ensure!(
        t.shape == [cfg.batch, cfg.num_classes],
        "label shape {:?} != ({}, {})",
        t.shape,
        cfg.batch,
        cfg.num_classes
    );
    Ok(())
}

/// The step executable: (params…, x, t) → loss, ncorrect, grads (param
/// order), a/g taps (kfac order), BN taps, BN batch stats — exactly the
/// output tuple the manifest's `step_outputs` declares.
pub fn run_step(
    cfg: &NativeModelCfg,
    param_names: &[String],
    geo: &[LayerGeo],
    inputs: &[&HostTensor],
    one_mc: bool,
    seed: Option<u32>,
    scratch: &mut Scratch,
) -> Result<Vec<HostTensor>> {
    let np = param_names.len();
    anyhow::ensure!(
        inputs.len() == np + 2,
        "step executable expects {} inputs (params, x, t), got {}",
        np + 2,
        inputs.len()
    );
    let pdict: PDict =
        param_names.iter().map(String::as_str).zip(inputs[..np].iter().copied()).collect();
    let x = inputs[np];
    let t = inputs[np + 1];
    check_batch_shapes(cfg, x, t)?;

    let fwd = forward(cfg, &pdict, x, None, scratch)?;
    let (loss, ncorrect, p) = softmax_xent(&fwd.logits, t);
    let dl = dlogits_from(&p, &t.data, cfg.batch);

    let cap = if one_mc {
        // backward 1: param grads for the true labels; backward 2: taps
        // for the sampled labels (extra backward pass, §4.1)
        let mut cap = backward(&fwd.tape, &pdict, &dl, cfg.batch, true, false, scratch)?;
        let t_mc = sample_labels(&p, seed.unwrap_or(0));
        let dl_mc = dlogits_from(&p, &t_mc, cfg.batch);
        let taps = backward(&fwd.tape, &pdict, &dl_mc, cfg.batch, false, true, scratch)?;
        cap.g_taps = taps.g_taps;
        cap.bn_taps = taps.bn_taps;
        cap
    } else {
        backward(&fwd.tape, &pdict, &dl, cfg.batch, true, true, scratch)?
    };
    recycle_tape(fwd.tape, scratch);

    let mut outs = Vec::with_capacity(2 + np + 2 * geo.len());
    outs.push(HostTensor::scalar(loss));
    outs.push(HostTensor::scalar(ncorrect));
    let mut grads = cap.grads;
    for name in param_names {
        outs.push(grads.remove(name).with_context(|| format!("no gradient for {name}"))?);
    }
    let mut a_taps = fwd.a_taps;
    let mut g_taps = cap.g_taps;
    let mut bn_taps = cap.bn_taps;
    for lg in geo.iter().filter(|lg| lg.kind != "bn") {
        outs.push(a_taps.remove(&lg.name).with_context(|| format!("no a_tap {}", lg.name))?);
        outs.push(g_taps.remove(&lg.name).with_context(|| format!("no g_tap {}", lg.name))?);
    }
    for lg in geo.iter().filter(|lg| lg.kind == "bn") {
        let (gg, gb) =
            bn_taps.remove(&lg.name).with_context(|| format!("no bn taps {}", lg.name))?;
        outs.push(gg);
        outs.push(gb);
    }
    for lg in geo.iter().filter(|lg| lg.kind == "bn") {
        let (mean, var) = fwd
            .bn_stats
            .get(&lg.name)
            .with_context(|| format!("no bn stats {}", lg.name))?;
        outs.push(HostTensor::new(vec![lg.channels], mean.clone()));
        outs.push(HostTensor::new(vec![lg.channels], var.clone()));
    }
    Ok(outs)
}

/// The eval executable: (params…, x, t, bn_means…, bn_vars…) → loss,
/// ncorrect, using the coordinator-maintained running BN statistics.
pub fn run_eval(
    cfg: &NativeModelCfg,
    param_names: &[String],
    geo: &[LayerGeo],
    inputs: &[&HostTensor],
    scratch: &mut Scratch,
) -> Result<Vec<HostTensor>> {
    let np = param_names.len();
    let bn_names: Vec<&str> =
        geo.iter().filter(|lg| lg.kind == "bn").map(|lg| lg.name.as_str()).collect();
    let nb = bn_names.len();
    anyhow::ensure!(
        inputs.len() == np + 2 + 2 * nb,
        "eval executable expects {} inputs, got {}",
        np + 2 + 2 * nb,
        inputs.len()
    );
    let pdict: PDict =
        param_names.iter().map(String::as_str).zip(inputs[..np].iter().copied()).collect();
    let x = inputs[np];
    let t = inputs[np + 1];
    check_batch_shapes(cfg, x, t)?;
    let bn_running: BTreeMap<&str, (&HostTensor, &HostTensor)> = bn_names
        .iter()
        .enumerate()
        .map(|(i, &n)| (n, (inputs[np + 2 + i], inputs[np + 2 + nb + i])))
        .collect();
    let fwd = forward(cfg, &pdict, x, Some(&bn_running), scratch)?;
    let (loss, ncorrect, _) = softmax_xent(&fwd.logits, t);
    Ok(vec![HostTensor::scalar(loss), HostTensor::scalar(ncorrect)])
}

/// The predict executable: (params…, x, bn_means…, bn_vars…) → logits
/// (B, K). The inference-only forward path `spngd serve` runs: no
/// labels, no loss — just the network under the coordinator-maintained
/// running BN statistics. Like every native executable the batch shape
/// is static (`cfg.batch`); callers with fewer live rows pad and slice.
pub fn run_predict(
    cfg: &NativeModelCfg,
    param_names: &[String],
    geo: &[LayerGeo],
    inputs: &[&HostTensor],
    scratch: &mut Scratch,
) -> Result<Vec<HostTensor>> {
    let np = param_names.len();
    let bn_names: Vec<&str> =
        geo.iter().filter(|lg| lg.kind == "bn").map(|lg| lg.name.as_str()).collect();
    let nb = bn_names.len();
    anyhow::ensure!(
        inputs.len() == np + 1 + 2 * nb,
        "predict executable expects {} inputs (params, x, bn stats), got {}",
        np + 1 + 2 * nb,
        inputs.len()
    );
    let pdict: PDict =
        param_names.iter().map(String::as_str).zip(inputs[..np].iter().copied()).collect();
    let x = inputs[np];
    let (c, h, w) = cfg.in_shape;
    anyhow::ensure!(
        x.shape == [cfg.batch, c, h, w],
        "input shape {:?} != ({}, {c}, {h}, {w})",
        x.shape,
        cfg.batch
    );
    let bn_running: BTreeMap<&str, (&HostTensor, &HostTensor)> = bn_names
        .iter()
        .enumerate()
        .map(|(i, &n)| (n, (inputs[np + 1 + i], inputs[np + 1 + nb + i])))
        .collect();
    let fwd = forward(cfg, &pdict, x, Some(&bn_running), scratch)?;
    let (b, k) = (fwd.logits.rows, fwd.logits.cols);
    Ok(vec![HostTensor::new(vec![b, k], fwd.logits.data)])
}
