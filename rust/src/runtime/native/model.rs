//! Native model configurations — rust port of `python/compile/config.py`.
//!
//! A model is a flat op program; residual blocks are expressed with
//! Save/Add ops, and an Add may carry a projection (conv + bn) applied to
//! the saved tensor (ResNet downsample shortcuts). The same geometry
//! rules as the python L2 tracer apply, so the manifests the native
//! backend synthesizes are shape-identical to the AOT ones.

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ConvSpec {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvSpec {
    pub fn spatial_out(&self, h: usize, w: usize) -> (usize, usize) {
        super::kernels::conv_out_dims(h, w, self.k, self.stride, self.pad)
    }
}

#[derive(Clone, Debug)]
pub struct BnSpec {
    pub name: String,
    pub c: usize,
}

#[derive(Clone, Debug)]
pub struct FcSpec {
    pub name: String,
    pub din: usize,
    pub dout: usize,
}

#[derive(Clone, Debug)]
pub enum Op {
    Conv(ConvSpec),
    Bn(BnSpec),
    Relu,
    Save(String),
    Add { from_save: String, proj: Option<Box<(ConvSpec, BnSpec)>> },
    GlobalPool,
    Flatten,
    Fc(FcSpec),
}

#[derive(Clone, Debug)]
pub struct NativeModelCfg {
    pub name: String,
    /// (C, H, W)
    pub in_shape: (usize, usize, usize),
    pub num_classes: usize,
    /// per-worker batch
    pub batch: usize,
    pub ops: Vec<Op>,
}

/// Shape of the tensor flowing through the op program.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Flow {
    Chw(usize, usize, usize),
    Flat(usize),
}

/// Static per-K-FAC-layer geometry, in kfac order (op-program order, Add
/// projections in place).
#[derive(Clone, Debug)]
pub struct LayerGeo {
    pub name: String,
    pub kind: &'static str, // "conv" | "fc" | "bn"
    pub a_dim: usize,
    pub g_dim: usize,
    pub grad_shape: (usize, usize),
    pub a_tap_shape: Vec<usize>,
    pub g_tap_shape: Vec<usize>,
    /// conv only: (cin, h, w, k, stride, pad) at this layer's input
    pub conv_sig: Option<(usize, usize, usize, usize, usize, usize)>,
    /// conv only: ho * wo
    pub spatial: usize,
    /// bn only
    pub channels: usize,
}

impl NativeModelCfg {
    /// Parameter (name, shape) pairs in the canonical order the manifest,
    /// the step executable and the trainer all share.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::new();
        let push_conv = |out: &mut Vec<(String, Vec<usize>)>, c: &ConvSpec| {
            out.push((format!("{}.w", c.name), vec![c.cout, c.cin, c.k, c.k]));
        };
        let push_bn = |out: &mut Vec<(String, Vec<usize>)>, b: &BnSpec| {
            out.push((format!("{}.gamma", b.name), vec![b.c]));
            out.push((format!("{}.beta", b.name), vec![b.c]));
        };
        for op in &self.ops {
            match op {
                Op::Conv(c) => push_conv(&mut out, c),
                Op::Fc(f) => out.push((format!("{}.w", f.name), vec![f.dout, f.din])),
                Op::Bn(b) => push_bn(&mut out, b),
                Op::Add { proj: Some(p), .. } => {
                    push_conv(&mut out, &p.0);
                    push_bn(&mut out, &p.1);
                }
                _ => {}
            }
        }
        out
    }

    /// Trace the op program symbolically (shapes only) and return the
    /// K-FAC layer table. Panics on inconsistent configs — these are
    /// compiled-in, so a bad one is a programming error.
    pub fn layer_geometry(&self) -> Vec<LayerGeo> {
        let b = self.batch;
        let (c0, h0, w0) = self.in_shape;
        let mut flow = Flow::Chw(c0, h0, w0);
        let mut saved: Vec<(String, Flow)> = Vec::new();
        let mut geo = Vec::new();

        fn conv_geo(b: usize, cs: &ConvSpec, flow: Flow) -> (LayerGeo, Flow) {
            let Flow::Chw(cin, h, w) = flow else {
                panic!("{}: conv after flatten", cs.name)
            };
            assert_eq!(cin, cs.cin, "{}: cin mismatch", cs.name);
            let (ho, wo) = cs.spatial_out(h, w);
            let a_dim = cs.cin * cs.k * cs.k;
            let geo = LayerGeo {
                name: cs.name.clone(),
                kind: "conv",
                a_dim,
                g_dim: cs.cout,
                grad_shape: (cs.cout, a_dim),
                a_tap_shape: vec![b, cin, h, w],
                g_tap_shape: vec![b, cs.cout, ho, wo],
                conv_sig: Some((cin, h, w, cs.k, cs.stride, cs.pad)),
                spatial: ho * wo,
                channels: 0,
            };
            (geo, Flow::Chw(cs.cout, ho, wo))
        }

        fn bn_geo(b: usize, bs: &BnSpec, flow: Flow) -> LayerGeo {
            let Flow::Chw(c, _, _) = flow else {
                panic!("{}: bn after flatten", bs.name)
            };
            assert_eq!(c, bs.c, "{}: channel mismatch", bs.name);
            LayerGeo {
                name: bs.name.clone(),
                kind: "bn",
                a_dim: 0,
                g_dim: 0,
                grad_shape: (0, 0),
                a_tap_shape: Vec::new(),
                g_tap_shape: vec![b, bs.c],
                conv_sig: None,
                spatial: 0,
                channels: bs.c,
            }
        }

        for op in &self.ops {
            match op {
                Op::Save(name) => saved.push((name.clone(), flow)),
                Op::Conv(cs) => {
                    let (g, f) = conv_geo(b, cs, flow);
                    geo.push(g);
                    flow = f;
                }
                Op::Bn(bs) => geo.push(bn_geo(b, bs, flow)),
                Op::Relu => {}
                Op::Add { from_save, proj } => {
                    let sflow = saved
                        .iter()
                        .rev()
                        .find(|(n, _)| n == from_save)
                        .unwrap_or_else(|| panic!("add from unknown save '{from_save}'"))
                        .1;
                    match proj {
                        Some(p) => {
                            let (g, pf) = conv_geo(b, &p.0, sflow);
                            geo.push(g);
                            geo.push(bn_geo(b, &p.1, pf));
                            assert_eq!(pf, flow, "projection shape mismatch at {from_save}");
                        }
                        None => assert_eq!(sflow, flow, "identity add mismatch at {from_save}"),
                    }
                }
                Op::GlobalPool => {
                    let Flow::Chw(c, _, _) = flow else { panic!("gap after flatten") };
                    flow = Flow::Chw(c, 1, 1);
                }
                Op::Flatten => {
                    let Flow::Chw(c, h, w) = flow else { panic!("double flatten") };
                    flow = Flow::Flat(c * h * w);
                }
                Op::Fc(fs) => {
                    let Flow::Flat(d) = flow else { panic!("{}: fc before flatten", fs.name) };
                    assert_eq!(d, fs.din, "{}: din mismatch", fs.name);
                    geo.push(LayerGeo {
                        name: fs.name.clone(),
                        kind: "fc",
                        a_dim: fs.din,
                        g_dim: fs.dout,
                        grad_shape: (fs.dout, fs.din),
                        a_tap_shape: vec![b, fs.din],
                        g_tap_shape: vec![b, fs.dout],
                        conv_sig: None,
                        spatial: 0,
                        channels: 0,
                    });
                    flow = Flow::Flat(fs.dout);
                }
            }
        }
        assert_eq!(flow, Flow::Flat(self.num_classes), "program must end at the logits");
        geo
    }

    /// HeNormal initial parameters (BN gamma = 1, beta = 0), in param
    /// order. Deterministic for a given seed.
    pub fn init_params(&self, seed: u64) -> Vec<HostTensor> {
        let mut rng = Rng::new(seed ^ 0x1417_BEEF);
        self.param_shapes()
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                if name.ends_with(".gamma") {
                    HostTensor::new(shape, vec![1.0; n])
                } else if name.ends_with(".beta") {
                    HostTensor::zeros(shape)
                } else {
                    let fan_in: usize = shape[1..].iter().product();
                    let std = (2.0 / fan_in as f64).sqrt();
                    let data = (0..n).map(|_| (rng.normal() * std) as f32).collect();
                    HostTensor::new(shape, data)
                }
            })
            .collect()
    }
}

fn conv(name: &str, cin: usize, cout: usize, k: usize, stride: usize, pad: usize) -> ConvSpec {
    ConvSpec { name: name.to_string(), cin, cout, k, stride, pad }
}

/// ResNet basic block: conv-bn-relu-conv-bn + shortcut, relu.
fn basic_block(ops: &mut Vec<Op>, prefix: &str, cin: usize, cout: usize, stride: usize) {
    ops.push(Op::Save(format!("{prefix}.in")));
    ops.push(Op::Conv(conv(&format!("{prefix}.conv1"), cin, cout, 3, stride, 1)));
    ops.push(Op::Bn(BnSpec { name: format!("{prefix}.bn1"), c: cout }));
    ops.push(Op::Relu);
    ops.push(Op::Conv(conv(&format!("{prefix}.conv2"), cout, cout, 3, 1, 1)));
    ops.push(Op::Bn(BnSpec { name: format!("{prefix}.bn2"), c: cout }));
    let proj = if stride != 1 || cin != cout {
        Some(Box::new((
            conv(&format!("{prefix}.proj"), cin, cout, 1, stride, 0),
            BnSpec { name: format!("{prefix}.projbn"), c: cout },
        )))
    } else {
        None
    };
    ops.push(Op::Add { from_save: format!("{prefix}.in"), proj });
    ops.push(Op::Relu);
}

/// ResNet-style ConvNet: stem + stages of basic blocks + GAP + FC.
pub fn convnet(
    name: &str,
    width: usize,
    img: usize,
    blocks: &[usize],
    num_classes: usize,
    batch: usize,
) -> NativeModelCfg {
    let mut ops = vec![
        Op::Conv(conv("stem.conv", 3, width, 3, 1, 1)),
        Op::Bn(BnSpec { name: "stem.bn".to_string(), c: width }),
        Op::Relu,
    ];
    let mut cin = width;
    for (s, &nblocks) in blocks.iter().enumerate() {
        let cout = width << s;
        for b in 0..nblocks {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            basic_block(&mut ops, &format!("s{s}b{b}"), cin, cout, stride);
            cin = cout;
        }
    }
    ops.push(Op::GlobalPool);
    ops.push(Op::Flatten);
    ops.push(Op::Fc(FcSpec { name: "fc".to_string(), din: cin, dout: num_classes }));
    NativeModelCfg {
        name: name.to_string(),
        in_shape: (3, img, img),
        num_classes,
        batch,
        ops,
    }
}

/// The end-to-end example model (~60k params, 21 K-FAC layers).
pub fn convnet_small() -> NativeModelCfg {
    convnet("convnet_small", 16, 16, &[2, 2], 10, 32)
}

/// Fast config for tests.
pub fn convnet_tiny() -> NativeModelCfg {
    convnet("convnet_tiny", 8, 8, &[1, 1], 10, 8)
}

/// FC-only model for the quickstart (input flattened 3*img*img).
pub fn mlp() -> NativeModelCfg {
    let (img, dims) = (8usize, [192usize, 128, 64]);
    let mut ops = vec![Op::Flatten];
    let mut d = dims[0];
    for (i, &h) in dims[1..].iter().enumerate() {
        ops.push(Op::Fc(FcSpec { name: format!("fc{i}"), din: d, dout: h }));
        ops.push(Op::Relu);
        d = h;
    }
    ops.push(Op::Fc(FcSpec { name: "head".to_string(), din: d, dout: 10 }));
    NativeModelCfg {
        name: "mlp".to_string(),
        in_shape: (3, img, img),
        num_classes: 10,
        batch: 32,
        ops,
    }
}

/// Registered model names, in presentation order — the single source for
/// the CLI (`--model`), harness (`SPNGD_MODEL`), examples and benches.
pub const MODEL_NAMES: &[&str] = &["mlp", "convnet_small", "convnet_tiny"];

/// Look up a built-in model config by registry name. Unknown names are a
/// hard error listing the valid choices (mirroring `optim::by_name`).
pub fn by_name(name: &str) -> anyhow::Result<NativeModelCfg> {
    match name {
        "mlp" => Ok(mlp()),
        "convnet_small" => Ok(convnet_small()),
        "convnet_tiny" => Ok(convnet_tiny()),
        other => anyhow::bail!(
            "unknown model '{other}' (valid choices: {})",
            MODEL_NAMES.join(" | ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convnet_small_matches_aot_geometry() {
        let cfg = convnet_small();
        let geo = cfg.layer_geometry();
        // 21 K-FAC layers, as the AOT manifest records for this model
        assert_eq!(geo.len(), 21);
        assert_eq!(geo[0].name, "stem.conv");
        assert_eq!(geo[0].a_dim, 27);
        assert_eq!(geo[0].g_dim, 16);
        // s1b0 projection appears in place, right after s1b0.bn2
        let names: Vec<&str> = geo.iter().map(|g| g.name.as_str()).collect();
        let i = names.iter().position(|n| *n == "s1b0.proj").unwrap();
        assert_eq!(names[i - 1], "s1b0.bn2");
        assert_eq!(names[i + 1], "s1b0.projbn");
        // final fc takes the GAP output
        let fc = geo.last().unwrap();
        assert_eq!(fc.kind, "fc");
        assert_eq!(fc.a_dim, 32);
        assert_eq!(fc.g_dim, 10);
    }

    #[test]
    fn mlp_geometry_and_params() {
        let cfg = mlp();
        let geo = cfg.layer_geometry();
        assert_eq!(geo.len(), 3);
        assert_eq!(geo[0].a_dim, 192);
        assert_eq!(geo[2].g_dim, 10);
        let shapes = cfg.param_shapes();
        assert_eq!(shapes.len(), 3);
        assert_eq!(shapes[0].1, vec![128, 192]);
        let total: usize = shapes.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(total, 128 * 192 + 64 * 128 + 10 * 64);
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        let cfg = convnet_tiny();
        let p1 = cfg.init_params(0);
        let p2 = cfg.init_params(0);
        let p3 = cfg.init_params(1);
        assert_eq!(p1.len(), cfg.param_shapes().len());
        assert_eq!(p1[0].data, p2[0].data);
        assert_ne!(p1[0].data, p3[0].data);
        // stem conv: fan_in = 27, HeNormal std ~ sqrt(2/27)
        let std = (p1[0].data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            / p1[0].data.len() as f64)
            .sqrt();
        let want = (2.0f64 / 27.0).sqrt();
        assert!((std - want).abs() < want * 0.5, "std={std} want~{want}");
        // gammas are ones, betas zeros
        let shapes = cfg.param_shapes();
        let gi = shapes.iter().position(|(n, _)| n.ends_with(".gamma")).unwrap();
        assert!(p1[gi].data.iter().all(|&v| v == 1.0));
        assert!(p1[gi + 1].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn registry_resolves_every_name_and_rejects_unknown() {
        for name in MODEL_NAMES {
            let cfg = by_name(name).unwrap();
            assert_eq!(&cfg.name, name);
        }
        let err = by_name("resnet50").unwrap_err().to_string();
        assert!(err.contains("unknown model 'resnet50'"), "{err}");
        for name in MODEL_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn tiny_has_projection_block() {
        let cfg = convnet_tiny();
        let geo = cfg.layer_geometry();
        assert!(geo.iter().any(|g| g.name == "s1b0.proj"));
        // stride-2 stage halves the spatial dims: s1 convs see 4x4
        let c = geo.iter().find(|g| g.name == "s1b0.conv2").unwrap();
        assert_eq!(c.g_tap_shape, vec![cfg.batch, 16, 4, 4]);
    }
}
