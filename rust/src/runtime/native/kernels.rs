//! Native CPU kernels — the rust counterparts of the L1 Pallas kernels,
//! numerically matched to the oracles in `python/compile/kernels/ref.py`
//! (see `tests/native_golden.rs` for golden-value checks).
//!
//! The hot kernels are parallel: im2col/col2im fan out over the batch
//! axis, SYRK over row bands with per-thread f64 accumulators, and the
//! Newton-Schulz products run on the blocked pool matmul with ping-pong
//! scratch buffers. Each keeps its single-threaded predecessor as a
//! `*_ref` oracle for differential tests and the naive bench baseline
//! (`linalg::set_reference_kernels` routes the default entry points back
//! to them).
//!
//! Inner loops are vectorized through [`crate::util::simd`] and the
//! im2col/col2im bodies move whole valid kw-spans with `copy_from_slice`
//! / zipped adds; wide SYRK factors additionally tile the j axis over a
//! packed panel ([`crate::linalg::packed::pack_panel`]). Every fast path
//! keeps the per-element accumulation order of its `*_ref` oracle, so
//! the differential suite pins them bit-for-bit.

use crate::linalg::{self, packed, Mat, Scratch};
use crate::runtime::HostTensor;
use crate::util::pool::{self, Pool};
use crate::util::simd;

/// SYRK row-band work (rows · cols²) below which parallel dispatch costs
/// more than it saves.
const SYRK_PAR_CUTOFF: usize = 1 << 15;

/// Minimum SYRK rows per band: each band re-walks the full c×c
/// accumulator, so bands must amortize that traffic.
const SYRK_MIN_BAND: usize = 16;

// ------------------------------------------------------------- im2col

/// Conv output spatial dims for an (h, w) input with a square k-kernel —
/// the single home of the `(d + 2·pad − k)/stride + 1` formula.
pub fn conv_out_dims(h: usize, w: usize, k: usize, stride: usize, pad: usize) -> (usize, usize) {
    ((h + 2 * pad - k) / stride + 1, (w + 2 * pad - k) / stride + 1)
}

/// Conv-patch extraction: (B, C, H, W) -> (B*ho*wo, C*k*k) with row index
/// (b, oy, ox) and column index c*k*k + kh*k + kw — the exact layout of
/// `lax.conv_general_dilated_patches` the AOT factor executables consume.
/// Parallel over the batch axis on the global pool.
pub fn im2col(x: &HostTensor, k: usize, stride: usize, pad: usize) -> (Mat, usize, usize) {
    im2col_with(pool::global(), x, k, stride, pad)
}

/// [`im2col`] on an explicit pool.
pub fn im2col_with(
    pool: &Pool,
    x: &HostTensor,
    k: usize,
    stride: usize,
    pad: usize,
) -> (Mat, usize, usize) {
    let mut out = Mat::zeros(0, 0);
    let (ho, wo) = im2col_into_with(pool, x, k, stride, pad, &mut out);
    (out, ho, wo)
}

/// [`im2col`] into a caller-provided (scratch) matrix; returns (ho, wo).
pub fn im2col_into_with(
    pool: &Pool,
    x: &HostTensor,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Mat,
) -> (usize, usize) {
    assert_eq!(x.rank(), 4, "im2col expects NCHW");
    let (b, h, w) = (x.shape[0], x.shape[2], x.shape[3]);
    let c = x.shape[1];
    let (ho, wo) = conv_out_dims(h, w, k, stride, pad);
    let ckk = c * k * k;
    out.reset(b * ho * wo, ckk);
    let per_image = ho * wo * ckk;
    if linalg::reference_kernels() {
        for (bi, chunk) in out.data.chunks_mut(per_image.max(1)).enumerate() {
            im2col_image_ref(x, bi, k, stride, pad, ho, wo, chunk);
        }
    } else if b <= 1 || pool.size() <= 1 {
        for (bi, chunk) in out.data.chunks_mut(per_image.max(1)).enumerate() {
            im2col_image(x, bi, k, stride, pad, ho, wo, chunk);
        }
    } else {
        pool.parallel_for_mut(&mut out.data, per_image, |bi, chunk| {
            im2col_image(x, bi, k, stride, pad, ho, wo, chunk);
        });
    }
    (ho, wo)
}

/// Single-threaded [`im2col`] — differential-test oracle / naive baseline.
pub fn im2col_ref(x: &HostTensor, k: usize, stride: usize, pad: usize) -> (Mat, usize, usize) {
    assert_eq!(x.rank(), 4, "im2col expects NCHW");
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (ho, wo) = conv_out_dims(h, w, k, stride, pad);
    let ckk = c * k * k;
    let mut out = Mat::zeros(b * ho * wo, ckk);
    let per_image = ho * wo * ckk;
    for (bi, chunk) in out.data.chunks_mut(per_image.max(1)).enumerate() {
        im2col_image_ref(x, bi, k, stride, pad, ho, wo, chunk);
    }
    (out, ho, wo)
}

/// Fill the patch rows of one image: `chunk` is the (ho*wo, C*k*k) block
/// of rows belonging to batch element `bi`, already zeroed. Each valid
/// kw-span is one contiguous `copy_from_slice` (a pure copy — identical
/// bits to the per-element reference body).
fn im2col_image(
    x: &HostTensor,
    bi: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    chunk: &mut [f32],
) {
    let (c, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * ckk;
            let x0 = ox * stride;
            let kw_lo = pad.saturating_sub(x0);
            let kw_hi = k.min((w + pad).saturating_sub(x0));
            if kw_lo >= kw_hi {
                continue;
            }
            let len = kw_hi - kw_lo;
            let src_x = x0 + kw_lo - pad;
            for ci in 0..c {
                for kh in 0..k {
                    let y = (oy * stride + kh) as isize - pad as isize;
                    if y < 0 || y >= h as isize {
                        continue;
                    }
                    let src = ((bi * c + ci) * h + y as usize) * w + src_x;
                    let dst = base + (ci * k + kh) * k + kw_lo;
                    chunk[dst..dst + len].copy_from_slice(&x.data[src..src + len]);
                }
            }
        }
    }
}

/// The pre-optimization per-element body of [`im2col_image`] — the naive
/// baseline and differential oracle (bounds handled element-wise).
fn im2col_image_ref(
    x: &HostTensor,
    bi: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    chunk: &mut [f32],
) {
    let (c, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let base = (oy * wo + ox) * ckk;
            for ci in 0..c {
                for kh in 0..k {
                    let y = (oy * stride + kh) as isize - pad as isize;
                    if y < 0 || y >= h as isize {
                        continue;
                    }
                    let src = ((bi * c + ci) * h + y as usize) * w;
                    for kw in 0..k {
                        let xx = (ox * stride + kw) as isize - pad as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        chunk[base + (ci * k + kh) * k + kw] = x.data[src + xx as usize];
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------------- col2im

/// Scatter-add inverse of [`im2col`]: fold patch gradients back onto the
/// input image (the conv backward data path). Parallel over the batch
/// axis on the global pool.
pub fn col2im(
    dpatches: &Mat,
    xshape: &[usize; 4],
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> HostTensor {
    col2im_with(pool::global(), dpatches, xshape, k, stride, pad, ho, wo)
}

/// [`col2im`] on an explicit pool.
pub fn col2im_with(
    pool: &Pool,
    dpatches: &Mat,
    xshape: &[usize; 4],
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> HostTensor {
    let [b, c, h, w] = *xshape;
    let mut dx = HostTensor::zeros(vec![b, c, h, w]);
    col2im_into_with(pool, dpatches, xshape, k, stride, pad, ho, wo, &mut dx);
    dx
}

/// [`col2im`] into a caller-provided (scratch) tensor of shape `xshape`;
/// `dx` is zeroed before the scatter.
pub fn col2im_into_with(
    pool: &Pool,
    dpatches: &Mat,
    xshape: &[usize; 4],
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    dx: &mut HostTensor,
) {
    let [b, c, h, w] = *xshape;
    let ckk = c * k * k;
    assert_eq!(dpatches.rows, b * ho * wo);
    assert_eq!(dpatches.cols, ckk);
    assert_eq!(dx.shape, xshape, "col2im output shape mismatch");
    dx.data.fill(0.0);
    let per_image = c * h * w;
    if linalg::reference_kernels() {
        for (bi, img) in dx.data.chunks_mut(per_image.max(1)).enumerate() {
            col2im_image_ref(dpatches, bi, c, h, w, k, stride, pad, ho, wo, img);
        }
    } else if b <= 1 || pool.size() <= 1 {
        for (bi, img) in dx.data.chunks_mut(per_image.max(1)).enumerate() {
            col2im_image(dpatches, bi, c, h, w, k, stride, pad, ho, wo, img);
        }
    } else {
        pool.parallel_for_mut(&mut dx.data, per_image, |bi, img| {
            col2im_image(dpatches, bi, c, h, w, k, stride, pad, ho, wo, img);
        });
    }
}

/// Single-threaded [`col2im`] — differential-test oracle / naive baseline.
pub fn col2im_ref(
    dpatches: &Mat,
    xshape: &[usize; 4],
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> HostTensor {
    let [b, c, h, w] = *xshape;
    let ckk = c * k * k;
    assert_eq!(dpatches.rows, b * ho * wo);
    assert_eq!(dpatches.cols, ckk);
    let mut dx = HostTensor::zeros(vec![b, c, h, w]);
    let per_image = c * h * w;
    for (bi, img) in dx.data.chunks_mut(per_image.max(1)).enumerate() {
        col2im_image_ref(dpatches, bi, c, h, w, k, stride, pad, ho, wo, img);
    }
    dx
}

/// Fold the patch-gradient rows of one image: `img` is the (C, H, W)
/// block of batch element `bi`, already zeroed. Each valid kw-span is
/// one zipped add over contiguous slices; the per-element accumulation
/// order matches the reference body exactly.
fn col2im_image(
    dpatches: &Mat,
    bi: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    img: &mut [f32],
) {
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let base = ((bi * ho + oy) * wo + ox) * ckk;
            let x0 = ox * stride;
            let kw_lo = pad.saturating_sub(x0);
            let kw_hi = k.min((w + pad).saturating_sub(x0));
            if kw_lo >= kw_hi {
                continue;
            }
            let len = kw_hi - kw_lo;
            let dst_x = x0 + kw_lo - pad;
            for ci in 0..c {
                for kh in 0..k {
                    let y = (oy * stride + kh) as isize - pad as isize;
                    if y < 0 || y >= h as isize {
                        continue;
                    }
                    let dst = (ci * h + y as usize) * w + dst_x;
                    let src = base + (ci * k + kh) * k + kw_lo;
                    let span = &dpatches.data[src..src + len];
                    for (o, v) in img[dst..dst + len].iter_mut().zip(span) {
                        *o += *v;
                    }
                }
            }
        }
    }
}

/// The pre-optimization per-element body of [`col2im_image`] — the naive
/// baseline and differential oracle.
fn col2im_image_ref(
    dpatches: &Mat,
    bi: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    img: &mut [f32],
) {
    let ckk = c * k * k;
    for oy in 0..ho {
        for ox in 0..wo {
            let base = ((bi * ho + oy) * wo + ox) * ckk;
            for ci in 0..c {
                for kh in 0..k {
                    let y = (oy * stride + kh) as isize - pad as isize;
                    if y < 0 || y >= h as isize {
                        continue;
                    }
                    let dst = (ci * h + y as usize) * w;
                    for kw in 0..k {
                        let xx = (ox * stride + kw) as isize - pad as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        img[dst + xx as usize] += dpatches.data[base + (ci * k + kh) * k + kw];
                    }
                }
            }
        }
    }
}

// --------------------------------------------------------------- syrk

/// SYRK: scale * XᵀX for X (rows, cols) -> (cols, cols) symmetric — the
/// Kronecker-factor construction primitive (f64 accumulation over the
/// long row axis). Row-band-parallel on the global pool: each band
/// accumulates a private f64 upper triangle, reduced in band order so
/// results are deterministic for a fixed thread count.
pub fn syrk(x: &Mat, scale: f32) -> Mat {
    syrk_with(pool::global(), x, scale)
}

/// [`syrk`] on an explicit pool.
pub fn syrk_with(pool: &Pool, x: &Mat, scale: f32) -> Mat {
    syrk_slice_with(pool, &x.data, x.rows, x.cols, scale)
}

/// [`syrk`] over a raw row-major (rows, cols) slice — lets the backend
/// feed tap tensors without copying them into a `Mat` first.
pub fn syrk_slice_with(pool: &Pool, x: &[f32], rows: usize, cols: usize, scale: f32) -> Mat {
    assert_eq!(x.len(), rows * cols, "syrk shape mismatch");
    if linalg::reference_kernels() {
        return syrk_slice_ref(x, rows, cols, scale);
    }
    let (r, c) = (rows, cols);
    let nbands = pool.size().min(r.div_ceil(SYRK_MIN_BAND)).max(1);
    if nbands <= 1 || r * c * c < SYRK_PAR_CUTOFF {
        let mut acc = vec![0.0f64; c * c];
        syrk_band(x, 0, r, c, &mut acc);
        return syrk_finish(&acc, c, scale);
    }
    let band = r.div_ceil(nbands);
    let mut partials: Vec<Vec<f64>> = (0..nbands).map(|_| vec![0.0f64; c * c]).collect();
    pool.parallel_for_mut(&mut partials, 1, |bi, slot| {
        let t0 = bi * band;
        let t1 = (t0 + band).min(r);
        syrk_band(x, t0, t1, c, &mut slot[0]);
    });
    // reduce in band order (deterministic for a fixed band count)
    let (head, rest) = partials.split_first_mut().expect("at least one band");
    for p in rest {
        for (a, v) in head.iter_mut().zip(p.iter()) {
            *a += *v;
        }
    }
    syrk_finish(head, c, scale)
}

/// Single-threaded [`syrk`] (the pre-refactor column-pair loop) —
/// differential-test oracle / naive baseline.
pub fn syrk_ref(x: &Mat, scale: f32) -> Mat {
    syrk_slice_ref(&x.data, x.rows, x.cols, scale)
}

fn syrk_slice_ref(x: &[f32], rows: usize, cols: usize, scale: f32) -> Mat {
    let (r, c) = (rows, cols);
    let mut out = Mat::zeros(c, c);
    for i in 0..c {
        for j in i..c {
            let mut acc = 0.0f64;
            for t in 0..r {
                acc += x[t * c + i] as f64 * x[t * c + j] as f64;
            }
            let v = (acc * scale as f64) as f32;
            out.data[i * c + j] = v;
            out.data[j * c + i] = v;
        }
    }
    out
}

/// Factor width at which the SYRK band switches to the packed j-tiled
/// walk (below it the whole x row is L1-resident and direct is faster).
const SYRK_PACK_MIN_C: usize = 160;

/// j-tile width of the packed SYRK walk: one panel row (≤ 192 f32) plus
/// the active accumulator span stay cache-resident.
const SYRK_JT: usize = 192;

/// Rows packed per panel in the tiled SYRK walk.
const SYRK_TT: usize = 64;

/// Accumulate the upper triangle of XᵀX over rows [t0, t1) into `acc`
/// (c×c, row-major, only i ≤ j written) — the per-band body. Narrow
/// factors use a direct row-wise walk (one x row register/L1-resident
/// per outer-product update); wide factors tile the j axis over a packed
/// panel. Both walks feed [`simd::axpy_widen`] and add t-ascending per
/// element, so every path is bit-identical to the naive reference.
fn syrk_band(x: &[f32], t0: usize, t1: usize, c: usize, acc: &mut [f64]) {
    if c < SYRK_PACK_MIN_C {
        for t in t0..t1 {
            let xrow = &x[t * c..(t + 1) * c];
            for i in 0..c {
                let xi = xrow[i] as f64;
                simd::axpy_widen(xi, &xrow[i..], &mut acc[i * c + i..(i + 1) * c]);
            }
        }
        return;
    }
    let mut panel = Vec::new();
    let mut j0 = 0;
    while j0 < c {
        let j1 = (j0 + SYRK_JT).min(c);
        let jw = j1 - j0;
        let mut tb0 = t0;
        while tb0 < t1 {
            let tb1 = (tb0 + SYRK_TT).min(t1);
            packed::pack_panel(x, c, tb0, tb1, j0, j1, &mut panel);
            for (ti, t) in (tb0..tb1).enumerate() {
                let xrow = &x[t * c..(t + 1) * c];
                let prow = &panel[ti * jw..(ti + 1) * jw];
                for i in 0..j1 {
                    let xi = xrow[i] as f64;
                    if i < j0 {
                        simd::axpy_widen(xi, prow, &mut acc[i * c + j0..i * c + j1]);
                    } else {
                        simd::axpy_widen(xi, &prow[i - j0..], &mut acc[i * c + i..i * c + j1]);
                    }
                }
            }
            tb0 = tb1;
        }
        j0 = j1;
    }
}

/// Scale the accumulated upper triangle and mirror it into a full matrix.
fn syrk_finish(acc: &[f64], c: usize, scale: f32) -> Mat {
    let mut out = Mat::zeros(c, c);
    let s = scale as f64;
    for i in 0..c {
        for j in i..c {
            let v = (acc[i * c + j] * s) as f32;
            out.data[i * c + j] = v;
            out.data[j * c + i] = v;
        }
    }
    out
}

// ------------------------------------------------------ Newton-Schulz

fn matvec(m: &Mat, v: &[f32]) -> Vec<f32> {
    let n = m.rows;
    let mut out = vec![0.0f32; n];
    for i in 0..n {
        let row = &m.data[i * m.cols..(i + 1) * m.cols];
        let mut acc = 0.0f64;
        for j in 0..v.len() {
            acc += row[j] as f64 * v[j] as f64;
        }
        out[i] = acc as f32;
    }
    out
}

fn l2norm(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

/// Damped SPD inverse (M + damping·I)⁻¹ via Newton-Schulz, matching the
/// AOT `invert_<n>` executables exactly: 8 power iterations from
/// v₀ = 1/√n, σ = 1.1·‖M_d v‖ + damping, X₀ = I/σ, then `iters` steps of
/// X ← X(2I − M_d X). Zero-padded buckets stay exact: damping makes the
/// pad block λI, which inverts independently of the top-left block.
pub fn ns_inverse(m: &Mat, damping: f32, iters: usize) -> Mat {
    let mut scratch = Scratch::new();
    ns_inverse_with(pool::global(), &mut scratch, &m.data, m.rows, damping, iters)
}

/// [`ns_inverse`] over a raw row-major n×n slice, on an explicit pool
/// with scratch-buffer reuse: the two products per iteration run on the
/// blocked pool matmul and ping-pong between recycled buffers.
pub fn ns_inverse_with(
    pool: &Pool,
    scratch: &mut Scratch,
    m: &[f32],
    n: usize,
    damping: f32,
    iters: usize,
) -> Mat {
    assert_eq!(m.len(), n * n, "ns_inverse expects a square matrix");
    if linalg::reference_kernels() {
        return ns_inverse_ref(&Mat::from_vec(n, n, m.to_vec()), damping, iters);
    }
    let mut md = scratch.mat_from(n, n, m);
    md.add_diag(damping);
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    for _ in 0..8 {
        let w = matvec(&md, &v);
        let norm = l2norm(&w).max(1e-30);
        for (vi, wi) in v.iter_mut().zip(w.iter()) {
            *vi = wi / norm;
        }
    }
    let sigma = l2norm(&matvec(&md, &v)).max(1e-30) * 1.1 + damping;
    let mut x = scratch.mat(n, n);
    for i in 0..n {
        x.data[i * n + i] = 1.0 / sigma;
    }
    let mut t = scratch.mat_spare(n, n);
    let mut x2 = scratch.mat_spare(n, n);
    for _ in 0..iters {
        md.matmul_into_with(pool, &x, &mut t);
        for tv in t.data.iter_mut() {
            *tv = -*tv;
        }
        t.add_diag(2.0); // t = 2I − M_d X
        x.matmul_into_with(pool, &t, &mut x2);
        std::mem::swap(&mut x, &mut x2);
    }
    scratch.recycle_mat(md);
    scratch.recycle_mat(t);
    scratch.recycle_mat(x2);
    x
}

/// Single-threaded [`ns_inverse`] (the pre-refactor allocate-per-step
/// loop over `matmul_ref`) — differential-test oracle / naive baseline.
pub fn ns_inverse_ref(m: &Mat, damping: f32, iters: usize) -> Mat {
    assert!(m.is_square());
    let n = m.rows;
    let mut md = m.clone();
    md.add_diag(damping);
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    for _ in 0..8 {
        let w = matvec(&md, &v);
        let norm = l2norm(&w).max(1e-30);
        for (vi, wi) in v.iter_mut().zip(w.iter()) {
            *vi = wi / norm;
        }
    }
    let sigma = l2norm(&matvec(&md, &v)).max(1e-30) * 1.1 + damping;
    let mut x = Mat::eye(n).scale(1.0 / sigma);
    let two_i = Mat::eye(n).scale(2.0);
    for _ in 0..iters {
        let p = md.matmul_ref(&x);
        x = x.matmul_ref(&two_i.axpy(-1.0, &p));
    }
    x
}

// ------------------------------------------------------ precondition

/// K-FAC preconditioned gradient: G⁻¹ · grad · A⁻¹.
pub fn precondition(g_inv: &Mat, grad: &Mat, a_inv: &Mat) -> Mat {
    g_inv.matmul(grad).matmul(a_inv)
}

/// [`precondition`] on an explicit pool with scratch-buffer reuse.
pub fn precondition_with(
    pool: &Pool,
    scratch: &mut Scratch,
    g_inv: &Mat,
    grad: &Mat,
    a_inv: &Mat,
) -> Mat {
    let mut t = scratch.mat_spare(g_inv.rows, grad.cols);
    g_inv.matmul_into_with(pool, grad, &mut t);
    let mut out = scratch.mat_spare(t.rows, a_inv.cols);
    t.matmul_into_with(pool, a_inv, &mut out);
    scratch.recycle_mat(t);
    out
}

// ------------------------------------------------------------------ bn

/// Full (2C × 2C) BatchNorm Fisher from per-sample (B, C) gamma/beta
/// gradients, parameter order (γ₁, β₁, …, γ_C, β_C).
pub fn bn_full_fisher(g_gamma: &HostTensor, g_beta: &HostTensor) -> HostTensor {
    let (b, c) = (g_gamma.shape[0], g_gamma.shape[1]);
    assert_eq!(g_beta.shape, g_gamma.shape);
    let n = 2 * c;
    let mut f = vec![0.0f32; n * n];
    let mut v = vec![0.0f32; n];
    for bi in 0..b {
        for ci in 0..c {
            v[2 * ci] = g_gamma.data[bi * c + ci];
            v[2 * ci + 1] = g_beta.data[bi * c + ci];
        }
        for i in 0..n {
            if v[i] == 0.0 {
                continue;
            }
            for j in 0..n {
                f[i * n + j] += v[i] * v[j];
            }
        }
    }
    let inv_b = 1.0 / b as f32;
    for x in f.iter_mut() {
        *x *= inv_b;
    }
    HostTensor::new(vec![n, n], f)
}

/// Damped closed-form inverse of the unit-wise BN Fisher: (B, C) gamma
/// and beta gradients -> (C, 2, 2) inverse blocks of (F_c + damping·I).
pub fn bn_unit_fisher_inv(g_gamma: &HostTensor, g_beta: &HostTensor, damping: f32) -> HostTensor {
    let (b, c) = (g_gamma.shape[0], g_gamma.shape[1]);
    assert_eq!(g_beta.shape, g_gamma.shape);
    let mut out = vec![0.0f32; c * 4];
    let inv_b = 1.0 / b as f32;
    for ci in 0..c {
        let (mut f11, mut f12, mut f22) = (0.0f64, 0.0f64, 0.0f64);
        for bi in 0..b {
            let gg = g_gamma.data[bi * c + ci] as f64;
            let gb = g_beta.data[bi * c + ci] as f64;
            f11 += gg * gg;
            f12 += gg * gb;
            f22 += gb * gb;
        }
        let a = f11 * inv_b as f64 + damping as f64;
        let off = f12 * inv_b as f64;
        let d = f22 * inv_b as f64 + damping as f64;
        let det = a * d - off * off;
        out[ci * 4] = (d / det) as f32;
        out[ci * 4 + 1] = (-off / det) as f32;
        out[ci * 4 + 2] = (-off / det) as f32;
        out[ci * 4 + 3] = (a / det) as f32;
    }
    HostTensor::new(vec![c, 2, 2], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve;
    use crate::util::rng::Rng;

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y (adjointness is
        // exactly what conv backward needs)
        let mut rng = Rng::new(3);
        let x = HostTensor::new(vec![2, 3, 5, 5], (0..150).map(|_| rng.f32()).collect());
        let (px, ho, wo) = im2col(&x, 3, 2, 1);
        let y = Mat::from_vec(px.rows, px.cols, (0..px.data.len()).map(|_| rng.f32()).collect());
        let lhs: f64 =
            px.data.iter().zip(y.data.iter()).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let folded = col2im(&y, &[2, 3, 5, 5], 3, 2, 1, ho, wo);
        let rhs: f64 =
            x.data.iter().zip(folded.data.iter()).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn syrk_is_symmetric_gram() {
        let x = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, -1.0, 0.5, 4.0]);
        let s = syrk(&x, 0.5);
        let want = x.transpose().matmul(&x).scale(0.5);
        assert!(s.max_abs_diff(&want) < 1e-5);
        assert_eq!(s.at(0, 1), s.at(1, 0));
    }

    #[test]
    fn ns_inverse_matches_gauss_jordan() {
        let mut rng = Rng::new(11);
        let n = 24;
        let raw: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let b = Mat::from_vec(n, n, raw);
        let mut m = b.transpose().matmul(&b).scale(1.0 / n as f32);
        m.symmetrize();
        let lambda = 0.05;
        let inv = ns_inverse(&m, lambda, 20);
        let mut md = m.clone();
        md.add_diag(lambda);
        let gj = solve::gauss_jordan_inverse(&md).unwrap();
        assert!(inv.max_abs_diff(&gj) < 5e-3, "diff {}", inv.max_abs_diff(&gj));
    }

    #[test]
    fn ns_inverse_matches_its_ref_oracle() {
        let mut rng = Rng::new(29);
        let n = 48;
        let raw: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
        let b = Mat::from_vec(n, n, raw);
        let mut m = b.transpose().matmul(&b).scale(1.0 / n as f32);
        m.symmetrize();
        let fast = ns_inverse(&m, 0.05, 20);
        let naive = ns_inverse_ref(&m, 0.05, 20);
        assert!(fast.max_abs_diff(&naive) < 1e-5, "diff {}", fast.max_abs_diff(&naive));
    }

    #[test]
    fn ns_inverse_padded_bucket_slices_exactly() {
        // pad a 5x5 SPD into a 16-bucket; the sliced-back inverse must
        // match the unpadded inverse (block-diagonal argument)
        let mut rng = Rng::new(13);
        let raw: Vec<f32> = (0..25).map(|_| rng.normal() as f32).collect();
        let b = Mat::from_vec(5, 5, raw);
        let mut m = b.transpose().matmul(&b).scale(0.2);
        m.symmetrize();
        let t = HostTensor::from_mat(&m).pad_square(16);
        let inv_padded = ns_inverse(&t.as_mat(), 0.1, 20);
        let sliced = HostTensor::from_mat(&inv_padded).slice_square(5);
        let inv_direct = ns_inverse(&m, 0.1, 20);
        assert!(sliced.as_mat().max_abs_diff(&inv_direct) < 1e-4);
    }

    #[test]
    fn bn_unit_inv_inverts_damped_fisher() {
        let mut rng = Rng::new(17);
        let (b, c) = (16, 3);
        let gg = HostTensor::new(vec![b, c], (0..b * c).map(|_| rng.normal() as f32).collect());
        let gb = HostTensor::new(vec![b, c], (0..b * c).map(|_| rng.normal() as f32).collect());
        let lam = 0.05f32;
        let inv = bn_unit_fisher_inv(&gg, &gb, lam);
        assert_eq!(inv.shape, vec![c, 2, 2]);
        for ci in 0..c {
            let (mut f11, mut f12, mut f22) = (0.0f64, 0.0f64, 0.0f64);
            for bi in 0..b {
                let g1 = gg.data[bi * c + ci] as f64;
                let g2 = gb.data[bi * c + ci] as f64;
                f11 += g1 * g1;
                f12 += g1 * g2;
                f22 += g2 * g2;
            }
            let (f11, f12, f22) = (
                f11 / b as f64 + lam as f64,
                f12 / b as f64,
                f22 / b as f64 + lam as f64,
            );
            let blk = &inv.data[ci * 4..ci * 4 + 4];
            let i00 = f11 * blk[0] as f64 + f12 * blk[2] as f64;
            let i01 = f11 * blk[1] as f64 + f12 * blk[3] as f64;
            let i11 = f12 * blk[1] as f64 + f22 * blk[3] as f64;
            assert!((i00 - 1.0).abs() < 1e-4);
            assert!(i01.abs() < 1e-4);
            assert!((i11 - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn bn_full_diagonal_matches_unit_blocks() {
        let mut rng = Rng::new(19);
        let (b, c) = (8, 3);
        let gg = HostTensor::new(vec![b, c], (0..b * c).map(|_| rng.normal() as f32).collect());
        let gb = HostTensor::new(vec![b, c], (0..b * c).map(|_| rng.normal() as f32).collect());
        let full = bn_full_fisher(&gg, &gb);
        assert_eq!(full.shape, vec![2 * c, 2 * c]);
        let f = crate::kfac::bn::BnFisher::from_taps(&gg.data, &gb.data, b, c);
        let n = 2 * c;
        for ci in 0..c {
            assert!((full.data[(2 * ci) * n + 2 * ci] - f.blocks[ci][0]).abs() < 1e-5);
            assert!((full.data[(2 * ci) * n + 2 * ci + 1] - f.blocks[ci][1]).abs() < 1e-5);
            assert!((full.data[(2 * ci + 1) * n + 2 * ci + 1] - f.blocks[ci][2]).abs() < 1e-5);
        }
    }
}
