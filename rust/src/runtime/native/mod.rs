//! Native CPU backend: the default, hermetic [`Executor`].
//!
//! Mirrors the AOT pipeline (`python/compile/aot.py`) in-process: for each
//! model it synthesizes the same manifest (same executable names, layer
//! table, output layout, padded inversion buckets) and registers a native
//! implementation per executable — full fwd/bwd step with K-FAC taps,
//! im2col+SYRK factor construction, damped Newton-Schulz inversion, and
//! preconditioning. `cargo build` with default features is all it needs:
//! no artifacts, no XLA toolchain, no network.

pub mod kernels;
pub mod model;
mod net;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use self::model::{LayerGeo, NativeModelCfg};
use super::manifest::{KfacLayer, Manifest, ModelManifest, OutputSpec, ParamSpec};
use super::{Executor, HostTensor};
use crate::linalg::Scratch;
use crate::util::obs::{self, Cat};
use crate::util::pool;

/// Newton-Schulz iteration count — matches `NS_ITERS` in the AOT
/// pipeline, where 20 iterations reach f32 tolerance at the damping
/// levels the coordinator uses.
const NS_ITERS: usize = 20;

/// Inversion executables are shared across factor dims by padding to a
/// multiple of 16 (block-diagonal padding is exact; the trainer slices
/// the top-left block back out).
fn bucket(n: usize) -> usize {
    n.div_ceil(16) * 16
}

/// How to execute a manifest name natively. Every name fully determines
/// its spec, so cross-model sharing (e.g. `invert_64`) is safe.
#[derive(Clone, Debug)]
enum ExecSpec {
    Step {
        model: String,
        one_mc: bool,
    },
    Eval {
        model: String,
    },
    Predict {
        model: String,
    },
    FactorConvA {
        cin: usize,
        h: usize,
        w: usize,
        k: usize,
        stride: usize,
        pad: usize,
        batch: usize,
    },
    FactorSyrk {
        rows: usize,
        cols: usize,
        scale_rows: usize,
    },
    BnInv,
    BnFull,
    Invert {
        n: usize,
    },
    Precond {
        m: usize,
        n: usize,
    },
}

#[derive(Clone)]
struct NativeModel {
    cfg: NativeModelCfg,
    param_names: Vec<String>,
    geo: Vec<LayerGeo>,
}

/// The native backend: model table + executable registry + counters,
/// plus the scratch-buffer arena the per-step hot loop recycles matmul
/// and patch buffers through (interior-mutable: `execute` takes `&self`;
/// the mutex is uncontended in the intended one-thread-per-backend use —
/// the `dist` engine forks one backend per worker via [`Executor::fork_worker`]).
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
    execs: BTreeMap<String, ExecSpec>,
    ns_iters: usize,
    executions: AtomicU64,
    exec_nanos: AtomicU64,
    scratch: Mutex<Scratch>,
}

/// Build manifests + backend for every registered model
/// ([`model::MODEL_NAMES`]).
pub fn build_default() -> Result<(Manifest, NativeBackend)> {
    build(model::MODEL_NAMES, 0)
}

/// Build an in-memory [`Manifest`] (same contract as the AOT
/// `manifest.json`) and the backend executing it, for the named models.
/// `seed` controls the HeNormal parameter initialization.
pub fn build(model_names: &[&str], seed: u64) -> Result<(Manifest, NativeBackend)> {
    let mut execs: BTreeMap<String, ExecSpec> = BTreeMap::new();
    let mut models = BTreeMap::new();
    let mut manifests = BTreeMap::new();
    let mut init_params = BTreeMap::new();

    for &mname in model_names {
        let cfg = model::by_name(mname)?;
        let geo = cfg.layer_geometry();
        let pshapes = cfg.param_shapes();
        let b = cfg.batch;

        let step_emp = format!("step_{mname}_emp");
        let step_1mc = format!("step_{mname}_1mc");
        let eval_exe = format!("eval_{mname}");
        let predict_exe = format!("predict_{mname}");
        execs.insert(step_emp.clone(), ExecSpec::Step { model: mname.to_string(), one_mc: false });
        execs.insert(step_1mc.clone(), ExecSpec::Step { model: mname.to_string(), one_mc: true });
        execs.insert(eval_exe.clone(), ExecSpec::Eval { model: mname.to_string() });
        execs.insert(predict_exe.clone(), ExecSpec::Predict { model: mname.to_string() });

        let mut kfac_layers = Vec::new();
        let mut bn_order = Vec::new();
        for lg in &geo {
            if lg.kind == "bn" {
                let c = lg.channels;
                let bn_inv = format!("bn_inv_{c}");
                let bn_full = format!("bn_full_{c}");
                let full_bucket = bucket(2 * c);
                let invert_full = format!("invert_{full_bucket}");
                execs.insert(bn_inv.clone(), ExecSpec::BnInv);
                execs.insert(bn_full.clone(), ExecSpec::BnFull);
                execs.insert(invert_full.clone(), ExecSpec::Invert { n: full_bucket });
                kfac_layers.push(KfacLayer {
                    name: lg.name.clone(),
                    kind: "bn".to_string(),
                    a_dim: 0,
                    g_dim: 0,
                    a_bucket: 0,
                    g_bucket: 0,
                    grad_shape: (0, 0),
                    factor_a: String::new(),
                    factor_g: String::new(),
                    invert_a: String::new(),
                    invert_g: String::new(),
                    precond: String::new(),
                    weight_param: String::new(),
                    channels: c,
                    bn_inv,
                    bn_full,
                    invert_full,
                    full_bucket,
                    gamma_param: format!("{}.gamma", lg.name),
                    beta_param: format!("{}.beta", lg.name),
                });
                bn_order.push(lg.name.clone());
                continue;
            }
            let (factor_a, factor_g) = if lg.kind == "conv" {
                let (cin, h, w, k, s, p) = lg.conv_sig.expect("conv layer has a signature");
                let fa = format!("factor_conv_a_c{cin}h{h}w{w}k{k}s{s}p{p}_b{b}");
                execs.insert(
                    fa.clone(),
                    ExecSpec::FactorConvA { cin, h, w, k, stride: s, pad: p, batch: b },
                );
                let rows = b * lg.spatial;
                let fg = format!("factor_g_r{rows}c{}s{b}", lg.g_dim);
                execs.insert(
                    fg.clone(),
                    ExecSpec::FactorSyrk { rows, cols: lg.g_dim, scale_rows: b },
                );
                (fa, fg)
            } else {
                let fa = format!("factor_g_r{b}c{}s{b}", lg.a_dim);
                execs.insert(
                    fa.clone(),
                    ExecSpec::FactorSyrk { rows: b, cols: lg.a_dim, scale_rows: b },
                );
                let fg = format!("factor_g_r{b}c{}s{b}", lg.g_dim);
                execs.insert(
                    fg.clone(),
                    ExecSpec::FactorSyrk { rows: b, cols: lg.g_dim, scale_rows: b },
                );
                (fa, fg)
            };
            let (a_bucket, g_bucket) = (bucket(lg.a_dim), bucket(lg.g_dim));
            let invert_a = format!("invert_{a_bucket}");
            let invert_g = format!("invert_{g_bucket}");
            execs.insert(invert_a.clone(), ExecSpec::Invert { n: a_bucket });
            execs.insert(invert_g.clone(), ExecSpec::Invert { n: g_bucket });
            let (gm, gn) = lg.grad_shape;
            let precond = format!("precond_{gm}x{gn}");
            execs.insert(precond.clone(), ExecSpec::Precond { m: gm, n: gn });
            kfac_layers.push(KfacLayer {
                name: lg.name.clone(),
                kind: lg.kind.to_string(),
                a_dim: lg.a_dim,
                g_dim: lg.g_dim,
                a_bucket,
                g_bucket,
                grad_shape: lg.grad_shape,
                factor_a,
                factor_g,
                invert_a,
                invert_g,
                precond,
                weight_param: format!("{}.w", lg.name),
                channels: 0,
                bn_inv: String::new(),
                bn_full: String::new(),
                invert_full: String::new(),
                full_bucket: 0,
                gamma_param: String::new(),
                beta_param: String::new(),
            });
        }

        // step output layout (mirrors the AOT manifest ordering)
        let mut step_outputs = vec![
            OutputSpec {
                name: "loss".to_string(),
                role: "loss".to_string(),
                layer: None,
                param: None,
                shape: Vec::new(),
            },
            OutputSpec {
                name: "ncorrect".to_string(),
                role: "ncorrect".to_string(),
                layer: None,
                param: None,
                shape: Vec::new(),
            },
        ];
        for (pname, shape) in &pshapes {
            step_outputs.push(OutputSpec {
                name: format!("grad:{pname}"),
                role: "grad".to_string(),
                layer: None,
                param: Some(pname.clone()),
                shape: shape.clone(),
            });
        }
        for lg in geo.iter().filter(|lg| lg.kind != "bn") {
            step_outputs.push(OutputSpec {
                name: format!("a_tap:{}", lg.name),
                role: "a_tap".to_string(),
                layer: Some(lg.name.clone()),
                param: None,
                shape: lg.a_tap_shape.clone(),
            });
            step_outputs.push(OutputSpec {
                name: format!("g_tap:{}", lg.name),
                role: "g_tap".to_string(),
                layer: Some(lg.name.clone()),
                param: None,
                shape: lg.g_tap_shape.clone(),
            });
        }
        for lg in geo.iter().filter(|lg| lg.kind == "bn") {
            for role in ["g_gamma", "g_beta"] {
                step_outputs.push(OutputSpec {
                    name: format!("{role}:{}", lg.name),
                    role: role.to_string(),
                    layer: Some(lg.name.clone()),
                    param: None,
                    shape: vec![b, lg.channels],
                });
            }
        }
        for lg in geo.iter().filter(|lg| lg.kind == "bn") {
            for role in ["bn_mean", "bn_var"] {
                step_outputs.push(OutputSpec {
                    name: format!("{role}:{}", lg.name),
                    role: role.to_string(),
                    layer: Some(lg.name.clone()),
                    param: None,
                    shape: vec![lg.channels],
                });
            }
        }

        let (c, h, w) = cfg.in_shape;
        manifests.insert(
            mname.to_string(),
            ModelManifest {
                name: mname.to_string(),
                input_shape: vec![b, c, h, w],
                num_classes: cfg.num_classes,
                batch: b,
                params: pshapes
                    .iter()
                    .map(|(n, s)| ParamSpec { name: n.clone(), shape: s.clone() })
                    .collect(),
                init_file: String::new(),
                kfac_layers,
                bn_order,
                step_outputs,
                step_emp,
                step_1mc,
                eval_exe,
                predict_exe,
            },
        );
        init_params.insert(mname.to_string(), cfg.init_params(seed));
        models.insert(
            mname.to_string(),
            NativeModel {
                param_names: pshapes.into_iter().map(|(n, _)| n).collect(),
                geo,
                cfg,
            },
        );
    }

    let executables = execs.keys().map(|k| (k.clone(), k.clone())).collect();
    let manifest = Manifest {
        dir: PathBuf::new(),
        ns_iters: NS_ITERS,
        models: manifests,
        executables,
        init_params,
    };
    let backend = NativeBackend {
        models,
        execs,
        ns_iters: NS_ITERS,
        executions: AtomicU64::new(0),
        exec_nanos: AtomicU64::new(0),
        scratch: Mutex::new(Scratch::new()),
    };
    Ok((manifest, backend))
}

impl NativeBackend {
    fn model(&self, name: &str) -> Result<&NativeModel> {
        self.models.get(name).with_context(|| format!("model '{name}' not registered"))
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// An isolated copy of this backend (same model/executable tables,
    /// fresh scratch arena and counters) — one per `dist` worker thread,
    /// so per-worker hot loops never contend on the scratch mutex.
    pub fn fork(&self) -> NativeBackend {
        NativeBackend {
            models: self.models.clone(),
            execs: self.execs.clone(),
            ns_iters: self.ns_iters,
            executions: AtomicU64::new(0),
            exec_nanos: AtomicU64::new(0),
            scratch: Mutex::new(Scratch::new()),
        }
    }
}

fn check_shape(t: &HostTensor, want: &[usize], what: &str) -> Result<()> {
    anyhow::ensure!(t.shape == want, "{what}: shape {:?} != expected {:?}", t.shape, want);
    Ok(())
}

impl Executor for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn execute_seeded(
        &self,
        name: &str,
        inputs: &[&HostTensor],
        seed: Option<u32>,
    ) -> Result<Vec<HostTensor>> {
        let spec = self
            .execs
            .get(name)
            .with_context(|| format!("executable '{name}' not in manifest"))?;
        // static span name per executable class (manifest names are dynamic)
        let _exec_span = obs::span(
            match spec {
                ExecSpec::Step { .. } => "exec_step",
                ExecSpec::Eval { .. } => "exec_eval",
                ExecSpec::Predict { .. } => "exec_predict",
                ExecSpec::FactorConvA { .. } => "exec_factor_conv_a",
                ExecSpec::FactorSyrk { .. } => "exec_factor_syrk",
                ExecSpec::BnInv => "exec_bn_inv",
                ExecSpec::BnFull => "exec_bn_full",
                ExecSpec::Invert { .. } => "exec_invert",
                ExecSpec::Precond { .. } => "exec_precond",
            },
            Cat::Compute,
        );
        // lint:allow(determinism) -- exec wall-time telemetry, never step math
        let t0 = Instant::now();
        let mut scratch_guard = self.scratch.lock().unwrap();
        let scratch = &mut *scratch_guard;
        let out = match spec {
            ExecSpec::Step { model, one_mc } => {
                let m = self.model(model)?;
                net::run_step(&m.cfg, &m.param_names, &m.geo, inputs, *one_mc, seed, scratch)
                    .with_context(|| format!("native step {name}"))?
            }
            ExecSpec::Eval { model } => {
                let m = self.model(model)?;
                net::run_eval(&m.cfg, &m.param_names, &m.geo, inputs, scratch)
                    .with_context(|| format!("native eval {name}"))?
            }
            ExecSpec::Predict { model } => {
                let m = self.model(model)?;
                net::run_predict(&m.cfg, &m.param_names, &m.geo, inputs, scratch)
                    .with_context(|| format!("native predict {name}"))?
            }
            ExecSpec::FactorConvA { cin, h, w, k, stride, pad, batch } => {
                anyhow::ensure!(inputs.len() == 1, "{name}: expects the a_tap input");
                check_shape(inputs[0], &[*batch, *cin, *h, *w], name)?;
                let (kk, ss, pp) = (*k, *stride, *pad);
                let (ho, wo) = kernels::conv_out_dims(*h, *w, kk, ss, pp);
                let mut patches = scratch.mat_spare(*batch * ho * wo, *cin * kk * kk);
                kernels::im2col_into_with(pool::global(), inputs[0], kk, ss, pp, &mut patches);
                let scale = 1.0 / (*batch * ho * wo) as f32;
                let s = kernels::syrk(&patches, scale);
                scratch.recycle_mat(patches);
                vec![HostTensor::new(vec![s.rows, s.cols], s.data)]
            }
            ExecSpec::FactorSyrk { rows, cols, scale_rows } => {
                anyhow::ensure!(inputs.len() == 1, "{name}: expects the tap input");
                check_shape(inputs[0], &[*rows, *cols], name)?;
                let scale = 1.0 / *scale_rows as f32;
                let p = pool::global();
                let s = kernels::syrk_slice_with(p, &inputs[0].data, *rows, *cols, scale);
                vec![HostTensor::new(vec![s.rows, s.cols], s.data)]
            }
            ExecSpec::BnInv => {
                anyhow::ensure!(inputs.len() == 3, "{name}: expects (g_gamma, g_beta, damping)");
                vec![kernels::bn_unit_fisher_inv(inputs[0], inputs[1], inputs[2].data[0])]
            }
            ExecSpec::BnFull => {
                anyhow::ensure!(inputs.len() == 2, "{name}: expects (g_gamma, g_beta)");
                vec![kernels::bn_full_fisher(inputs[0], inputs[1])]
            }
            ExecSpec::Invert { n } => {
                anyhow::ensure!(inputs.len() == 2, "{name}: expects (matrix, damping)");
                check_shape(inputs[0], &[*n, *n], name)?;
                let damping = inputs[1].data[0];
                let p = pool::global();
                let data = &inputs[0].data;
                let inv = kernels::ns_inverse_with(p, scratch, data, *n, damping, self.ns_iters);
                vec![HostTensor::new(vec![inv.rows, inv.cols], inv.data)]
            }
            ExecSpec::Precond { m, n } => {
                anyhow::ensure!(inputs.len() == 3, "{name}: expects (g_inv, grad, a_inv)");
                check_shape(inputs[0], &[*m, *m], name)?;
                check_shape(inputs[1], &[*m, *n], name)?;
                check_shape(inputs[2], &[*n, *n], name)?;
                let gi = scratch.mat_from(*m, *m, &inputs[0].data);
                let gr = scratch.mat_from(*m, *n, &inputs[1].data);
                let ai = scratch.mat_from(*n, *n, &inputs[2].data);
                let u = kernels::precondition_with(pool::global(), scratch, &gi, &gr, &ai);
                scratch.recycle_mat(gi);
                scratch.recycle_mat(gr);
                scratch.recycle_mat(ai);
                vec![HostTensor::new(vec![u.rows, u.cols], u.data)]
            }
        };
        self.executions.fetch_add(1, Ordering::Relaxed);
        self.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    fn ensure_compiled(&self, name: &str) -> Result<bool> {
        anyhow::ensure!(self.execs.contains_key(name), "executable '{name}' not in manifest");
        Ok(false)
    }

    fn exec_seconds(&self) -> f64 {
        self.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    fn fork_worker(&self) -> Option<Arc<dyn Executor>> {
        Some(Arc::new(self.fork()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_mirrors_aot_contract() {
        let (manifest, backend) = build(&["mlp", "convnet_small"], 0).unwrap();
        let m = manifest.model("convnet_small").unwrap();
        assert_eq!(m.kfac_layers.len(), 21);
        assert_eq!(m.input_shape, vec![32, 3, 16, 16]);
        // every referenced executable resolves in the backend
        for l in &m.kfac_layers {
            let names: Vec<&String> = if l.is_bn() {
                vec![&l.bn_inv, &l.bn_full, &l.invert_full]
            } else {
                vec![&l.factor_a, &l.factor_g, &l.invert_a, &l.invert_g, &l.precond]
            };
            for n in names {
                assert!(backend.execs.contains_key(n), "missing exec {n}");
            }
        }
        assert!(backend.execs.contains_key(&m.step_emp));
        assert!(backend.execs.contains_key(&m.step_1mc));
        assert!(backend.execs.contains_key(&m.eval_exe));
        assert!(backend.execs.contains_key(&m.predict_exe));
        // buckets are multiples of 16 and cover the dims
        for l in m.kfac_layers.iter().filter(|l| !l.is_bn()) {
            assert!(l.a_bucket >= l.a_dim && l.a_bucket % 16 == 0);
            assert!(l.g_bucket >= l.g_dim && l.g_bucket % 16 == 0);
        }
        // step outputs: declared count = 2 + params + 2*(conv/fc) + 4*bn
        let bn = m.kfac_layers.iter().filter(|l| l.is_bn()).count();
        let convfc = m.kfac_layers.len() - bn;
        assert_eq!(m.step_outputs.len(), 2 + m.params.len() + 2 * convfc + 4 * bn);
    }

    #[test]
    fn init_params_present_for_each_model() {
        let (manifest, _) = build(&["mlp"], 7).unwrap();
        let m = manifest.model("mlp").unwrap();
        let params = manifest.load_init_params(m).unwrap();
        assert_eq!(params.len(), m.params.len());
        for (p, spec) in params.iter().zip(m.params.iter()) {
            assert_eq!(p.shape, spec.shape);
        }
    }

    #[test]
    fn unknown_executable_is_an_error() {
        let (_, backend) = build(&["mlp"], 0).unwrap();
        assert!(backend.execute("nope", &[]).is_err());
        assert!(backend.ensure_compiled("nope").is_err());
        assert!(backend.ensure_compiled("step_mlp_emp").is_ok());
    }
}
