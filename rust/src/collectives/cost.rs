//! α-β cluster cost model: converts measured per-GPU work + logged
//! communication volumes into predicted step times at arbitrary GPU
//! counts. Regenerates the *shape* of Fig. 5 / Table 1 timing columns
//! (the authors' testbed was ABCI: 4×V100 per node, InfiniBand EDR).

/// Collective algorithm families the model prices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    RingAllReduce,
    RingReduceScatter,
    RingAllGather,
    /// Ueno & Yokota hierarchical AllReduce: intra-node RS, inter-node AR
    /// over the node leaders, intra-node AG.
    HierarchicalAllReduce,
}

/// Cluster constants. Defaults approximate ABCI (Tesla V100 nodes).
#[derive(Clone, Debug)]
pub struct ClusterModel {
    /// GPUs per node (ABCI: 4).
    pub gpus_per_node: usize,
    /// per-hop latency within a node (NVLink), seconds
    pub alpha_intra: f64,
    /// per-hop latency across nodes (IB EDR), seconds
    pub alpha_inter: f64,
    /// intra-node bandwidth, bytes/s per GPU pair
    pub beta_intra: f64,
    /// inter-node bandwidth, bytes/s per node
    pub beta_inter: f64,
}

impl Default for ClusterModel {
    fn default() -> Self {
        ClusterModel {
            gpus_per_node: 4,
            alpha_intra: 3e-6,
            alpha_inter: 8e-6,
            beta_intra: 60e9,  // NVLink-ish effective
            beta_inter: 10e9,  // IB EDR ~100 Gb/s effective
        }
    }
}

impl ClusterModel {
    fn nodes(&self, p: usize) -> usize {
        p.div_ceil(self.gpus_per_node).max(1)
    }

    /// Effective per-GPU bandwidth for a ring spanning the whole cluster:
    /// bounded by the inter-node link once the ring crosses nodes.
    fn ring_beta(&self, p: usize) -> f64 {
        if p <= self.gpus_per_node {
            self.beta_intra
        } else {
            // every node's traffic funnels through its IB link; the ring
            // moves ~(per-GPU bytes * gpus_per_node) through each node
            self.beta_inter / self.gpus_per_node as f64
        }
    }

    fn ring_alpha(&self, p: usize) -> f64 {
        if p <= self.gpus_per_node {
            self.alpha_intra
        } else {
            self.alpha_inter
        }
    }

    /// Time for one collective moving `bytes` *per GPU of payload* (the
    /// full buffer size N; ring traffic factors are applied here).
    pub fn collective_time(&self, kind: CollectiveKind, bytes: f64, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let pf = p as f64;
        let ring = (pf - 1.0) / pf;
        match kind {
            CollectiveKind::RingReduceScatter | CollectiveKind::RingAllGather => {
                (pf - 1.0) * self.ring_alpha(p) + ring * bytes / self.ring_beta(p)
            }
            CollectiveKind::RingAllReduce => {
                2.0 * (pf - 1.0) * self.ring_alpha(p)
                    + 2.0 * ring * bytes / self.ring_beta(p)
            }
            CollectiveKind::HierarchicalAllReduce => {
                let g = self.gpus_per_node.min(p) as f64;
                let nodes = self.nodes(p) as f64;
                let intra = 2.0 * (g - 1.0) * self.alpha_intra
                    + 2.0 * (g - 1.0) / g * bytes / self.beta_intra;
                let inter = if nodes > 1.0 {
                    2.0 * (nodes - 1.0) * self.alpha_inter
                        + 2.0 * (nodes - 1.0) / nodes * (bytes / g) / self.beta_inter
                } else {
                    0.0
                };
                intra + inter
            }
        }
    }
}

/// Measured single-GPU work profile for one training step (seconds),
/// captured by the coordinator and fed to [`predict_step_time`].
#[derive(Clone, Debug, Default)]
pub struct StepProfile {
    /// forward pass (per GPU, fixed per-GPU batch)
    pub t_forward: f64,
    /// backward pass (per GPU)
    pub t_backward: f64,
    /// statistics construction for ALL layers (one GPU's shard)
    pub t_factors: f64,
    /// factor inversion for ALL layers (single process)
    pub t_inverse: f64,
    /// preconditioning + weight update for ALL layers
    pub t_update: f64,
    /// extra backward for the 1mc Fisher (0 for emp)
    pub t_extra_bwd: f64,
    /// bytes per GPU: statistics ReduceScatterV payload (A + G/F)
    pub stats_bytes: f64,
    /// bytes per GPU: gradient AllReduce payload
    pub grad_bytes: f64,
    /// bytes per GPU: parameter AllGatherV payload
    pub param_bytes: f64,
    /// number of invertible statistics (model-parallel work items)
    pub n_stats: usize,
}

/// Predict time/step at `p` GPUs from a single-GPU profile — the Fig. 5
/// generator. Key structure (§5.1):
///  - fwd/bwd/factor construction are data-parallel (constant in p,
///    per-GPU batch fixed);
///  - Stage-2 overlaps the A-statistics ReduceScatterV with the backward;
///  - inversion + update are model-parallel: divided by min(p, n_stats)
///    (the superlinear-scaling source at small p);
///  - Stage-5 AllGatherV + gradient AllReduce pay ring costs that grow
///    with p (the ≥128-GPU degradation).
pub fn predict_step_time(prof: &StepProfile, p: usize, cm: &ClusterModel) -> f64 {
    let p = p.max(1);
    let mp = p.min(prof.n_stats.max(1)) as f64;
    let t_inv = prof.t_inverse / mp;
    let t_upd = prof.t_update / mp;

    let half = 0.5 * prof.stats_bytes;
    let t_rs_a = cm.collective_time(CollectiveKind::RingReduceScatter, half, p);
    let t_rs_g = cm.collective_time(CollectiveKind::RingReduceScatter, half, p);
    let t_ar_grad =
        cm.collective_time(CollectiveKind::HierarchicalAllReduce, prof.grad_bytes, p);
    let t_ag_param = cm.collective_time(CollectiveKind::RingAllGather, prof.param_bytes, p);

    // Stage 1: forward + A-factor construction (half the factor work)
    let stage1 = prof.t_forward + 0.5 * prof.t_factors;
    // Stage 2: backward (+1mc extra) overlapped with ReduceScatterV(A)
    let stage2 = (prof.t_backward + prof.t_extra_bwd + 0.5 * prof.t_factors).max(t_rs_a);
    // Stage 3: ReduceScatterV(G, F) + gradient AllReduce
    let stage3 = t_rs_g + t_ar_grad;
    // Stage 4: model-parallel inversion + update
    let stage4 = t_inv + t_upd;
    // Stage 5: AllGatherV(params)
    let stage5 = t_ag_param;

    stage1 + stage2 + stage3 + stage4 + stage5
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> StepProfile {
        StepProfile {
            t_forward: 0.020,
            t_backward: 0.040,
            t_factors: 0.030,
            t_inverse: 0.120,
            t_update: 0.020,
            t_extra_bwd: 0.0,
            stats_bytes: 25e6,
            grad_bytes: 100e6,
            param_bytes: 100e6,
            n_stats: 107, // ResNet-50's K-FAC layer count
        }
    }

    #[test]
    fn superlinear_region_small_p() {
        // time/step should drop superlinearly from 1 -> 64 GPUs (Fig. 5):
        // t(1)/t(64) > 2 because inversion is model-parallel.
        let cm = ClusterModel::default();
        let p1 = predict_step_time(&profile(), 1, &cm);
        let p64 = predict_step_time(&profile(), 64, &cm);
        assert!(p1 / p64 > 1.5, "p1={p1} p64={p64}");
        assert!(p1 > p64);
    }

    #[test]
    fn degradation_at_large_p_is_bounded() {
        // 128 -> 1024 should be near-flat (ideal scaling region) —
        // within 2x (paper: "almost ideal").
        let cm = ClusterModel::default();
        let a = predict_step_time(&profile(), 128, &cm);
        let b = predict_step_time(&profile(), 1024, &cm);
        assert!(b / a < 2.0, "128:{a} 1024:{b}");
    }

    #[test]
    fn collective_times_monotone_in_bytes() {
        let cm = ClusterModel::default();
        for kind in [
            CollectiveKind::RingAllReduce,
            CollectiveKind::RingReduceScatter,
            CollectiveKind::RingAllGather,
            CollectiveKind::HierarchicalAllReduce,
        ] {
            let t1 = cm.collective_time(kind, 1e6, 64);
            let t2 = cm.collective_time(kind, 1e8, 64);
            assert!(t2 > t1, "{kind:?}");
        }
    }

    #[test]
    fn hierarchical_beats_flat_ring_at_scale() {
        // The hierarchical AR (Ueno & Yokota) should win at large node
        // counts on latency (the paper's reason to adopt it).
        let cm = ClusterModel::default();
        let flat = cm.collective_time(CollectiveKind::RingAllReduce, 1e6, 1024);
        let hier = cm.collective_time(CollectiveKind::HierarchicalAllReduce, 1e6, 1024);
        assert!(hier < flat, "flat={flat} hier={hier}");
    }

    #[test]
    fn single_gpu_no_comm() {
        let cm = ClusterModel::default();
        assert_eq!(cm.collective_time(CollectiveKind::RingAllReduce, 1e9, 1), 0.0);
    }

    #[test]
    fn mixed_precision_wire_shrinks_predicted_time() {
        // the mixed wire format halves grad + stat payloads (params stay
        // f32) — exactly what `Trainer::profile` reports under `Mixed`.
        // The cost model prices real serialized bytes, so prediction must
        // drop at every comm-bound scale.
        let cm = ClusterModel::default();
        let full = profile();
        let mut mixed = profile();
        mixed.stats_bytes *= 0.5;
        mixed.grad_bytes *= 0.5;
        for p in [4, 64, 256, 1024] {
            let t32 = predict_step_time(&full, p, &cm);
            let t16 = predict_step_time(&mixed, p, &cm);
            assert!(t16 < t32, "p={p}: mixed {t16} vs f32 {t32}");
        }
        // at p=1 there is no wire, so precision cannot change the time
        assert_eq!(
            predict_step_time(&mixed, 1, &cm),
            predict_step_time(&full, 1, &cm)
        );
    }

    #[test]
    fn stale_stats_shrink_predicted_time() {
        // zeroing the stats bytes + inversion (the stale-step fast path)
        // must reduce the predicted step time at comm-bound scales.
        let cm = ClusterModel::default();
        let full = profile();
        let mut stale = profile();
        stale.stats_bytes = 0.06 * stale.stats_bytes; // Table 2: 5-8%
        stale.t_inverse = 0.06 * stale.t_inverse;
        stale.t_factors = 0.06 * stale.t_factors;
        for p in [64, 256, 1024] {
            assert!(
                predict_step_time(&stale, p, &cm) < predict_step_time(&full, p, &cm),
                "p={p}"
            );
        }
    }
}
