//! In-process collective implementations with byte accounting.
//!
//! Reduction contract (shared with `dist::RingComm`, which the threaded
//! engine uses): collectives operate on *lanes* — one buffer per
//! (micro-step, worker) contribution, passed in canonical global lane
//! order `g = m·W + w` — and reduce in lane order with f64 accumulators.
//! The canonical order makes results bit-identical for every worker
//! count that factorizes the same lane total, which is what lets the
//! threaded dist engine be differentially tested against the sequential
//! coordinator (and both against a single-worker run).

use std::sync::Mutex;

use crate::linalg::{packed_len, Mat};
use crate::util::f16;
use crate::util::obs::{self, Cat};

/// Per-GPU wire bytes of an N-element ring collective: `(p−1)/p · N ·
/// wire_elem_bytes`, rounded once — THE byte formula every
/// [`Collective`] charges, so `SimComm` and `dist::RingComm` accounting
/// can never drift apart.
pub fn ring_wire_bytes(world: usize, wire_elem_bytes: u64, elems: usize) -> u64 {
    let p = world.max(1) as f64;
    (elems as f64 * ((p - 1.0) / p) * wire_elem_bytes as f64).round() as u64
}

/// Wire precision of the gradient/statistics collective payloads (§5.2).
///
/// `Mixed` moves the gradient AllReduce and the statistics
/// ReduceScatterV as IEEE f16 while every master copy stays f32 and
/// reductions still accumulate in f64 in canonical lane order; updated
/// parameters always travel f32. Numerically this is modeled by pushing
/// each payload element through the exact f16 round-trip at
/// serialization points — the same per-element op sequence on `SimComm`
/// and `dist::RingComm`, so the two engines stay bit-identical to each
/// other within a mode (and worker-count-invariant, since every lane is
/// quantized symmetrically).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// f32 wire format (4 bytes/element) — the default.
    #[default]
    F32,
    /// f16 wire for gradients + statistics (2 bytes/element), f32 master
    /// copies, f64 reductions.
    Mixed,
}

impl Precision {
    /// Bytes per element on the wire for gradient/statistics payloads.
    pub fn wire_elem_bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Mixed => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Mixed => "mixed",
        }
    }

    /// Parse a CLI/env spelling; `fp16`/`f16` are accepted as aliases
    /// for `mixed`.
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "mixed" | "f16" | "fp16" => Ok(Precision::Mixed),
            other => Err(format!("unknown precision '{other}' (expected f32 | mixed)")),
        }
    }

    /// Resolve from `SPNGD_PRECISION` (default `F32`; invalid values are
    /// a hard error, mirroring the optimizer/model registries).
    pub fn from_env() -> Precision {
        match std::env::var("SPNGD_PRECISION") {
            Ok(v) => Precision::parse(&v).unwrap_or_else(|e| panic!("SPNGD_PRECISION: {e}")),
            Err(_) => Precision::F32,
        }
    }
}

/// One payload element as it comes off the wire: the exact f16
/// round-trip under `Mixed`, the identity under `F32`. Shared by both
/// `Collective` implementations — part of the parity contract.
#[inline]
pub fn wire_quantize(p: Precision, x: f32) -> f32 {
    match p {
        Precision::F32 => x,
        Precision::Mixed => f16::round_trip(x),
    }
}

/// Serialize a whole buffer to the wire format in place (no-op for f32).
pub fn wire_quantize_slice(p: Precision, buf: &mut [f32]) {
    if p == Precision::Mixed {
        f16::quantize_slice(buf);
    }
}

/// Canonical lane-order mean of f32 values — THE per-element reduction
/// op sequence every [`Collective`] runs (f64 accumulation in iteration
/// order, one divide, one rounding to f32). Shared so the bitwise-parity
/// contract between `SimComm` and `dist::RingComm` is enforced by code,
/// not by convention.
#[inline]
pub fn lane_mean<I: Iterator<Item = f32>>(vals: I, lanes: usize) -> f32 {
    let mut acc = 0.0f64;
    for v in vals {
        acc += v as f64;
    }
    (acc / lanes as f64) as f32
}

/// Canonical lane-order mean of one statistic's lane matrices (see
/// [`lane_mean`]; the multiplication-by-reciprocal form is part of the
/// contract and must match on every implementation).
pub fn lane_mean_mats(lanes: &[&Mat]) -> Mat {
    lane_mean_mats_wire(lanes, Precision::F32)
}

/// [`lane_mean_mats`] with the lane payloads read through the wire
/// format: under `Mixed`, each element is f16-quantized as it enters the
/// f64 accumulator — numerically identical to quantizing the published
/// copies in place (what `dist::RingComm` does) and then reducing, so
/// the engines stay bit-parity-checked per mode. The mean itself is NOT
/// quantized: it lands on the owning worker's f32 master statistics.
pub fn lane_mean_mats_wire(lanes: &[&Mat], p: Precision) -> Mat {
    let (rows, cols) = (lanes[0].rows, lanes[0].cols);
    for m in lanes {
        assert_eq!((m.rows, m.cols), (rows, cols), "lane shape mismatch");
    }
    let inv_l = 1.0 / lanes.len() as f64;
    let mut out = Mat::zeros(rows, cols);
    for (j, v) in out.data.iter_mut().enumerate() {
        let mut s = 0.0f64;
        for m in lanes {
            s += wire_quantize(p, m.data[j]) as f64;
        }
        *v = (s * inv_l) as f32;
    }
    out
}

/// The collective-communication seam between the coordinator and a
/// communicator backend: [`SimComm`] (sequential, byte accounting over
/// in-place reductions) and `dist::RingComm` (concurrent chunked
/// shared-memory collectives with the same byte accounting) both
/// implement it, so the α-β cost model and the Fig. 5/6 accounting are
/// backend-independent.
///
/// All reductions follow the canonical-lane contract described in the
/// module docs: lanes in global order, f64 accumulation in lane order,
/// mean over the lane count.
pub trait Collective: Send + Sync {
    /// Data-parallel world size (simulated GPUs) used for wire-byte
    /// accounting — independent of the lane count (lanes = world ×
    /// grad-accumulation micro-steps).
    fn world(&self) -> usize;

    /// AllReduce (mean) over equal-length lanes; the mean is written back
    /// to every lane.
    fn all_reduce_mean(&self, lanes: &mut [Vec<f32>]);

    /// ReduceScatterV of statistic matrices: `lanes[g][i]` is lane g's
    /// local matrix for statistic i; returns the lane-mean per statistic
    /// (conceptually landing on the statistic's model-parallel owner).
    fn reduce_scatter_v(&self, lanes: &[Vec<Mat>], classes: &[StatClass]) -> Vec<Mat>;

    /// AllGatherV of updated parameters (accounting; parameters are
    /// shared in-process).
    fn all_gather_v_params(&self, total_elems: usize);

    /// Snapshot cumulative byte counters.
    fn stats(&self) -> CommStats;

    /// Take and reset the per-step byte counters.
    fn take_step_stats(&self) -> CommStats;
}

/// Per-GPU communication byte counters (f32 payloads).
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// ReduceScatterV bytes for statistics (A factors).
    pub rs_stats_a: u64,
    /// ReduceScatterV bytes for statistics (G factors / BN Fishers).
    pub rs_stats_g: u64,
    /// ReduceScatter+AllGather bytes for gradients (AllReduce).
    pub ar_grads: u64,
    /// AllGatherV bytes for updated parameters.
    pub ag_params: u64,
    /// Number of collective invocations (latency accounting).
    pub num_ops: u64,
}

impl CommStats {
    pub fn total(&self) -> u64 {
        self.rs_stats_a + self.rs_stats_g + self.ar_grads + self.ag_params
    }
    pub fn stats_total(&self) -> u64 {
        self.rs_stats_a + self.rs_stats_g
    }
    pub fn add(&mut self, o: &CommStats) {
        self.rs_stats_a += o.rs_stats_a;
        self.rs_stats_g += o.rs_stats_g;
        self.ar_grads += o.ar_grads;
        self.ag_params += o.ag_params;
        self.num_ops += o.num_ops;
    }
}

/// Which statistic class a ReduceScatterV payload belongs to (Fig. 6
/// stacks A separately from G/F).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatClass {
    A,
    GorF,
}

/// Simulated communicator over `p` workers.
pub struct SimComm {
    p: usize,
    /// communicate only the upper triangle of symmetric matrices (§5.2)
    pub symmetric_packing: bool,
    /// wire precision for gradient/statistics payloads (§5.2)
    pub precision: Precision,
    stats: Mutex<CommStats>,
    step_stats: Mutex<CommStats>,
}

impl SimComm {
    pub fn new(p: usize) -> Self {
        SimComm {
            p: p.max(1),
            symmetric_packing: true,
            precision: Precision::F32,
            stats: Mutex::new(CommStats::default()),
            step_stats: Mutex::new(CommStats::default()),
        }
    }

    pub fn world(&self) -> usize {
        self.p
    }

    fn elems_to_bytes(&self, elems: usize) -> u64 {
        ring_wire_bytes(self.p, self.precision.wire_elem_bytes(), elems)
    }

    /// AllReduce (mean) of equal-shaped lane buffers (canonical lane
    /// order, one per micro-step × worker); the mean is written back to
    /// every lane. Ring AR = RS + AG; wire bytes are charged per GPU.
    /// Under `Mixed` each lane is serialized to f16 at post time and the
    /// reduced mean travels the AllGather half as f16 too, so every lane
    /// receives the quantized mean (f64 accumulation is unchanged).
    pub fn all_reduce_mean(&self, bufs: &mut [Vec<f32>]) {
        assert!(!bufs.is_empty(), "at least one lane");
        let _s = obs::span("all_reduce_mean", Cat::Comm).arg("lanes", bufs.len() as f64);
        let n = bufs[0].len();
        let nlanes = bufs.len();
        {
            let _q = obs::span("wire_quantize", Cat::Wire);
            for b in bufs.iter_mut() {
                wire_quantize_slice(self.precision, b);
            }
        }
        // reduce into lane 0 (f64 accumulation in canonical lane order)
        for i in 0..n {
            let m = lane_mean(bufs.iter().map(|b| b[i]), nlanes);
            bufs[0][i] = wire_quantize(self.precision, m);
        }
        let (first, rest) = bufs.split_first_mut().unwrap();
        for b in rest {
            b.copy_from_slice(first);
        }
        let bytes = 2 * self.elems_to_bytes(n);
        let mut s = self.stats.lock().unwrap();
        s.ar_grads += bytes;
        s.num_ops += 1;
        let mut ss = self.step_stats.lock().unwrap();
        ss.ar_grads += bytes;
        ss.num_ops += 1;
    }

    /// ReduceScatterV for symmetric statistic matrices: `items[g][i]` is
    /// lane g's local matrix for statistic i (canonical lane order); the
    /// lane mean lands on the owner of statistic i (model-parallel
    /// hand-off). Returns the reduced matrices (one per statistic).
    /// Reduction is f64 in lane order — the shared contract with
    /// `dist::RingComm`. Byte accounting uses the packed
    /// (upper-triangular) size when enabled.
    pub fn reduce_scatter_v(
        &self,
        items: &[Vec<Mat>],
        classes: &[StatClass],
    ) -> Vec<Mat> {
        assert!(!items.is_empty(), "at least one lane");
        let _s = obs::span("reduce_scatter_v", Cat::Comm).arg("items", items[0].len() as f64);
        let n_items = items[0].len();
        assert_eq!(classes.len(), n_items);
        let mut out = Vec::with_capacity(n_items);
        let mut elems_a = 0usize;
        let mut elems_g = 0usize;
        for i in 0..n_items {
            let lane_mats: Vec<&Mat> = items.iter().map(|lane| &lane[i]).collect();
            let acc = lane_mean_mats_wire(&lane_mats, self.precision);
            let elems = if self.symmetric_packing && acc.is_square() {
                packed_len(acc.rows)
            } else {
                acc.rows * acc.cols
            };
            match classes[i] {
                StatClass::A => elems_a += elems,
                StatClass::GorF => elems_g += elems,
            }
            out.push(acc);
        }
        let mut s = self.stats.lock().unwrap();
        s.rs_stats_a += self.elems_to_bytes(elems_a);
        s.rs_stats_g += self.elems_to_bytes(elems_g);
        s.num_ops += 2;
        drop(s);
        let mut ss = self.step_stats.lock().unwrap();
        ss.rs_stats_a += self.elems_to_bytes(elems_a);
        ss.rs_stats_g += self.elems_to_bytes(elems_g);
        ss.num_ops += 2;
        out
    }

    /// AllGatherV of updated parameters (owners broadcast their layers).
    /// Parameters are shared in-process, so this is accounting-only.
    /// Parameters always travel f32 — `Mixed` is scoped to gradients and
    /// statistics (§5.2).
    pub fn all_gather_v_params(&self, total_elems: usize) {
        let bytes = ring_wire_bytes(self.p, 4, total_elems);
        let mut s = self.stats.lock().unwrap();
        s.ag_params += bytes;
        s.num_ops += 1;
        drop(s);
        let mut ss = self.step_stats.lock().unwrap();
        ss.ag_params += bytes;
        ss.num_ops += 1;
    }

    /// Snapshot cumulative counters.
    pub fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    /// Take and reset the per-step counters (Fig. 6 series).
    pub fn take_step_stats(&self) -> CommStats {
        let mut ss = self.step_stats.lock().unwrap();
        let out = ss.clone();
        *ss = CommStats::default();
        out
    }
}

impl Collective for SimComm {
    fn world(&self) -> usize {
        SimComm::world(self)
    }

    fn all_reduce_mean(&self, lanes: &mut [Vec<f32>]) {
        SimComm::all_reduce_mean(self, lanes)
    }

    fn reduce_scatter_v(&self, lanes: &[Vec<Mat>], classes: &[StatClass]) -> Vec<Mat> {
        SimComm::reduce_scatter_v(self, lanes, classes)
    }

    fn all_gather_v_params(&self, total_elems: usize) {
        SimComm::all_gather_v_params(self, total_elems)
    }

    fn stats(&self) -> CommStats {
        SimComm::stats(self)
    }

    fn take_step_stats(&self) -> CommStats {
        SimComm::take_step_stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mats(vals: &[&[f32]], n: usize) -> Vec<Mat> {
        vals.iter().map(|v| Mat::from_vec(n, n, v.to_vec())).collect()
    }

    #[test]
    fn all_reduce_mean_exact() {
        let c = SimComm::new(4);
        let mut bufs = vec![
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
            vec![7.0, 8.0],
        ];
        c.all_reduce_mean(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![4.0, 5.0]);
        }
        let s = c.stats();
        // 2 * (3/4) * 2 elems * 4 bytes = 12
        assert_eq!(s.ar_grads, 12);
    }

    #[test]
    fn reduce_scatter_v_mean_and_bytes() {
        let c = SimComm::new(2);
        let w0 = mats(&[&[1., 0., 0., 1.], &[2., 2., 2., 2.]], 2);
        let w1 = mats(&[&[3., 0., 0., 3.], &[0., 0., 0., 0.]], 2);
        let out = c.reduce_scatter_v(
            &[w0, w1],
            &[StatClass::A, StatClass::GorF],
        );
        assert_eq!(out[0].data, vec![2., 0., 0., 2.]);
        assert_eq!(out[1].data, vec![1., 1., 1., 1.]);
        let s = c.stats();
        // packed 2x2 = 3 elems; ring factor 1/2; 4 bytes => 6 bytes each
        assert_eq!(s.rs_stats_a, 6);
        assert_eq!(s.rs_stats_g, 6);
    }

    #[test]
    fn packing_toggle_changes_bytes() {
        let mk = |packed: bool| {
            let mut c = SimComm::new(2);
            c.symmetric_packing = packed;
            let m = vec![Mat::eye(8)];
            c.reduce_scatter_v(&[m.clone(), m], &[StatClass::A]);
            c.stats().rs_stats_a
        };
        let packed = mk(true);
        let dense = mk(false);
        assert!(packed < dense);
        assert_eq!(packed as f64 / dense as f64, 36.0 / 64.0);
    }

    #[test]
    fn mixed_wire_halves_grad_and_stat_bytes() {
        let mut c = SimComm::new(2);
        c.precision = Precision::Mixed;
        let mut bufs = vec![vec![0.0f32; 100], vec![0.0; 100]];
        c.all_reduce_mean(&mut bufs);
        assert_eq!(c.stats().ar_grads, 2 * 50 * 2);
        let m = vec![Mat::eye(2)];
        c.reduce_scatter_v(&[m.clone(), m], &[StatClass::A]);
        // packed 2x2 = 3 elems; ring factor 1/2; 2 bytes => 3 bytes
        assert_eq!(c.stats().rs_stats_a, 3);
    }

    #[test]
    fn mixed_params_still_travel_f32() {
        let mk = |p: Precision| {
            let mut c = SimComm::new(2);
            c.precision = p;
            c.all_gather_v_params(1000);
            c.stats().ag_params
        };
        assert_eq!(mk(Precision::F32), mk(Precision::Mixed));
    }

    #[test]
    fn mixed_all_reduce_quantizes_payload_and_result() {
        let c32 = SimComm::new(2);
        let mut c16 = SimComm::new(2);
        c16.precision = Precision::Mixed;
        // 0.1 is not representable in f16: the quantized mean must differ
        // from the f32 mean, and must equal the mean of the quantized lanes.
        let lanes = || vec![vec![0.1f32, 1.0, -3.0], vec![0.3, 1.0, 5.0]];
        let mut a = lanes();
        let mut b = lanes();
        c32.all_reduce_mean(&mut a);
        c16.all_reduce_mean(&mut b);
        assert_ne!(a[0][0], b[0][0], "f16 wire must perturb 0.1/0.3");
        let expect = f16::round_trip(lane_mean(
            [f16::round_trip(0.1), f16::round_trip(0.3)].into_iter(),
            2,
        ));
        assert_eq!(b[0][0], expect);
        assert_eq!(b[0], b[1], "every lane receives the same mean");
        // exactly representable values pass through unchanged
        assert_eq!(b[0][1], 1.0);
        assert_eq!(b[0][2], 1.0);
    }

    #[test]
    fn mixed_reduce_scatter_quantizes_lanes_not_result() {
        let mut c = SimComm::new(2);
        c.precision = Precision::Mixed;
        let w0 = mats(&[&[0.1, 0., 0., 0.1]], 2);
        let w1 = mats(&[&[0.3, 0., 0., 0.3]], 2);
        let out = c.reduce_scatter_v(&[w0, w1], &[StatClass::A]);
        // lanes quantize; the owner-side mean stays full f32 (master copy)
        let expect = lane_mean(
            [f16::round_trip(0.1), f16::round_trip(0.3)].into_iter(),
            2,
        );
        assert_eq!(out[0].data[0], expect);
        assert_ne!(out[0].data[0], 0.2, "f16 wire must perturb the mean");
        assert_ne!(
            out[0].data[0],
            f16::round_trip(expect),
            "owner-side result is NOT re-quantized"
        );
    }

    #[test]
    fn precision_parse_and_names() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("fp32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("mixed").unwrap(), Precision::Mixed);
        assert_eq!(Precision::parse("fp16").unwrap(), Precision::Mixed);
        assert_eq!(Precision::parse("f16").unwrap(), Precision::Mixed);
        assert!(Precision::parse("bf16").is_err());
        assert_eq!(Precision::F32.name(), "f32");
        assert_eq!(Precision::Mixed.name(), "mixed");
        assert_eq!(Precision::F32.wire_elem_bytes(), 4);
        assert_eq!(Precision::Mixed.wire_elem_bytes(), 2);
    }

    #[test]
    fn step_stats_reset() {
        let c = SimComm::new(2);
        c.all_gather_v_params(1000);
        assert!(c.take_step_stats().ag_params > 0);
        assert_eq!(c.take_step_stats().ag_params, 0);
        assert!(c.stats().ag_params > 0, "cumulative stays");
    }

    #[test]
    fn single_worker_no_wire_bytes() {
        let c = SimComm::new(1);
        let mut bufs = vec![vec![1.0, 2.0]];
        c.all_reduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(c.stats().total(), 0, "P=1 moves nothing");
    }
}
