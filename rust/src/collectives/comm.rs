//! In-process collective implementations with byte accounting.

use std::sync::Mutex;

use crate::linalg::{packed_len, Mat};

/// Per-GPU communication byte counters (f32 payloads).
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// ReduceScatterV bytes for statistics (A factors).
    pub rs_stats_a: u64,
    /// ReduceScatterV bytes for statistics (G factors / BN Fishers).
    pub rs_stats_g: u64,
    /// ReduceScatter+AllGather bytes for gradients (AllReduce).
    pub ar_grads: u64,
    /// AllGatherV bytes for updated parameters.
    pub ag_params: u64,
    /// Number of collective invocations (latency accounting).
    pub num_ops: u64,
}

impl CommStats {
    pub fn total(&self) -> u64 {
        self.rs_stats_a + self.rs_stats_g + self.ar_grads + self.ag_params
    }
    pub fn stats_total(&self) -> u64 {
        self.rs_stats_a + self.rs_stats_g
    }
    pub fn add(&mut self, o: &CommStats) {
        self.rs_stats_a += o.rs_stats_a;
        self.rs_stats_g += o.rs_stats_g;
        self.ar_grads += o.ar_grads;
        self.ag_params += o.ag_params;
        self.num_ops += o.num_ops;
    }
}

/// Which statistic class a ReduceScatterV payload belongs to (Fig. 6
/// stacks A separately from G/F).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatClass {
    A,
    GorF,
}

/// Simulated communicator over `p` workers.
pub struct SimComm {
    p: usize,
    /// communicate only the upper triangle of symmetric matrices (§5.2)
    pub symmetric_packing: bool,
    /// bytes per element on the wire (4 = f32, 2 = fp16 communication)
    pub wire_elem_bytes: u64,
    stats: Mutex<CommStats>,
    step_stats: Mutex<CommStats>,
}

impl SimComm {
    pub fn new(p: usize) -> Self {
        SimComm {
            p: p.max(1),
            symmetric_packing: true,
            wire_elem_bytes: 4,
            stats: Mutex::new(CommStats::default()),
            step_stats: Mutex::new(CommStats::default()),
        }
    }

    pub fn world(&self) -> usize {
        self.p
    }

    /// Per-GPU ring traffic for an N-element ReduceScatter (or AllGather).
    fn ring_factor(&self) -> f64 {
        (self.p as f64 - 1.0) / self.p as f64
    }

    fn elems_to_bytes(&self, elems: usize) -> u64 {
        (elems as f64 * self.ring_factor() * self.wire_elem_bytes as f64).round() as u64
    }

    /// AllReduce (mean) of equal-shaped per-worker buffers; result is
    /// written back to every worker. Ring AR = RS + AG.
    pub fn all_reduce_mean(&self, bufs: &mut [Vec<f32>]) {
        assert_eq!(bufs.len(), self.p, "one buffer per worker");
        let n = bufs[0].len();
        // reduce into worker 0 (f64 accumulation for order-stable means)
        for i in 0..n {
            let mut acc = 0.0f64;
            for b in bufs.iter() {
                acc += b[i] as f64;
            }
            bufs[0][i] = (acc / self.p as f64) as f32;
        }
        let (first, rest) = bufs.split_first_mut().unwrap();
        for b in rest {
            b.copy_from_slice(first);
        }
        let bytes = 2 * self.elems_to_bytes(n);
        let mut s = self.stats.lock().unwrap();
        s.ar_grads += bytes;
        s.num_ops += 1;
        let mut ss = self.step_stats.lock().unwrap();
        ss.ar_grads += bytes;
        ss.num_ops += 1;
    }

    /// ReduceScatterV for symmetric statistic matrices: `items[w][i]` is
    /// worker w's local matrix for statistic i; the mean lands on the
    /// owner of statistic i (model-parallel hand-off). Returns the
    /// reduced matrices (one per statistic). Byte accounting uses the
    /// packed (upper-triangular) size when enabled.
    pub fn reduce_scatter_v(
        &self,
        items: &[Vec<Mat>],
        classes: &[StatClass],
    ) -> Vec<Mat> {
        assert_eq!(items.len(), self.p);
        let n_items = items[0].len();
        assert_eq!(classes.len(), n_items);
        let mut out = Vec::with_capacity(n_items);
        let inv_p = 1.0 / self.p as f32;
        let mut elems_a = 0usize;
        let mut elems_g = 0usize;
        for i in 0..n_items {
            let mut acc = items[0][i].clone();
            for w in 1..self.p {
                let m = &items[w][i];
                assert_eq!((m.rows, m.cols), (acc.rows, acc.cols));
                for (a, b) in acc.data.iter_mut().zip(m.data.iter()) {
                    *a += *b;
                }
            }
            acc = acc.scale(inv_p);
            let elems = if self.symmetric_packing && acc.is_square() {
                packed_len(acc.rows)
            } else {
                acc.rows * acc.cols
            };
            match classes[i] {
                StatClass::A => elems_a += elems,
                StatClass::GorF => elems_g += elems,
            }
            out.push(acc);
        }
        let mut s = self.stats.lock().unwrap();
        s.rs_stats_a += self.elems_to_bytes(elems_a);
        s.rs_stats_g += self.elems_to_bytes(elems_g);
        s.num_ops += 2;
        drop(s);
        let mut ss = self.step_stats.lock().unwrap();
        ss.rs_stats_a += self.elems_to_bytes(elems_a);
        ss.rs_stats_g += self.elems_to_bytes(elems_g);
        ss.num_ops += 2;
        out
    }

    /// AllGatherV of updated parameters (owners broadcast their layers).
    /// Parameters are shared in-process, so this is accounting-only.
    pub fn all_gather_v_params(&self, total_elems: usize) {
        let bytes = self.elems_to_bytes(total_elems);
        let mut s = self.stats.lock().unwrap();
        s.ag_params += bytes;
        s.num_ops += 1;
        drop(s);
        let mut ss = self.step_stats.lock().unwrap();
        ss.ag_params += bytes;
        ss.num_ops += 1;
    }

    /// Snapshot cumulative counters.
    pub fn stats(&self) -> CommStats {
        self.stats.lock().unwrap().clone()
    }

    /// Take and reset the per-step counters (Fig. 6 series).
    pub fn take_step_stats(&self) -> CommStats {
        let mut ss = self.step_stats.lock().unwrap();
        let out = ss.clone();
        *ss = CommStats::default();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mats(vals: &[&[f32]], n: usize) -> Vec<Mat> {
        vals.iter().map(|v| Mat::from_vec(n, n, v.to_vec())).collect()
    }

    #[test]
    fn all_reduce_mean_exact() {
        let c = SimComm::new(4);
        let mut bufs = vec![
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
            vec![7.0, 8.0],
        ];
        c.all_reduce_mean(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![4.0, 5.0]);
        }
        let s = c.stats();
        // 2 * (3/4) * 2 elems * 4 bytes = 12
        assert_eq!(s.ar_grads, 12);
    }

    #[test]
    fn reduce_scatter_v_mean_and_bytes() {
        let c = SimComm::new(2);
        let w0 = mats(&[&[1., 0., 0., 1.], &[2., 2., 2., 2.]], 2);
        let w1 = mats(&[&[3., 0., 0., 3.], &[0., 0., 0., 0.]], 2);
        let out = c.reduce_scatter_v(
            &[w0, w1],
            &[StatClass::A, StatClass::GorF],
        );
        assert_eq!(out[0].data, vec![2., 0., 0., 2.]);
        assert_eq!(out[1].data, vec![1., 1., 1., 1.]);
        let s = c.stats();
        // packed 2x2 = 3 elems; ring factor 1/2; 4 bytes => 6 bytes each
        assert_eq!(s.rs_stats_a, 6);
        assert_eq!(s.rs_stats_g, 6);
    }

    #[test]
    fn packing_toggle_changes_bytes() {
        let mk = |packed: bool| {
            let mut c = SimComm::new(2);
            c.symmetric_packing = packed;
            let m = vec![Mat::eye(8)];
            c.reduce_scatter_v(&[m.clone(), m], &[StatClass::A]);
            c.stats().rs_stats_a
        };
        let packed = mk(true);
        let dense = mk(false);
        assert!(packed < dense);
        assert_eq!(packed as f64 / dense as f64, 36.0 / 64.0);
    }

    #[test]
    fn fp16_wire_halves_bytes() {
        let mut c = SimComm::new(2);
        c.wire_elem_bytes = 2;
        let mut bufs = vec![vec![0.0f32; 100], vec![0.0; 100]];
        c.all_reduce_mean(&mut bufs);
        assert_eq!(c.stats().ar_grads, 2 * 50 * 2);
    }

    #[test]
    fn step_stats_reset() {
        let c = SimComm::new(2);
        c.all_gather_v_params(1000);
        assert!(c.take_step_stats().ag_params > 0);
        assert_eq!(c.take_step_stats().ag_params, 0);
        assert!(c.stats().ag_params > 0, "cumulative stays");
    }

    #[test]
    fn single_worker_no_wire_bytes() {
        let c = SimComm::new(1);
        let mut bufs = vec![vec![1.0, 2.0]];
        c.all_reduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        assert_eq!(c.stats().total(), 0, "P=1 moves nothing");
    }
}
