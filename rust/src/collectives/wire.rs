//! Framed wire protocol for the multi-process transport (`dist::ProcComm`).
//!
//! Every message between the coordinator and a `spngd worker` process is
//! one length-prefixed frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"SPWF"
//! 4       2     version (LE, currently 1)
//! 6       1     kind (see [`Kind`])
//! 7       1     flags (bit 0: payload elements are f16 on the wire)
//! 8       4     payload length (LE; hard-capped, checked BEFORE allocation)
//! 12      4     FNV-1a checksum of the payload (LE)
//! 16      len   payload
//! ```
//!
//! Payload element buffers travel at the wire precision of the run:
//! `f32` as little-endian f32 bytes, `mixed` as real little-endian IEEE
//! f16 bytes through `util::f16` — this module is where the f16 wire
//! format finally meets actual serialization rather than in-place
//! quantization. Decoding at the receiver is the exact
//! `wire_quantize` round trip, so process runs stay bit-identical to the
//! in-process engines. The parser ([`Frame::parse`]) is total: malformed
//! input yields a structured [`WireError`], never a panic — it is a
//! fuzz target in `tests/fuzz_smoke.rs`.

use crate::collectives::comm::Precision;
use crate::util::f16;

/// Frame magic: "SPWF" = SP-NGD wire frame.
pub const MAGIC: [u8; 4] = *b"SPWF";
/// Protocol version spoken by this build.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 16;
/// Hard cap on a payload length, enforced before any allocation.
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;
/// Flags bit 0: element payloads are f16 on the wire.
pub const FLAG_F16: u8 = 1;

/// Message kinds. Values are part of the wire contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// worker → coordinator: first frame after connect; payload = uid u64.
    Hello = 1,
    /// coordinator → worker: admission; rank/world/step/heartbeat_ms.
    Welcome = 2,
    /// worker → coordinator: liveness beacon; payload = step u64.
    Heartbeat = 3,
    /// coordinator → worker: warmup liveness probe; empty payload.
    Ping = 4,
    /// worker → coordinator: warmup probe reply; empty payload.
    Pong = 5,
    /// coordinator → worker: a training round begins; payload = step u64.
    RoundStart = 6,
    /// coordinator → worker: the round is done; payload = step u64.
    RoundEnd = 7,
    /// coordinator → worker: reduce a gradient segment across lanes.
    ReduceGrad = 8,
    /// worker → coordinator: the reduced (mean) gradient segment.
    GradSeg = 9,
    /// coordinator → worker: reduce one statistic's lane matrices.
    ReduceStats = 10,
    /// worker → coordinator: the reduced statistic matrix (always f32).
    StatResult = 11,
    /// coordinator → worker: exit cleanly; empty payload.
    Shutdown = 12,
}

impl Kind {
    fn from_u8(b: u8) -> Option<Kind> {
        Some(match b {
            1 => Kind::Hello,
            2 => Kind::Welcome,
            3 => Kind::Heartbeat,
            4 => Kind::Ping,
            5 => Kind::Pong,
            6 => Kind::RoundStart,
            7 => Kind::RoundEnd,
            8 => Kind::ReduceGrad,
            9 => Kind::GradSeg,
            10 => Kind::ReduceStats,
            11 => Kind::StatResult,
            12 => Kind::Shutdown,
            _ => return None,
        })
    }
}

/// Structured parse/decode failure — every variant names what broke, so
/// the coordinator's diagnostics can say *why* a peer was dropped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    BadMagic([u8; 4]),
    BadVersion(u16),
    BadKind(u8),
    Oversized(u32),
    BadChecksum { want: u32, got: u32 },
    BadPayload(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v} (want {VERSION})"),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Oversized(n) => {
                write!(f, "payload length {n} exceeds cap {MAX_PAYLOAD}")
            }
            WireError::BadChecksum { want, got } => {
                write!(f, "payload checksum mismatch (header {want:#010x}, payload {got:#010x})")
            }
            WireError::BadPayload(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// FNV-1a over the payload — cheap, dependency-free corruption tripwire.
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: Kind,
    pub flags: u8,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: Kind, flags: u8, payload: Vec<u8>) -> Frame {
        Frame { kind, flags, payload }
    }

    /// An empty-payload control frame.
    pub fn control(kind: Kind) -> Frame {
        Frame::new(kind, 0, Vec::new())
    }

    /// Serialize to header + payload bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_BYTES + self.payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind as u8);
        out.push(self.flags);
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&checksum(&self.payload).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Total encoded size of a frame carrying `payload_len` bytes.
    pub fn encoded_len(payload_len: usize) -> u64 {
        (HEADER_BYTES + payload_len) as u64
    }

    /// Try to parse one frame from the front of `buf`.
    ///
    /// `Ok(None)` means the buffer holds a prefix of a valid frame — read
    /// more bytes. `Ok(Some((frame, consumed)))` hands back the frame and
    /// how many bytes it occupied. Errors are unrecoverable for the
    /// stream (framing is lost); the connection should be dropped with
    /// the error as the diagnostic.
    pub fn parse(buf: &[u8]) -> Result<Option<(Frame, usize)>, WireError> {
        if buf.len() < HEADER_BYTES {
            return Ok(None);
        }
        let magic = [buf[0], buf[1], buf[2], buf[3]];
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = u16::from_le_bytes([buf[4], buf[5]]);
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let kind = Kind::from_u8(buf[6]).ok_or(WireError::BadKind(buf[6]))?;
        let flags = buf[7];
        let len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
        if len > MAX_PAYLOAD {
            return Err(WireError::Oversized(len)); // reject BEFORE allocating
        }
        let want = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
        let total = HEADER_BYTES + len as usize;
        if buf.len() < total {
            return Ok(None);
        }
        let payload = buf[HEADER_BYTES..total].to_vec();
        let got = checksum(&payload);
        if got != want {
            return Err(WireError::BadChecksum { want, got });
        }
        Ok(Some((Frame { kind, flags, payload }, total)))
    }
}

// ---------------------------------------------------------------------------
// element buffers at wire precision

fn precision_flags(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::Mixed => FLAG_F16,
    }
}

/// Append `vals` to `out` at the wire precision: LE f32 bytes, or real
/// LE f16 bytes (RNE-encoded through `util::f16`) under `Mixed`.
pub fn encode_elems(p: Precision, vals: &[f32], out: &mut Vec<u8>) {
    match p {
        Precision::F32 => {
            out.reserve(vals.len() * 4);
            for &v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Precision::Mixed => f16::encode_le(vals, out),
    }
}

/// Decode a wire-precision element buffer. Under `Mixed` the result is
/// exactly `wire_quantize` of the sender's values — the parity contract
/// with the in-process engines.
pub fn decode_elems(p: Precision, bytes: &[u8]) -> Result<Vec<f32>, WireError> {
    match p {
        Precision::F32 => {
            if bytes.len() % 4 != 0 {
                return Err(WireError::BadPayload("f32 buffer not a multiple of 4 bytes"));
            }
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }
        Precision::Mixed => {
            f16::decode_le(bytes).ok_or(WireError::BadPayload("f16 buffer has odd byte count"))
        }
    }
}

/// Wire precision implied by a frame's flags (receiver side).
pub fn flags_precision(flags: u8) -> Precision {
    if flags & FLAG_F16 != 0 {
        Precision::Mixed
    } else {
        Precision::F32
    }
}

// ---------------------------------------------------------------------------
// control payload codecs

fn rd_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn rd_u64(b: &[u8], off: usize) -> u64 {
    let mut v = [0u8; 8];
    v.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(v)
}

/// worker → coordinator introduction. `uid` is the worker's stable
/// identity across reconnects (its pid).
pub fn encode_hello(uid: u64) -> Frame {
    Frame::new(Kind::Hello, 0, uid.to_le_bytes().to_vec())
}

pub fn decode_hello(f: &Frame) -> Result<u64, WireError> {
    if f.payload.len() != 8 {
        return Err(WireError::BadPayload("hello wants 8 bytes"));
    }
    Ok(rd_u64(&f.payload, 0))
}

/// Admission parameters a worker needs to serve: its rank, the world
/// size, the coordinator's current step (resync point for late joiners)
/// and the heartbeat cadence it must keep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WelcomeMsg {
    pub rank: u32,
    pub world: u32,
    pub step: u64,
    pub heartbeat_ms: u32,
}

pub fn encode_welcome(w: WelcomeMsg) -> Frame {
    let mut p = Vec::with_capacity(20);
    p.extend_from_slice(&w.rank.to_le_bytes());
    p.extend_from_slice(&w.world.to_le_bytes());
    p.extend_from_slice(&w.step.to_le_bytes());
    p.extend_from_slice(&w.heartbeat_ms.to_le_bytes());
    Frame::new(Kind::Welcome, 0, p)
}

pub fn decode_welcome(f: &Frame) -> Result<WelcomeMsg, WireError> {
    if f.payload.len() != 20 {
        return Err(WireError::BadPayload("welcome wants 20 bytes"));
    }
    Ok(WelcomeMsg {
        rank: rd_u32(&f.payload, 0),
        world: rd_u32(&f.payload, 4),
        step: rd_u64(&f.payload, 8),
        heartbeat_ms: rd_u32(&f.payload, 16),
    })
}

/// Heartbeat / RoundStart / RoundEnd all carry one step counter.
pub fn encode_step(kind: Kind, step: u64) -> Frame {
    Frame::new(kind, 0, step.to_le_bytes().to_vec())
}

pub fn decode_step(f: &Frame) -> Result<u64, WireError> {
    if f.payload.len() != 8 {
        return Err(WireError::BadPayload("step payload wants 8 bytes"));
    }
    Ok(rd_u64(&f.payload, 0))
}

// ---------------------------------------------------------------------------
// reduction job codecs

/// A gradient-segment reduction job: all lanes' values for one
/// contiguous element range, to be lane-mean-reduced by a worker.
#[derive(Clone, Debug, PartialEq)]
pub struct GradJob {
    pub job: u32,
    pub seg_len: u32,
    /// lane-major: `lanes[g]` is lane g's segment (len = seg_len).
    pub lanes: Vec<Vec<f32>>,
}

pub fn encode_grad_job(p: Precision, job: u32, lanes: &[&[f32]]) -> Frame {
    let seg_len = lanes.first().map_or(0, |l| l.len()) as u32;
    let mut pl = Vec::with_capacity(16 + lanes.len() * seg_len as usize * 4);
    pl.extend_from_slice(&job.to_le_bytes());
    pl.extend_from_slice(&(lanes.len() as u32).to_le_bytes());
    pl.extend_from_slice(&seg_len.to_le_bytes());
    pl.extend_from_slice(&0u32.to_le_bytes());
    for lane in lanes {
        encode_elems(p, lane, &mut pl);
    }
    Frame::new(Kind::ReduceGrad, precision_flags(p), pl)
}

pub fn decode_grad_job(f: &Frame) -> Result<GradJob, WireError> {
    if f.payload.len() < 16 {
        return Err(WireError::BadPayload("grad job header wants 16 bytes"));
    }
    let job = rd_u32(&f.payload, 0);
    let n_lanes = rd_u32(&f.payload, 4) as usize;
    let seg_len = rd_u32(&f.payload, 8) as usize;
    let p = flags_precision(f.flags);
    let elem = p.wire_elem_bytes() as usize;
    let body = &f.payload[16..];
    if n_lanes == 0 || body.len() != n_lanes * seg_len * elem {
        return Err(WireError::BadPayload("grad job body length mismatch"));
    }
    let mut lanes = Vec::with_capacity(n_lanes);
    for g in 0..n_lanes {
        lanes.push(decode_elems(p, &body[g * seg_len * elem..(g + 1) * seg_len * elem])?);
    }
    Ok(GradJob { job, seg_len: seg_len as u32, lanes })
}

/// Worker's reply: the lane-mean gradient segment, at wire precision
/// (the AllGather half of a ring AllReduce also travels quantized).
pub fn encode_grad_seg(p: Precision, job: u32, seg: &[f32]) -> Frame {
    let mut pl = Vec::with_capacity(8 + seg.len() * 4);
    pl.extend_from_slice(&job.to_le_bytes());
    pl.extend_from_slice(&(seg.len() as u32).to_le_bytes());
    encode_elems(p, seg, &mut pl);
    Frame::new(Kind::GradSeg, precision_flags(p), pl)
}

pub fn decode_grad_seg(f: &Frame) -> Result<(u32, Vec<f32>), WireError> {
    if f.payload.len() < 8 {
        return Err(WireError::BadPayload("grad seg header wants 8 bytes"));
    }
    let job = rd_u32(&f.payload, 0);
    let seg_len = rd_u32(&f.payload, 4) as usize;
    let p = flags_precision(f.flags);
    let body = &f.payload[8..];
    if body.len() != seg_len * p.wire_elem_bytes() as usize {
        return Err(WireError::BadPayload("grad seg body length mismatch"));
    }
    Ok((job, decode_elems(p, body)?))
}

/// One statistic's lane matrices, to be lane-mean-reduced by a worker.
#[derive(Clone, Debug, PartialEq)]
pub struct StatJob {
    pub item: u32,
    pub rows: u32,
    pub cols: u32,
    /// lane-major flattened matrices, each rows·cols long.
    pub lanes: Vec<Vec<f32>>,
}

pub fn encode_stat_job(
    p: Precision,
    item: u32,
    rows: u32,
    cols: u32,
    lanes: &[&[f32]],
) -> Frame {
    let mut pl = Vec::with_capacity(16 + lanes.len() * (rows * cols) as usize * 4);
    pl.extend_from_slice(&item.to_le_bytes());
    pl.extend_from_slice(&rows.to_le_bytes());
    pl.extend_from_slice(&cols.to_le_bytes());
    pl.extend_from_slice(&(lanes.len() as u32).to_le_bytes());
    for lane in lanes {
        encode_elems(p, lane, &mut pl);
    }
    Frame::new(Kind::ReduceStats, precision_flags(p), pl)
}

pub fn decode_stat_job(f: &Frame) -> Result<StatJob, WireError> {
    if f.payload.len() < 16 {
        return Err(WireError::BadPayload("stat job header wants 16 bytes"));
    }
    let item = rd_u32(&f.payload, 0);
    let rows = rd_u32(&f.payload, 4);
    let cols = rd_u32(&f.payload, 8);
    let n_lanes = rd_u32(&f.payload, 12) as usize;
    let p = flags_precision(f.flags);
    let elem = p.wire_elem_bytes() as usize;
    let mat = (rows as usize).saturating_mul(cols as usize);
    let body = &f.payload[16..];
    if n_lanes == 0 || mat == 0 || body.len() != n_lanes * mat * elem {
        return Err(WireError::BadPayload("stat job body length mismatch"));
    }
    let mut lanes = Vec::with_capacity(n_lanes);
    for g in 0..n_lanes {
        lanes.push(decode_elems(p, &body[g * mat * elem..(g + 1) * mat * elem])?);
    }
    Ok(StatJob { item, rows, cols, lanes })
}

/// Worker's reply: the owner-side statistic mean. ALWAYS f32 — the mean
/// lands on an f32 master copy and is never re-quantized (§5.2).
pub fn encode_stat_result(item: u32, rows: u32, cols: u32, mat: &[f32]) -> Frame {
    let mut pl = Vec::with_capacity(16 + mat.len() * 4);
    pl.extend_from_slice(&item.to_le_bytes());
    pl.extend_from_slice(&rows.to_le_bytes());
    pl.extend_from_slice(&cols.to_le_bytes());
    pl.extend_from_slice(&0u32.to_le_bytes());
    encode_elems(Precision::F32, mat, &mut pl);
    Frame::new(Kind::StatResult, 0, pl)
}

pub fn decode_stat_result(f: &Frame) -> Result<(u32, u32, u32, Vec<f32>), WireError> {
    if f.payload.len() < 16 {
        return Err(WireError::BadPayload("stat result header wants 16 bytes"));
    }
    let item = rd_u32(&f.payload, 0);
    let rows = rd_u32(&f.payload, 4);
    let cols = rd_u32(&f.payload, 8);
    let body = &f.payload[16..];
    if body.len() != (rows as usize).saturating_mul(cols as usize) * 4 {
        return Err(WireError::BadPayload("stat result body length mismatch"));
    }
    Ok((item, rows, cols, decode_elems(Precision::F32, body)?))
}

// ---------------------------------------------------------------------------
// segment partitioning + closed-form framed-byte accounting

/// Balanced contiguous partition of `elems` into at most `parts` ranges:
/// the first `elems % parts` segments get one extra element. Returns
/// `(start, len)` pairs; empty segments are dropped, so fewer workers
/// than elements always means every worker gets work.
pub fn split_segments(elems: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    let base = elems / parts;
    let rem = elems % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len > 0 {
            out.push((start, len));
            start += len;
        }
    }
    out
}

/// Closed-form framed bytes the coordinator SENDS for one gradient
/// AllReduce round (one `ReduceGrad` frame per segment, all lanes).
pub fn grad_round_tx_bytes(seg_lens: &[usize], lanes: usize, elem_bytes: u64) -> u64 {
    seg_lens
        .iter()
        .map(|&len| Frame::encoded_len(16 + lanes * len * elem_bytes as usize))
        .sum()
}

/// Closed-form framed bytes the coordinator RECEIVES for one gradient
/// AllReduce round (one `GradSeg` reply per segment).
pub fn grad_round_rx_bytes(seg_lens: &[usize], elem_bytes: u64) -> u64 {
    seg_lens.iter().map(|&len| Frame::encoded_len(8 + len * elem_bytes as usize)).sum()
}

/// Closed-form framed bytes to SEND one statistic reduction job.
pub fn stat_item_tx_bytes(rows: usize, cols: usize, lanes: usize, elem_bytes: u64) -> u64 {
    Frame::encoded_len(16 + lanes * rows * cols * elem_bytes as usize)
}

/// Closed-form framed bytes of one statistic result (always f32).
pub fn stat_item_rx_bytes(rows: usize, cols: usize) -> u64 {
    Frame::encoded_len(16 + rows * cols * 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::comm::{lane_mean, wire_quantize};

    #[test]
    fn frame_round_trip_all_kinds() {
        for kind in [
            Kind::Hello,
            Kind::Welcome,
            Kind::Heartbeat,
            Kind::Ping,
            Kind::Pong,
            Kind::RoundStart,
            Kind::RoundEnd,
            Kind::ReduceGrad,
            Kind::GradSeg,
            Kind::ReduceStats,
            Kind::StatResult,
            Kind::Shutdown,
        ] {
            let f = Frame::new(kind, FLAG_F16, vec![1, 2, 3]);
            let bytes = f.encode();
            let (back, used) = Frame::parse(&bytes).unwrap().unwrap();
            assert_eq!(back, f);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn parse_wants_more_bytes_on_truncation() {
        let bytes = encode_hello(42).encode();
        for cut in 0..bytes.len() {
            let r = Frame::parse(&bytes[..cut]);
            assert_eq!(r, Ok(None), "prefix of {cut} bytes must ask for more");
        }
        // two concatenated frames: first parse consumes exactly one
        let mut two = bytes.clone();
        two.extend_from_slice(&encode_step(Kind::Heartbeat, 7).encode());
        let (f, used) = Frame::parse(&two).unwrap().unwrap();
        assert_eq!(f.kind, Kind::Hello);
        let (g, _) = Frame::parse(&two[used..]).unwrap().unwrap();
        assert_eq!(g.kind, Kind::Heartbeat);
        assert_eq!(decode_step(&g).unwrap(), 7);
    }

    #[test]
    fn parse_rejects_structured() {
        let good = encode_hello(1).encode();
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Frame::parse(&bad), Err(WireError::BadMagic(_))));
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(matches!(Frame::parse(&bad), Err(WireError::BadVersion(_))));
        let mut bad = good.clone();
        bad[6] = 200;
        assert_eq!(Frame::parse(&bad), Err(WireError::BadKind(200)));
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::parse(&bad), Err(WireError::Oversized(_))));
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff; // flip a payload byte: checksum trips
        assert!(matches!(Frame::parse(&bad), Err(WireError::BadChecksum { .. })));
    }

    #[test]
    fn oversized_is_rejected_even_without_payload_bytes() {
        // a 16-byte header announcing a huge payload must error immediately
        // (no allocation, no Ok(None) wait-for-64MiB)
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC);
        hdr.extend_from_slice(&VERSION.to_le_bytes());
        hdr.push(Kind::Heartbeat as u8);
        hdr.push(0);
        hdr.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        hdr.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(Frame::parse(&hdr), Err(WireError::Oversized(MAX_PAYLOAD + 1)));
    }

    #[test]
    fn control_codecs_round_trip() {
        assert_eq!(decode_hello(&encode_hello(0xdead_beef)).unwrap(), 0xdead_beef);
        let w = WelcomeMsg { rank: 3, world: 5, step: 17, heartbeat_ms: 50 };
        assert_eq!(decode_welcome(&encode_welcome(w)).unwrap(), w);
        for kind in [Kind::Heartbeat, Kind::RoundStart, Kind::RoundEnd] {
            assert_eq!(decode_step(&encode_step(kind, 99)).unwrap(), 99);
        }
        assert!(decode_hello(&Frame::control(Kind::Hello)).is_err());
        assert!(decode_welcome(&Frame::control(Kind::Welcome)).is_err());
    }

    #[test]
    fn grad_job_round_trip_both_precisions() {
        let l0 = [0.1f32, -2.5, 3.0];
        let l1 = [4.0f32, 0.3, -1.0];
        for p in [Precision::F32, Precision::Mixed] {
            let f = encode_grad_job(p, 7, &[&l0, &l1]);
            let job = decode_grad_job(&f).unwrap();
            assert_eq!(job.job, 7);
            assert_eq!(job.seg_len, 3);
            for (wire, sent) in job.lanes.iter().zip([&l0, &l1]) {
                for (w, &s) in wire.iter().zip(sent.iter()) {
                    assert_eq!(w.to_bits(), wire_quantize(p, s).to_bits());
                }
            }
            // a worker reduces with the shared lane_mean and replies
            let mean: Vec<f32> = (0..3)
                .map(|i| {
                    wire_quantize(p, lane_mean(job.lanes.iter().map(|l| l[i]), job.lanes.len()))
                })
                .collect();
            let (jid, back) = decode_grad_seg(&encode_grad_seg(p, 7, &mean)).unwrap();
            assert_eq!(jid, 7);
            // the mean is already at wire precision: serialization is exact
            assert_eq!(back, mean);
        }
    }

    #[test]
    fn stat_job_round_trip_and_f32_result() {
        let l0 = [0.1f32, 0.0, 0.0, 0.1];
        let l1 = [0.3f32, 0.0, 0.0, 0.3];
        for p in [Precision::F32, Precision::Mixed] {
            let f = encode_stat_job(p, 2, 2, 2, &[&l0, &l1]);
            let job = decode_stat_job(&f).unwrap();
            assert_eq!((job.item, job.rows, job.cols), (2, 2, 2));
            assert_eq!(job.lanes[0][0].to_bits(), wire_quantize(p, 0.1).to_bits());
            // owner-side mean is f32 — result serialization must be exact
            let mean = [0.12345678f32, 0.0, 0.0, 0.2];
            let rf = encode_stat_result(2, 2, 2, &mean);
            assert_eq!(rf.flags, 0, "stat results always travel f32");
            let (item, r, c, back) = decode_stat_result(&rf).unwrap();
            assert_eq!((item, r, c), (2, 2, 2));
            for (a, b) in mean.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn reduction_payloads_reject_length_lies() {
        let f = encode_grad_job(Precision::F32, 0, &[&[1.0, 2.0]]);
        let mut lie = f.clone();
        lie.payload[4..8].copy_from_slice(&3u32.to_le_bytes()); // claim 3 lanes
        assert!(decode_grad_job(&lie).is_err());
        let mut zero = f.clone();
        zero.payload[4..8].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_grad_job(&zero).is_err());
        let s = encode_stat_job(Precision::F32, 0, 2, 2, &[&[1.0; 4]]);
        let mut lie = s.clone();
        lie.payload[4..8].copy_from_slice(&u32::MAX.to_le_bytes()); // rows lie
        assert!(decode_stat_job(&lie).is_err(), "saturating mul must not wrap");
    }

    #[test]
    fn split_segments_is_balanced_and_total() {
        assert_eq!(split_segments(10, 3), vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(split_segments(2, 4), vec![(0, 1), (1, 1)]);
        assert_eq!(split_segments(0, 3), Vec::<(usize, usize)>::new());
        for (elems, parts) in [(1usize, 1usize), (7, 2), (100, 7), (5, 5), (3, 8)] {
            let segs = split_segments(elems, parts);
            assert!(segs.len() <= parts);
            let mut at = 0;
            for &(start, len) in &segs {
                assert_eq!(start, at);
                assert!(len > 0);
                at += len;
            }
            assert_eq!(at, elems);
        }
    }

    /// Pinned vectors shared with `python/tools/ring_sim.py`
    /// (`check_proc_frame_bytes`) — the two accountings must agree.
    #[test]
    fn closed_form_byte_vectors_pinned() {
        // 10 elems over 3 workers, 4 lanes, f32 wire:
        // segs (4,3,3); tx = Σ 16+16+4·len·4 = 3·32 + 16·10·4/… = pinned
        let segs: Vec<usize> = split_segments(10, 3).iter().map(|s| s.1).collect();
        assert_eq!(grad_round_tx_bytes(&segs, 4, 4), 96 + 160);
        assert_eq!(grad_round_rx_bytes(&segs, 4), 72 + 40);
        // f16 wire halves only the element payload
        assert_eq!(grad_round_tx_bytes(&segs, 4, 2), 96 + 80);
        assert_eq!(grad_round_rx_bytes(&segs, 2), 72 + 20);
        // one 8×8 statistic over 2 lanes
        assert_eq!(stat_item_tx_bytes(8, 8, 2, 4), 32 + 512);
        assert_eq!(stat_item_tx_bytes(8, 8, 2, 2), 32 + 256);
        assert_eq!(stat_item_rx_bytes(8, 8), 32 + 256);
        // byte-level frame pin: hello(42) encodes to exactly these bytes
        let bytes = encode_hello(42).encode();
        assert_eq!(bytes.len(), 24);
        assert_eq!(&bytes[..8], b"SPWF\x01\x00\x01\x00");
        assert_eq!(checksum(b""), 0x811c_9dc5);
        assert_eq!(checksum(b"SPWF"), 0x5ebb_61ef);
    }
}
