//! Collective communication substrate for the simulated multi-GPU runtime.
//!
//! The paper's decentralized design (§5) replaces the parameter server
//! with MPI/NCCL collectives: `ReduceScatterV` moves per-layer statistics
//! from data-parallel workers to their model-parallel owner, `AllGatherV`
//! broadcasts updated weights back, and gradients use AllReduce
//! (= ReduceScatter + AllGather).
//!
//! Workers here are simulated processes sharing one address space, so the
//! *reduction math is real* (buffers are actually combined, bit-for-bit
//! what NCCL would produce) while the *wire time* is modeled: every
//! operation logs the per-GPU bytes it would move (symmetry-aware packed
//! sizes for the statistics, §5.2) and the α-β cost model in
//! [`cost`] converts byte/latency counts into cluster step times.

//! The multi-process transport (`dist::ProcComm`) speaks the framed
//! [`wire`] protocol over Unix-domain sockets: same `Collective` trait,
//! same byte accounting, but payloads are *actually serialized* (f32 or
//! real f16 bytes) rather than shared in memory.

pub mod comm;
pub mod cost;
pub mod wire;

pub use comm::{Collective, CommStats, Precision, SimComm};
pub use cost::{ClusterModel, CollectiveKind};
