//! Collective communication substrate for the simulated multi-GPU runtime.
//!
//! The paper's decentralized design (§5) replaces the parameter server
//! with MPI/NCCL collectives: `ReduceScatterV` moves per-layer statistics
//! from data-parallel workers to their model-parallel owner, `AllGatherV`
//! broadcasts updated weights back, and gradients use AllReduce
//! (= ReduceScatter + AllGather).
//!
//! Workers here are simulated processes sharing one address space, so the
//! *reduction math is real* (buffers are actually combined, bit-for-bit
//! what NCCL would produce) while the *wire time* is modeled: every
//! operation logs the per-GPU bytes it would move (symmetry-aware packed
//! sizes for the statistics, §5.2) and the α-β cost model in
//! [`cost`] converts byte/latency counts into cluster step times.

pub mod comm;
pub mod cost;

pub use comm::{Collective, CommStats, Precision, SimComm};
pub use cost::{ClusterModel, CollectiveKind};
