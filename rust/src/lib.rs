//! SP-NGD: Scalable and Practical Natural Gradient Descent.
//!
//! Reproduction of Osawa et al., "Scalable and Practical Natural Gradient
//! for Large-Scale Deep Learning" (2020) as a three-layer stack:
//!
//! - **L3 (this crate)** — the distributed coordinator: hybrid data/model
//!   parallel SP-NGD step (Stages 1-5), adaptive stale-statistics scheduler,
//!   collectives, optimizer schedules, data pipeline and cluster simulator.
//! - **L2 (python/compile/model.py)** — JAX model fwd/bwd with K-FAC factor
//!   capture, AOT-lowered to HLO text under `artifacts/`.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for factor
//!   construction, Newton-Schulz inversion and preconditioning.
//!
//! Python never runs on the training path. The coordinator talks to an
//! execution backend through [`runtime::Executor`]:
//!
//! - the default **native CPU backend** (`runtime::native`) implements
//!   the full L1/L2 contract in pure rust — hermetic builds, no
//!   artifacts or XLA toolchain required;
//! - with the `pjrt` cargo feature, `runtime::engine` loads the AOT HLO
//!   artifacts through the PJRT C API (`xla` crate) instead.

pub mod ckpt;
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod harness;
pub mod kfac;
pub mod metrics;
pub mod optim;
pub mod linalg;
pub mod runtime;
pub mod serve;
pub mod simulator;
pub mod util;
