//! Unit-wise BatchNorm natural gradient (§4.2).
//!
//! The unit-wise Fisher keeps only the per-channel 2×2 (γ_c, β_c) blocks
//! (Eq. 15-16) and inverts them in closed form (Eq. 17) — reducing the
//! elements from 4c² to 4c. This math is deliberately host-side rust: the
//! paper's point is that unitBN makes the BN statistics negligible, and
//! at (C, 2, 2) scale the matrix work is a handful of flops per channel.

use crate::linalg::solve::inv2x2;
use crate::linalg::Mat;

/// Per-channel 2×2 Fisher blocks, stored flat: [f11, f12, f22] per channel.
#[derive(Clone, Debug, PartialEq)]
pub struct BnFisher {
    pub channels: usize,
    /// (f11, f12, f22) per channel — symmetric, f21 == f12
    pub blocks: Vec<[f32; 3]>,
}

impl BnFisher {
    /// Build from per-sample gradients: g_gamma, g_beta of shape (B, C)
    /// (the step executable's taps, already scaled to per-sample
    /// d log p / dθ). F_c = (1/B) Σ_b [gγ;gβ][gγ;gβ]ᵀ.
    pub fn from_taps(g_gamma: &[f32], g_beta: &[f32], batch: usize, channels: usize) -> Self {
        assert_eq!(g_gamma.len(), batch * channels);
        assert_eq!(g_beta.len(), batch * channels);
        let mut blocks = vec![[0.0f32; 3]; channels];
        for b in 0..batch {
            for c in 0..channels {
                let gg = g_gamma[b * channels + c];
                let gb = g_beta[b * channels + c];
                blocks[c][0] += gg * gg;
                blocks[c][1] += gg * gb;
                blocks[c][2] += gb * gb;
            }
        }
        let inv_b = 1.0 / batch as f32;
        for blk in blocks.iter_mut() {
            blk[0] *= inv_b;
            blk[1] *= inv_b;
            blk[2] *= inv_b;
        }
        BnFisher { channels, blocks }
    }

    /// Mean of several workers' Fishers (the ReduceScatterV for BN stats).
    pub fn mean(parts: &[BnFisher]) -> BnFisher {
        assert!(!parts.is_empty());
        let channels = parts[0].channels;
        let mut blocks = vec![[0.0f32; 3]; channels];
        for p in parts {
            assert_eq!(p.channels, channels);
            for (acc, b) in blocks.iter_mut().zip(p.blocks.iter()) {
                acc[0] += b[0];
                acc[1] += b[1];
                acc[2] += b[2];
            }
        }
        let inv = 1.0 / parts.len() as f32;
        for b in blocks.iter_mut() {
            b[0] *= inv;
            b[1] *= inv;
            b[2] *= inv;
        }
        BnFisher { channels, blocks }
    }

    /// Apply the damped inverse to the (γ, β) gradient pair per channel:
    /// (F_c + λI)⁻¹ [gγ_c; gβ_c]  (the Stage-4 update for BN layers).
    pub fn precondition(
        &self,
        grad_gamma: &[f32],
        grad_beta: &[f32],
        lambda: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(grad_gamma.len(), self.channels);
        assert_eq!(grad_beta.len(), self.channels);
        let mut out_g = vec![0.0f32; self.channels];
        let mut out_b = vec![0.0f32; self.channels];
        for c in 0..self.channels {
            let [f11, f12, f22] = self.blocks[c];
            let inv = inv2x2(f11 + lambda, f12, f12, f22 + lambda)
                // damped block is SPD, determinant > 0; fall back to
                // identity (plain gradient) if numerically degenerate
                .unwrap_or([1.0, 0.0, 0.0, 1.0]);
            out_g[c] = inv[0] * grad_gamma[c] + inv[1] * grad_beta[c];
            out_b[c] = inv[2] * grad_gamma[c] + inv[3] * grad_beta[c];
        }
        (out_g, out_b)
    }

    /// Frobenius-norm view for the stale-statistics similarity metric.
    pub fn as_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.channels, 3);
        for (c, b) in self.blocks.iter().enumerate() {
            m.data[c * 3] = b[0];
            m.data[c * 3 + 1] = b[1];
            m.data[c * 3 + 2] = b[2];
        }
        m
    }

    /// Element count communicated per worker (4c of the paper vs 4c²).
    pub fn comm_elems(&self) -> usize {
        3 * self.channels // symmetric 2x2 packed = 3 per channel
    }
}

/// Full (2C × 2C) BN Fisher for the `fullBN` ablation — parameter order
/// (γ₁, β₁, …, γ_C, β_C) as in Eq. 14.
#[derive(Clone, Debug)]
pub struct BnFullFisher {
    pub channels: usize,
    pub fisher: Mat,
}

impl BnFullFisher {
    pub fn from_taps(g_gamma: &[f32], g_beta: &[f32], batch: usize, channels: usize) -> Self {
        let n = 2 * channels;
        let mut fisher = Mat::zeros(n, n);
        for b in 0..batch {
            // interleaved per-sample gradient vector
            let mut v = vec![0.0f32; n];
            for c in 0..channels {
                v[2 * c] = g_gamma[b * channels + c];
                v[2 * c + 1] = g_beta[b * channels + c];
            }
            for i in 0..n {
                if v[i] == 0.0 {
                    continue;
                }
                for j in 0..n {
                    fisher.data[i * n + j] += v[i] * v[j];
                }
            }
        }
        let fisher = fisher.scale(1.0 / batch as f32);
        BnFullFisher { channels, fisher }
    }

    /// Apply a precomputed damped inverse (from the invert executable) to
    /// the interleaved (γ, β) gradient.
    pub fn apply_inverse(
        inv: &Mat,
        grad_gamma: &[f32],
        grad_beta: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let channels = grad_gamma.len();
        let n = 2 * channels;
        assert_eq!(inv.rows, n);
        let mut v = vec![0.0f32; n];
        for c in 0..channels {
            v[2 * c] = grad_gamma[c];
            v[2 * c + 1] = grad_beta[c];
        }
        let mut out = vec![0.0f32; n];
        for i in 0..n {
            let row = &inv.data[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += row[j] * v[j];
            }
            out[i] = acc;
        }
        let mut og = vec![0.0f32; channels];
        let mut ob = vec![0.0f32; channels];
        for c in 0..channels {
            og[c] = out[2 * c];
            ob[c] = out[2 * c + 1];
        }
        (og, ob)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve;
    use crate::util::rng::Rng;

    fn taps(rng: &mut Rng, b: usize, c: usize) -> (Vec<f32>, Vec<f32>) {
        let gg = (0..b * c).map(|_| rng.normal() as f32).collect();
        let gb = (0..b * c).map(|_| rng.normal() as f32).collect();
        (gg, gb)
    }

    #[test]
    fn unit_fisher_matches_manual() {
        let gg = vec![1.0, 2.0, 3.0, 4.0]; // B=2, C=2
        let gb = vec![0.5, 0.0, 1.0, 1.0];
        let f = BnFisher::from_taps(&gg, &gb, 2, 2);
        // channel 0: samples (1, .5), (3, 1): f11=(1+9)/2=5, f12=(0.5+3)/2
        assert!((f.blocks[0][0] - 5.0).abs() < 1e-6);
        assert!((f.blocks[0][1] - 1.75).abs() < 1e-6);
        assert!((f.blocks[0][2] - 0.625).abs() < 1e-6);
    }

    #[test]
    fn unit_blocks_equal_full_diagonal() {
        let mut rng = Rng::new(5);
        let (b, c) = (16, 6);
        let (gg, gb) = taps(&mut rng, b, c);
        let unit = BnFisher::from_taps(&gg, &gb, b, c);
        let full = BnFullFisher::from_taps(&gg, &gb, b, c);
        for ch in 0..c {
            let n = 2 * c;
            assert!((full.fisher.data[(2 * ch) * n + 2 * ch] - unit.blocks[ch][0]).abs() < 1e-5);
            assert!(
                (full.fisher.data[(2 * ch) * n + 2 * ch + 1] - unit.blocks[ch][1]).abs() < 1e-5
            );
            assert!(
                (full.fisher.data[(2 * ch + 1) * n + 2 * ch + 1] - unit.blocks[ch][2]).abs()
                    < 1e-5
            );
        }
    }

    #[test]
    fn precondition_is_true_damped_inverse() {
        let mut rng = Rng::new(7);
        let (b, c) = (32, 4);
        let (gg, gb) = taps(&mut rng, b, c);
        let f = BnFisher::from_taps(&gg, &gb, b, c);
        let lam = 0.05;
        let grad_g: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let grad_b: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let (pg, pb) = f.precondition(&grad_g, &grad_b, lam);
        // verify F_damped @ preconditioned == grad per channel
        for ch in 0..c {
            let [f11, f12, f22] = f.blocks[ch];
            let r1 = (f11 + lam) * pg[ch] + f12 * pb[ch];
            let r2 = f12 * pg[ch] + (f22 + lam) * pb[ch];
            assert!((r1 - grad_g[ch]).abs() < 1e-3, "ch{ch}");
            assert!((r2 - grad_b[ch]).abs() < 1e-3, "ch{ch}");
        }
    }

    #[test]
    fn mean_across_workers() {
        let mut rng = Rng::new(9);
        let (b, c) = (8, 3);
        let parts: Vec<BnFisher> = (0..4)
            .map(|_| {
                let (gg, gb) = taps(&mut rng, b, c);
                BnFisher::from_taps(&gg, &gb, b, c)
            })
            .collect();
        let m = BnFisher::mean(&parts);
        for ch in 0..c {
            let want: f32 = parts.iter().map(|p| p.blocks[ch][0]).sum::<f32>() / 4.0;
            assert!((m.blocks[ch][0] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn full_fisher_apply_matches_gauss_jordan() {
        let mut rng = Rng::new(11);
        let (b, c) = (16, 3);
        let (gg, gb) = taps(&mut rng, b, c);
        let full = BnFullFisher::from_taps(&gg, &gb, b, c);
        let lam = 0.1;
        let mut fd = full.fisher.clone();
        fd.add_diag(lam);
        let inv = solve::gauss_jordan_inverse(&fd).unwrap();
        let grad_g: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let grad_b: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let (og, ob) = BnFullFisher::apply_inverse(&inv, &grad_g, &grad_b);
        // residual check: fd @ out == grad
        let n = 2 * c;
        let mut v = vec![0.0f32; n];
        for ch in 0..c {
            v[2 * ch] = og[ch];
            v[2 * ch + 1] = ob[ch];
        }
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += fd.data[i * n + j] * v[j];
            }
            let want = if i % 2 == 0 { grad_g[i / 2] } else { grad_b[i / 2] };
            assert!((acc - want).abs() < 1e-3);
        }
    }

    #[test]
    fn comm_savings_unit_vs_full() {
        // paper: 4c² -> 4c elements (we pack symmetric: 3c vs c(2c+1))
        let f = BnFisher { channels: 1024, blocks: vec![[0.0; 3]; 1024] };
        let unit_elems = f.comm_elems();
        let full_elems = 1024 * 2 * (1024 * 2 + 1) / 2;
        assert!(unit_elems * 100 < full_elems);
    }
}
