//! Host-side K-FAC state: damping (π split of Eq. 12), unit-wise
//! BatchNorm Fisher (Eqs. 15-17), and per-layer factor bookkeeping.

pub mod bn;
pub mod damping;

pub use bn::{BnFisher, BnFullFisher};
pub use damping::pi_split;
