//! Tikhonov damping with the π split (Eq. 12, Martens & Grosse).
//!
//! (G ⊗ A + λI)⁻¹ ≈ (G + √λ/π I)⁻¹ ⊗ (A + π√λ I)⁻¹ with
//! π = sqrt(avg_eig(A) / avg_eig(G)); avg eigenvalue = trace / dim.

use crate::linalg::Mat;

/// π clamp range — degenerate factors (zero trace early in training or
/// dead units) would otherwise send one side's damping to 0 or ∞.
const PI_MIN: f32 = 1e-1;
const PI_MAX: f32 = 1e1;

/// Compute (damp_a, damp_g) = (π√λ, √λ/π) from factor traces.
pub fn pi_split(a: &Mat, g: &Mat, lambda: f32) -> (f32, f32) {
    let sqrt_l = lambda.max(0.0).sqrt();
    let avg_a = (a.trace() / a.rows as f32).max(0.0);
    let avg_g = (g.trace() / g.rows as f32).max(0.0);
    let pi = if avg_a > 0.0 && avg_g > 0.0 {
        (avg_a / avg_g).sqrt().clamp(PI_MIN, PI_MAX)
    } else {
        1.0
    };
    (pi * sqrt_l, sqrt_l / pi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve;
    use crate::util::rng::Rng;

    #[test]
    fn balanced_factors_give_sqrt_lambda() {
        let a = Mat::eye(4).scale(2.0);
        let g = Mat::eye(8).scale(2.0);
        let (da, dg) = pi_split(&a, &g, 0.04);
        assert!((da - 0.2).abs() < 1e-6);
        assert!((dg - 0.2).abs() < 1e-6);
    }

    #[test]
    fn pi_scales_with_trace_ratio() {
        let a = Mat::eye(4).scale(100.0);
        let g = Mat::eye(4).scale(1.0);
        let (da, dg) = pi_split(&a, &g, 1.0);
        // π = 10: A damped more, G damped less; product preserved = λ
        assert!((da - 10.0).abs() < 1e-4);
        assert!((dg - 0.1).abs() < 1e-5);
        assert!((da * dg - 1.0).abs() < 1e-4);
    }

    #[test]
    fn degenerate_factor_clamped() {
        let a = Mat::zeros(4, 4);
        let g = Mat::eye(4);
        let (da, dg) = pi_split(&a, &g, 0.01);
        assert_eq!(da, 0.1);
        assert_eq!(dg, 0.1);
    }

    #[test]
    fn product_of_dampings_equals_lambda() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let n = 3 + rng.below_usize(8);
            let d: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
            let b = Mat::from_vec(n, n, d);
            let a = b.transpose().matmul(&b);
            let g = Mat::eye(n).scale(0.5 + rng.f32());
            let lambda = 0.001 + rng.f32() * 0.1;
            let (da, dg) = pi_split(&a, &g, lambda);
            assert!((da * dg - lambda).abs() / lambda < 1e-3);
        }
    }

    #[test]
    fn damped_kron_inverse_approximates_true_inverse() {
        // end-to-end check of Eq. 12 on a small Kronecker product:
        // (G ⊗ A + λI)⁻¹ vs (G+√λ/π I)⁻¹ ⊗ (A+π√λ I)⁻¹ should be close
        // when λ is small relative to the factor scales.
        let a = Mat::from_vec(2, 2, vec![2.0, 0.3, 0.3, 1.5]);
        let g = Mat::from_vec(2, 2, vec![1.0, 0.1, 0.1, 0.8]);
        let lambda = 0.01;
        let (da, dg) = pi_split(&a, &g, lambda);
        let mut ad = a.clone();
        ad.add_diag(da);
        let mut gd = g.clone();
        gd.add_diag(dg);
        let ainv = solve::gauss_jordan_inverse(&ad).unwrap();
        let ginv = solve::gauss_jordan_inverse(&gd).unwrap();
        // kron(G,A) + λI, inverted exactly
        let n = 4;
        let mut kron = Mat::zeros(n, n);
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    for l in 0..2 {
                        kron.data[(i * 2 + k) * n + (j * 2 + l)] =
                            g.at(i, j) * a.at(k, l);
                    }
                }
            }
        }
        kron.add_diag(lambda);
        let exact = solve::gauss_jordan_inverse(&kron).unwrap();
        let mut approx = Mat::zeros(n, n);
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    for l in 0..2 {
                        approx.data[(i * 2 + k) * n + (j * 2 + l)] =
                            ginv.at(i, j) * ainv.at(k, l);
                    }
                }
            }
        }
        // loose bound — Eq. 12 is itself an approximation
        let rel = exact.fro_dist(&approx) / exact.fro_norm();
        assert!(rel < 0.2, "rel={rel}");
    }
}
