//! Training metrics: per-step records and run summaries consumed by the
//! examples, the benches, and EXPERIMENTS.md.

use crate::collectives::comm::CommStats;

/// Wall-time breakdown of one coordinator step (seconds).
#[derive(Clone, Debug, Default)]
pub struct StageTimes {
    /// Stage 1+2 compute: fwd/bwd step executable (max over workers)
    pub t_step_exec: f64,
    /// statistics construction (factor executables, max over workers)
    pub t_factors: f64,
    /// Stage 4a: factor inversion (wall time across parallel owners)
    pub t_inverse: f64,
    /// Stage 4b: preconditioning + parameter update
    pub t_update: f64,
    /// whole step
    pub t_total: f64,
}

/// One training step's record.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub epoch: f64,
    pub loss: f32,
    pub train_acc: f32,
    pub lr: f64,
    pub momentum: f64,
    pub times: StageTimes,
    pub comm: CommStats,
    /// statistics refreshed this step / total statistics
    pub refreshed: usize,
    pub total_stats: usize,
}

/// Accumulating run log with summary helpers.
#[derive(Default)]
pub struct RunLog {
    pub records: Vec<StepRecord>,
    /// FNV-1a digest over the little-endian bytes of the parameter
    /// vector after the last recorded step — one hash the equivalence
    /// suites and the resume test compare instead of N tensors. `None`
    /// until a step has run.
    pub final_params_fnv: Option<u32>,
}

impl RunLog {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn mean_step_time(&self, skip_warmup: usize) -> f64 {
        let xs: Vec<f64> = self
            .records
            .iter()
            .skip(skip_warmup)
            .map(|r| r.times.t_total)
            .collect();
        if xs.is_empty() {
            return f64::NAN;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    /// Total statistics communication bytes over the run.
    pub fn total_stats_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.comm.stats_total()).sum()
    }

    /// Fraction of statistic-refreshes actually performed (Table 2's
    /// communication-reduction column ≈ this, weighted by matrix sizes).
    pub fn refresh_fraction(&self) -> f64 {
        let (mut r, mut t) = (0usize, 0usize);
        for rec in &self.records {
            r += rec.refreshed;
            t += rec.total_stats;
        }
        if t == 0 {
            1.0
        } else {
            r as f64 / t as f64
        }
    }

    /// First step at which loss drops below `target` (None if never).
    pub fn steps_to_loss(&self, target: f32) -> Option<u64> {
        self.records.iter().find(|r| r.loss <= target).map(|r| r.step)
    }

    pub fn final_loss(&self) -> f32 {
        self.records.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Write a CSV of (step, epoch, loss, acc, lr, t_total, stats_bytes).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use crate::util::log::TableWriter;
        let mut w = TableWriter::create(
            path,
            &["step", "epoch", "loss", "train_acc", "lr", "t_total", "stats_bytes"],
        )?;
        for r in &self.records {
            w.row(&[
                r.step as f64,
                r.epoch,
                r.loss as f64,
                r.train_acc as f64,
                r.lr,
                r.times.t_total,
                r.comm.stats_total() as f64,
            ])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, t: f64, refreshed: usize) -> StepRecord {
        StepRecord {
            step,
            epoch: step as f64 / 10.0,
            loss,
            train_acc: 0.5,
            lr: 0.1,
            momentum: 0.9,
            times: StageTimes { t_total: t, ..Default::default() },
            comm: CommStats { rs_stats_a: 100, rs_stats_g: 50, ..Default::default() },
            refreshed,
            total_stats: 10,
        }
    }

    #[test]
    fn summaries() {
        let mut log = RunLog::default();
        log.push(rec(1, 2.0, 1.0, 10));
        log.push(rec(2, 1.0, 0.5, 5));
        log.push(rec(3, 0.4, 0.5, 0));
        assert_eq!(log.mean_step_time(1), 0.5);
        assert_eq!(log.total_stats_bytes(), 450);
        assert!((log.refresh_fraction() - 0.5).abs() < 1e-9);
        assert_eq!(log.steps_to_loss(1.0), Some(2));
        assert_eq!(log.steps_to_loss(0.1), None);
        assert_eq!(log.final_loss(), 0.4);
    }

    #[test]
    fn summaries_empty_log() {
        let log = RunLog::default();
        assert!(log.mean_step_time(0).is_nan());
        assert_eq!(log.total_stats_bytes(), 0);
        assert_eq!(log.refresh_fraction(), 1.0, "no stats means nothing was skipped");
        assert_eq!(log.steps_to_loss(1.0), None);
        assert!(log.final_loss().is_nan());
    }

    #[test]
    fn summaries_warmup_skip_edges() {
        let mut log = RunLog::default();
        log.push(rec(1, 2.0, 4.0, 10));
        log.push(rec(2, 1.5, 2.0, 10));
        // skip nothing: plain mean; skip everything: NaN, not a panic
        assert_eq!(log.mean_step_time(0), 3.0);
        assert_eq!(log.mean_step_time(1), 2.0);
        assert!(log.mean_step_time(2).is_nan());
        assert!(log.mean_step_time(100).is_nan());
    }

    #[test]
    fn steps_to_loss_reports_first_crossing() {
        let mut log = RunLog::default();
        log.push(rec(1, 0.9, 1.0, 0));
        log.push(rec(2, 2.0, 1.0, 0)); // noisy rebound above target
        log.push(rec(3, 0.5, 1.0, 0));
        assert_eq!(log.steps_to_loss(1.0), Some(1), "first crossing wins, not the last");
        assert_eq!(log.steps_to_loss(0.9), Some(1), "boundary is inclusive");
    }
}
