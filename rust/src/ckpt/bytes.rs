//! Little-endian byte (de)serialization primitives shared by the SPCK
//! container and every state payload stored inside it (optimizer layer
//! state, transform-chain state, stashed batches).
//!
//! The reader is a total function over arbitrary bytes: every accessor
//! bounds-checks and returns a structured [`CkptError`] — no panics, no
//! unbounded allocation (element counts are validated against the bytes
//! actually present before any `Vec` is sized).

use crate::ckpt::format::CkptError;
use crate::linalg::Mat;
use crate::runtime::HostTensor;

/// Append-only little-endian writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// u32 length prefix + raw bytes.
    pub fn blob(&mut self, bytes: &[u8]) {
        self.u32(bytes.len() as u32);
        self.raw(bytes);
    }

    pub fn str_(&mut self, s: &str) {
        self.blob(s.as_bytes());
    }

    /// Count-free f32 run — the caller's framing must fix the length.
    pub fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.f32(x);
        }
    }

    pub fn rng_state(&mut self, s: [u64; 4]) {
        for w in s {
            self.u64(w);
        }
    }

    pub fn mat(&mut self, m: &Mat) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        self.f32s(&m.data);
    }

    pub fn opt_mat(&mut self, m: Option<&Mat>) {
        match m {
            None => self.u8(0),
            Some(m) => {
                self.u8(1);
                self.mat(m);
            }
        }
    }

    pub fn tensor(&mut self, t: &HostTensor) {
        self.u8(t.shape.len() as u8);
        for &d in &t.shape {
            self.u32(d as u32);
        }
        self.f32s(&t.data);
    }

    pub fn opt_tensor(&mut self, t: Option<&HostTensor>) {
        match t {
            None => self.u8(0),
            Some(t) => {
                self.u8(1);
                self.tensor(t);
            }
        }
    }
}

/// Bounds-checked little-endian reader over a payload slice.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        if self.remaining() < n {
            return Err(CkptError::BadPayload("payload shorter than its encoding claims"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Every byte must have been consumed — trailing garbage is corruption.
    pub fn finish(self) -> Result<(), CkptError> {
        if self.remaining() != 0 {
            return Err(CkptError::BadPayload("trailing bytes after payload"));
        }
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8, CkptError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, CkptError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, CkptError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, CkptError> {
        let b = self.take(8)?;
        let mut w = [0u8; 8];
        w.copy_from_slice(b);
        Ok(u64::from_le_bytes(w))
    }

    pub fn f32(&mut self) -> Result<f32, CkptError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, CkptError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn blob(&mut self) -> Result<&'a [u8], CkptError> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn str_(&mut self) -> Result<String, CkptError> {
        let b = self.blob()?;
        String::from_utf8(b.to_vec()).map_err(|_| CkptError::BadPayload("non-utf8 string"))
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>, CkptError> {
        // length check before sizing the Vec: a lying count cannot OOM
        let b = self.take(n.checked_mul(4).ok_or(CkptError::BadPayload("f32 count overflow"))?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn rng_state(&mut self) -> Result<[u64; 4], CkptError> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }

    pub fn mat(&mut self) -> Result<Mat, CkptError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows.checked_mul(cols).ok_or(CkptError::BadPayload("mat dims overflow"))?;
        Ok(Mat::from_vec(rows, cols, self.f32s(n)?))
    }

    pub fn opt_mat(&mut self) -> Result<Option<Mat>, CkptError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.mat()?)),
            _ => Err(CkptError::BadPayload("bad option flag")),
        }
    }

    pub fn tensor(&mut self) -> Result<HostTensor, CkptError> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim.min(8));
        let mut n = 1usize;
        for _ in 0..ndim {
            let d = self.u32()? as usize;
            n = n.checked_mul(d).ok_or(CkptError::BadPayload("tensor dims overflow"))?;
            shape.push(d);
        }
        Ok(HostTensor::new(shape, self.f32s(n)?))
    }

    pub fn opt_tensor(&mut self) -> Result<Option<HostTensor>, CkptError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.tensor()?)),
            _ => Err(CkptError::BadPayload("bad option flag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(-0.0);
        w.f64(std::f64::consts::PI);
        w.str_("lane-3");
        w.rng_state([1, 2, 3, u64::MAX]);
        let bytes = w.into_inner();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str_().unwrap(), "lane-3");
        assert_eq!(r.rng_state().unwrap(), [1, 2, 3, u64::MAX]);
        r.finish().unwrap();
    }

    #[test]
    fn tensor_and_mat_roundtrip_bitwise() {
        let t = HostTensor::new(vec![2, 3], vec![1.5, -0.0, f32::MIN_POSITIVE, 4.0, 5.0, 6.0]);
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut w = ByteWriter::new();
        w.opt_tensor(Some(&t));
        w.opt_tensor(None);
        w.opt_mat(Some(&m));
        w.opt_mat(None);
        let bytes = w.into_inner();
        let mut r = ByteReader::new(&bytes);
        let t2 = r.opt_tensor().unwrap().unwrap();
        assert_eq!(t2.shape, t.shape);
        assert_eq!(
            t2.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            t.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(r.opt_tensor().unwrap().is_none());
        let m2 = r.opt_mat().unwrap().unwrap();
        assert_eq!((m2.rows, m2.cols), (2, 2));
        assert_eq!(m2.data, m.data);
        assert!(r.opt_mat().unwrap().is_none());
        r.finish().unwrap();
    }

    #[test]
    fn reader_is_total_over_garbage() {
        // truncated / lying encodings must error, never panic or OOM
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.u32().is_err());
        let mut r = ByteReader::new(&[0xFF, 0xFF, 0xFF, 0xFF]); // blob claiming 4 GiB
        assert!(r.blob().is_err());
        let mut r = ByteReader::new(&[2, 0xFF, 0xFF, 0xFF, 0x7F, 0xFF, 0xFF, 0xFF, 0x7F]);
        assert!(r.tensor().is_err()); // dims product overflows / exceeds bytes
        let mut r = ByteReader::new(&[9]);
        assert!(r.opt_mat().is_err()); // bad option flag
        let r = ByteReader::new(&[0]);
        assert!(r.finish().is_err());
    }
}
