//! The SPCK container: a versioned binary checkpoint in the house wire
//! idiom (`collectives::wire`) — fixed magic + version header, a section
//! table of `(kind, tag, len, fnv1a)` entries, and hard caps enforced
//! before any allocation. Parsing is a total function: every malformed
//! input maps to a structured [`CkptError`], never a panic or an OOM.
//!
//! ```text
//! header (16 bytes):  "SPCK" | version u16 | flags u16 | nsect u32 | reserved u32
//! section (12 + len): kind u16 | tag u16 | len u32 | fnv1a u32 | payload
//! ```
//!
//! Section kinds are part of the format contract; `tag` disambiguates
//! repeated kinds (parameter index, layer index, lane index). Unknown
//! kinds are rejected — a checkpoint is a closed artifact, not an
//! extensible stream.

use crate::collectives::wire::checksum;

/// File magic.
pub const MAGIC: [u8; 4] = *b"SPCK";
/// Format version written by this build.
pub const VERSION: u16 = 1;
/// Fixed file header size in bytes.
pub const HEADER_BYTES: usize = 16;
/// Per-section header size in bytes.
pub const SECTION_HEADER_BYTES: usize = 12;
/// Hard cap on one section's payload, enforced before any allocation.
pub const MAX_SECTION: u32 = 64 * 1024 * 1024;
/// Hard cap on the section count (a lying header cannot drive a loop).
pub const MAX_SECTIONS: u32 = 65_536;

/// Section kinds. Values are part of the on-disk contract.
pub const SEC_META: u16 = 1;
/// Model parameter; tag = parameter index.
pub const SEC_PARAM: u16 = 2;
/// Update-rule momentum (velocity); tag = parameter index.
pub const SEC_VELOCITY: u16 = 3;
/// BatchNorm running (mean ‖ var); tag = bn index in `bn_order`.
pub const SEC_BN: u16 = 4;
/// Opaque `Preconditioner::state_save` payload; tag = kfac layer index.
pub const SEC_LAYER: u16 = 5;
/// Loader cursor (data + validation RNG streams, stash arity).
pub const SEC_LOADER: u16 = 6;
/// Per-lane transform-chain state; tag = lane index.
pub const SEC_CHAIN: u16 = 7;
/// In-flight prefetched batch; tag = lane index.
pub const SEC_STASH: u16 = 8;

fn known_kind(kind: u16) -> bool {
    (SEC_META..=SEC_STASH).contains(&kind)
}

/// Structured parse failure — every variant names what broke.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CkptError {
    /// file ends before the bytes its own headers promise
    Truncated,
    BadMagic([u8; 4]),
    BadVersion(u16),
    BadKind(u16),
    TooManySections(u32),
    Oversized { kind: u16, len: u32 },
    BadChecksum { kind: u16, want: u32, got: u32 },
    Duplicate { kind: u16, tag: u16 },
    BadPayload(&'static str),
    Missing(&'static str),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Truncated => write!(f, "checkpoint truncated"),
            CkptError::BadMagic(m) => write!(f, "bad magic {m:02x?} (want SPCK)"),
            CkptError::BadVersion(v) => write!(f, "unsupported version {v} (want {VERSION})"),
            CkptError::BadKind(k) => write!(f, "unknown section kind {k}"),
            CkptError::TooManySections(n) => {
                write!(f, "section count {n} exceeds cap {MAX_SECTIONS}")
            }
            CkptError::Oversized { kind, len } => {
                write!(f, "section kind {kind} length {len} exceeds cap {MAX_SECTION}")
            }
            CkptError::BadChecksum { kind, want, got } => {
                write!(f, "section kind {kind} checksum mismatch (want {want:08x}, got {got:08x})")
            }
            CkptError::Duplicate { kind, tag } => {
                write!(f, "duplicate section (kind {kind}, tag {tag})")
            }
            CkptError::BadPayload(why) => write!(f, "bad payload: {why}"),
            CkptError::Missing(what) => write!(f, "checkpoint missing {what}"),
        }
    }
}

impl std::error::Error for CkptError {}

/// One decoded section.
#[derive(Clone, Debug)]
pub struct Section {
    pub kind: u16,
    pub tag: u16,
    pub payload: Vec<u8>,
}

/// A decoded checkpoint: the flat section list plus a uniqueness
/// guarantee on `(kind, tag)`.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub sections: Vec<Section>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Checkpoint { sections: Vec::new() }
    }

    pub fn push(&mut self, kind: u16, tag: u16, payload: Vec<u8>) {
        debug_assert!(payload.len() as u32 <= MAX_SECTION);
        self.sections.push(Section { kind, tag, payload });
    }

    /// The unique section of `(kind, tag)`, if present.
    pub fn section(&self, kind: u16, tag: u16) -> Option<&[u8]> {
        self.sections
            .iter()
            .find(|s| s.kind == kind && s.tag == tag)
            .map(|s| s.payload.as_slice())
    }

    /// Required-section accessor with a structured error.
    pub fn require(&self, kind: u16, tag: u16, what: &'static str) -> Result<&[u8], CkptError> {
        self.section(kind, tag).ok_or(CkptError::Missing(what))
    }

    /// All sections of one kind, in tag order.
    pub fn sections_of(&self, kind: u16) -> Vec<(u16, &[u8])> {
        let mut out: Vec<(u16, &[u8])> = self
            .sections
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| (s.tag, s.payload.as_slice()))
            .collect();
        out.sort_by_key(|(tag, _)| *tag);
        out
    }

    /// Serialize to the on-disk byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let body: usize = self
            .sections
            .iter()
            .map(|s| SECTION_HEADER_BYTES + s.payload.len())
            .sum();
        let mut out = Vec::with_capacity(HEADER_BYTES + body);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes()); // reserved
        for s in &self.sections {
            out.extend_from_slice(&s.kind.to_le_bytes());
            out.extend_from_slice(&s.tag.to_le_bytes());
            out.extend_from_slice(&(s.payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&checksum(&s.payload).to_le_bytes());
            out.extend_from_slice(&s.payload);
        }
        out
    }

    /// Parse a complete checkpoint file. Total: any byte soup maps to a
    /// structured error. Caps are enforced from headers alone, before
    /// any payload allocation.
    pub fn parse(bytes: &[u8]) -> Result<Checkpoint, CkptError> {
        if bytes.len() < HEADER_BYTES {
            return Err(CkptError::Truncated);
        }
        if bytes[..4] != MAGIC {
            return Err(CkptError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
        }
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        if version != VERSION {
            return Err(CkptError::BadVersion(version));
        }
        let nsect = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        if nsect > MAX_SECTIONS {
            return Err(CkptError::TooManySections(nsect));
        }
        let mut pos = HEADER_BYTES;
        let mut ck = Checkpoint::new();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..nsect {
            if bytes.len() - pos < SECTION_HEADER_BYTES {
                return Err(CkptError::Truncated);
            }
            let kind = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]);
            let tag = u16::from_le_bytes([bytes[pos + 2], bytes[pos + 3]]);
            let len =
                u32::from_le_bytes([bytes[pos + 4], bytes[pos + 5], bytes[pos + 6], bytes[pos + 7]]);
            let want = u32::from_le_bytes([
                bytes[pos + 8],
                bytes[pos + 9],
                bytes[pos + 10],
                bytes[pos + 11],
            ]);
            pos += SECTION_HEADER_BYTES;
            if !known_kind(kind) {
                return Err(CkptError::BadKind(kind));
            }
            if len > MAX_SECTION {
                return Err(CkptError::Oversized { kind, len });
            }
            let len = len as usize;
            if bytes.len() - pos < len {
                return Err(CkptError::Truncated);
            }
            let payload = &bytes[pos..pos + len];
            pos += len;
            let got = checksum(payload);
            if got != want {
                return Err(CkptError::BadChecksum { kind, want, got });
            }
            if !seen.insert((kind, tag)) {
                return Err(CkptError::Duplicate { kind, tag });
            }
            ck.push(kind, tag, payload.to_vec());
        }
        if pos != bytes.len() {
            return Err(CkptError::BadPayload("trailing bytes after last section"));
        }
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.push(SEC_META, 0, b"meta-bytes".to_vec());
        ck.push(SEC_PARAM, 0, vec![1, 2, 3, 4]);
        ck.push(SEC_PARAM, 1, vec![]);
        ck.push(SEC_LAYER, 3, vec![0xAB; 33]);
        ck
    }

    #[test]
    fn encode_parse_roundtrip() {
        let ck = sample();
        let bytes = ck.encode();
        let back = Checkpoint::parse(&bytes).unwrap();
        assert_eq!(back.sections.len(), 4);
        assert_eq!(back.section(SEC_META, 0).unwrap(), b"meta-bytes");
        assert_eq!(back.section(SEC_PARAM, 1).unwrap(), b"");
        assert_eq!(back.section(SEC_LAYER, 3).unwrap(), &[0xAB; 33][..]);
        assert!(back.section(SEC_PARAM, 2).is_none());
        let params = back.sections_of(SEC_PARAM);
        assert_eq!(params.iter().map(|(t, _)| *t).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn header_rejections() {
        assert_eq!(Checkpoint::parse(&[]), Err(CkptError::Truncated));
        assert_eq!(Checkpoint::parse(&[0; 8]), Err(CkptError::Truncated));
        let mut b = sample().encode();
        b[0] = b'X';
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::BadMagic(_))));
        let mut b = sample().encode();
        b[4] = 0xFE;
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::BadVersion(_))));
        // a lying section count larger than the cap is rejected from the
        // header alone — no allocation, no loop
        let mut b = sample().encode();
        b[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::TooManySections(_))));
    }

    #[test]
    fn section_rejections() {
        // oversized length rejected before any payload read
        let mut b = sample().encode();
        b[HEADER_BYTES + 4..HEADER_BYTES + 8]
            .copy_from_slice(&(MAX_SECTION + 1).to_le_bytes());
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::Oversized { .. })));
        // corrupt payload trips the checksum, naming the section
        let mut b = sample().encode();
        let off = HEADER_BYTES + SECTION_HEADER_BYTES; // first payload byte
        b[off] ^= 0x40;
        assert!(matches!(
            Checkpoint::parse(&b),
            Err(CkptError::BadChecksum { kind: SEC_META, .. })
        ));
        // truncation anywhere inside a section is Truncated
        let b = sample().encode();
        for cut in [HEADER_BYTES + 3, HEADER_BYTES + SECTION_HEADER_BYTES + 2, b.len() - 1] {
            assert_eq!(Checkpoint::parse(&b[..cut]), Err(CkptError::Truncated), "cut={cut}");
        }
        // trailing garbage after the advertised sections is rejected
        let mut b = sample().encode();
        b.push(0);
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::BadPayload(_))));
        // unknown kinds are a closed-set violation
        let mut b = sample().encode();
        b[HEADER_BYTES..HEADER_BYTES + 2].copy_from_slice(&999u16.to_le_bytes());
        assert!(matches!(Checkpoint::parse(&b), Err(CkptError::BadKind(999))));
    }

    #[test]
    fn duplicate_sections_rejected() {
        let mut ck = Checkpoint::new();
        ck.push(SEC_PARAM, 7, vec![1]);
        ck.push(SEC_PARAM, 7, vec![2]);
        assert!(matches!(
            Checkpoint::parse(&ck.encode()),
            Err(CkptError::Duplicate { kind: SEC_PARAM, tag: 7 })
        ));
    }
}
