//! Checkpoint/restore: the on-disk artifact closing the train→inference
//! loop.
//!
//! A checkpoint captures everything a [`crate::coordinator::Trainer`]
//! needs to resume **bit-identically to an uninterrupted run**: model
//! parameters, update-rule momentum, BatchNorm running statistics, each
//! layer's opaque [`crate::optim::Preconditioner`] state (factors,
//! inverses, stale-scheduler history), every RNG stream, and the loader
//! cursor — including an in-flight prefetched batch, so double-buffering
//! stays bitwise-neutral across a save/kill/resume cycle. The schedule
//! and 1mc Fisher seeds are pure functions of the step counter and need
//! no persistence.
//!
//! Layout and parsing live in [`format`] (magic `SPCK`, versioned
//! header, checksummed section table, 64 MiB section cap — the house
//! wire idiom); [`bytes`] holds the shared little-endian payload
//! primitives. This module adds the file lifecycle: atomic tmp+rename
//! writes at round boundaries and latest-checkpoint discovery, which is
//! also what the proc engine's zero-survivor restart consults.

pub mod bytes;
pub mod format;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use bytes::{ByteReader, ByteWriter};
pub use format::{
    Checkpoint, CkptError, Section, MAX_SECTION, SEC_BN, SEC_CHAIN, SEC_LAYER, SEC_LOADER,
    SEC_META, SEC_PARAM, SEC_STASH, SEC_VELOCITY,
};

/// META payload layout version.
pub const META_V: u8 = 1;

/// The decoded META section (`SEC_META`, tag 0) — the run fingerprint
/// every consumer validates before touching state. Shared between the
/// trainer's restore path and `spngd serve`'s weight loader so the two
/// parsers cannot drift.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Meta {
    pub model: String,
    pub opt: String,
    /// 0 = f32 wire, 1 = mixed (f16 wire)
    pub precision: u8,
    pub lanes: u32,
    pub nparams: u32,
    pub nlayers: u32,
    pub nbn: u32,
    pub seed: u64,
    pub step: u64,
    /// [`params_fnv`] over the saved parameters, for end-to-end
    /// integrity beyond the per-section checksums
    pub params_fnv: u32,
}

impl Meta {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u8(META_V);
        w.str_(&self.model);
        w.str_(&self.opt);
        w.u8(self.precision);
        w.u32(self.lanes);
        w.u32(self.nparams);
        w.u32(self.nlayers);
        w.u32(self.nbn);
        w.u64(self.seed);
        w.u64(self.step);
        w.u32(self.params_fnv);
        w.into_inner()
    }

    pub fn parse(bytes: &[u8]) -> Result<Meta, CkptError> {
        let mut r = ByteReader::new(bytes);
        if r.u8()? != META_V {
            return Err(CkptError::BadPayload("unsupported META version"));
        }
        // struct-literal fields evaluate in source order — keep it equal
        // to the encode order above
        let m = Meta {
            model: r.str_()?,
            opt: r.str_()?,
            precision: r.u8()?,
            lanes: r.u32()?,
            nparams: r.u32()?,
            nlayers: r.u32()?,
            nbn: r.u32()?,
            seed: r.u64()?,
            step: r.u64()?,
            params_fnv: r.u32()?,
        };
        r.finish()?;
        Ok(m)
    }

    /// Decode a checkpoint's META section.
    pub fn of(ck: &Checkpoint) -> Result<Meta, CkptError> {
        Meta::parse(ck.require(SEC_META, 0, "meta section")?)
    }
}

/// FNV-1a (the same function as `wire::checksum`) over the little-endian
/// bytes of every tensor in order — streamed, no byte-vector
/// materialization. The one hash equivalence suites, the resume test and
/// `spngd serve` compare instead of N tensors.
pub fn params_fnv(tensors: &[crate::runtime::HostTensor]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for t in tensors {
        for v in &t.data {
            for b in v.to_le_bytes() {
                h ^= b as u32;
                h = h.wrapping_mul(0x0100_0193);
            }
        }
    }
    h
}

/// File name for the checkpoint taken at `step` — zero-padded so
/// lexicographic order is step order.
pub fn step_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("ckpt-{step:012}.spck"))
}

/// Write atomically: serialize to `<path>.tmp`, fsync, rename into
/// place. A crash mid-write leaves the previous checkpoint intact and
/// never a half-written `.spck`.
pub fn write_atomic(path: &Path, ck: &Checkpoint) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    }
    let tmp = path.with_extension("tmp");
    let bytes = ck.encode();
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes).with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().ok(); // best-effort durability; rename is the atomicity barrier
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Read and parse one checkpoint file.
pub fn read_file(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    Checkpoint::parse(&bytes)
        .with_context(|| format!("parsing checkpoint {}", path.display()))
}

/// The newest checkpoint in `dir` (highest step encoded in the file
/// name), or `None` when the directory is empty or absent. Stray files
/// and in-progress `.tmp` writes are ignored.
pub fn latest(dir: &Path) -> Result<Option<PathBuf>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in entries {
        let path = entry?.path();
        let Some(step) = parse_step(&path) else { continue };
        if best.as_ref().map(|(s, _)| step > *s).unwrap_or(true) {
            best = Some((step, path));
        }
    }
    Ok(best.map(|(_, p)| p))
}

fn parse_step(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    let digits = name.strip_prefix("ckpt-")?.strip_suffix(".spck")?;
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_roundtrip_and_rejections() {
        let m = Meta {
            model: "convnet".into(),
            opt: "spngd".into(),
            precision: 1,
            lanes: 8,
            nparams: 22,
            nlayers: 11,
            nbn: 5,
            seed: 42,
            step: 1_000_000,
            params_fnv: 0xDEAD_BEEF,
        };
        let bytes = m.encode();
        assert_eq!(Meta::parse(&bytes).unwrap(), m);
        // wrong version byte
        let mut bad = bytes.clone();
        bad[0] = 99;
        assert!(Meta::parse(&bad).is_err());
        // truncation anywhere must error, never panic
        for cut in 0..bytes.len() {
            assert!(Meta::parse(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(Meta::parse(&long).is_err());
    }

    #[test]
    fn params_fnv_matches_wire_checksum() {
        use crate::runtime::HostTensor;
        let ts = vec![
            HostTensor::new(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]),
            HostTensor::new(vec![3], vec![f32::MIN_POSITIVE, 7.0, -0.0]),
        ];
        let mut flat = Vec::new();
        for t in &ts {
            for v in &t.data {
                flat.extend_from_slice(&v.to_le_bytes());
            }
        }
        assert_eq!(params_fnv(&ts), crate::collectives::wire::checksum(&flat));
    }

    #[test]
    fn atomic_write_and_latest_discovery() {
        let dir = std::env::temp_dir().join(format!("spngd_ckpt_io_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest(&dir).unwrap().is_none());

        let mut ck = Checkpoint::new();
        ck.push(SEC_META, 0, b"m".to_vec());
        for step in [3u64, 12, 7] {
            write_atomic(&step_path(&dir, step), &ck).unwrap();
        }
        // stray files and half-written tmps must not confuse discovery
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        std::fs::write(dir.join("ckpt-000000000099.tmp"), b"partial").unwrap();

        let newest = latest(&dir).unwrap().unwrap();
        assert_eq!(newest, step_path(&dir, 12));
        let back = read_file(&newest).unwrap();
        assert_eq!(back.section(SEC_META, 0).unwrap(), b"m");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
