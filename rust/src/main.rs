//! spngd — SP-NGD leader CLI.
//!
//! Subcommands:
//!   info      print the manifest summary
//!   train     train a registered data source (--data) with a registered
//!             optimizer (--optim spngd | sgd | lars); `--ckpt-dir` +
//!             `--ckpt-every` write SPCK checkpoints, `--resume` picks
//!             the run back up bit-identically
//!   serve     load an SPCK checkpoint and answer `/v1/predict` over
//!             HTTP with dynamic micro-batching
//!   simulate  sweep the cluster cost model over GPU counts (Fig. 5)
//!   worker    multi-process reducer body: connect to a coordinator
//!             socket and serve reduction jobs (spawned by `train
//!             --proc`; rarely invoked by hand)
//!
//! Every subcommand takes `--backend native|pjrt`. The default native
//! backend is self-contained; `--backend pjrt` additionally needs the
//! `pjrt` cargo feature and `make artifacts`.

use std::sync::Arc;

use anyhow::{bail, Result};

use spngd::collectives::comm::Precision;
use spngd::collectives::cost::ClusterModel;
use spngd::coordinator::{DistMode, Trainer, TrainerBuilder};
use spngd::data::{self, AugmentCfg};
use spngd::dist::{FaultPlan, ProcCfg};
use spngd::optim::{self, BnMode, Fisher, HyperParams, Preconditioner, Schedule, SpNgd};
use spngd::runtime::{native, Executor, Manifest};
use spngd::serve::{Predictor, ServeCfg, Server};
use spngd::simulator;
use spngd::util::cli::Args;
use spngd::util::obs;
use spngd::util::stats::{fmt_bytes, fmt_duration};

fn main() {
    spngd::util::log::init_from_env();
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(),
        "serve" => cmd_serve(),
        "simulate" => cmd_simulate(),
        "worker" => cmd_worker(),
        _ => {
            eprintln!(
                "usage: spngd <info|train|serve|simulate|worker> [options]\n\
                 run `spngd <cmd> --help` for per-command options"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load(backend: &str, artifacts: &str) -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    match backend {
        "native" => spngd::harness::load_runtime_native(),
        "pjrt" => spngd::harness::load_runtime_pjrt_at(std::path::Path::new(artifacts)),
        other => bail!("unknown backend '{other}' (expected native | pjrt)"),
    }
}

fn cmd_info() -> Result<()> {
    let parsed = Args::new("spngd info", "print the manifest summary")
        .opt("backend", "native", "execution backend: native | pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .parse_env(2)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let (manifest, engine) = load(parsed.get("backend"), parsed.get("artifacts"))?;
    println!("platform: {}", engine.platform());
    println!("executables: {}", manifest.executables.len());
    for (name, m) in &manifest.models {
        println!(
            "model {name}: input {:?}, {} classes, batch/GPU {}, {} params ({} tensors), {} K-FAC layers",
            m.input_shape,
            m.num_classes,
            m.batch,
            m.total_param_count(),
            m.params.len(),
            m.kfac_layers.len()
        );
        let (conv, fc, bn) = m.kfac_layers.iter().fold((0, 0, 0), |(c, f, b), l| {
            match l.kind.as_str() {
                "conv" => (c + 1, f, b),
                "fc" => (c, f + 1, b),
                _ => (c, f, b + 1),
            }
        });
        println!("  layer mix: {conv} conv, {fc} fc, {bn} bn");
    }
    println!("optimizers: {}", optim::OPTIMIZER_NAMES.join(" | "));
    println!("data sources: {}", data::DATA_NAMES.join(" | "));
    Ok(())
}

/// Resolve `--optim` through the registry; SP-NGD additionally picks up
/// the NGD-specific flags (--fisher/--bn/--stale*/--lambda). Unknown
/// names are a hard error listing the valid choices.
fn optimizer_from_args(
    parsed: &spngd::util::cli::Parsed,
    lambda: f32,
) -> Result<Arc<dyn Preconditioner>> {
    match parsed.get("optim") {
        "spngd" => Ok(Arc::new(SpNgd {
            fisher: match parsed.get("fisher") {
                "1mc" => Fisher::OneMc,
                _ => Fisher::Emp,
            },
            bn_mode: match parsed.get("bn") {
                "full" => BnMode::Full,
                _ => BnMode::Unit,
            },
            stale: parsed.get_bool("stale"),
            stale_alpha: parsed.get_f64("stale-alpha") as f32,
            lambda,
        })),
        other => optim::by_name(other),
    }
}

/// Resolve the wire precision: `--precision f32|mixed`, with the legacy
/// `--fp16-comm` flag as an alias for `--precision mixed`.
fn precision_from_args(parsed: &spngd::util::cli::Parsed) -> Result<Precision> {
    if parsed.get_bool("fp16-comm") {
        return Ok(Precision::Mixed);
    }
    Precision::parse(parsed.get("precision")).map_err(|e| anyhow::anyhow!("--precision: {e}"))
}

fn trainer_from_args(parsed: &spngd::util::cli::Parsed) -> Result<Trainer> {
    let model = parsed.get("model").to_string();
    if parsed.get("backend") == "native" {
        // registry check first: unknown --model errors listing choices
        native::model::by_name(&model)?;
    }
    let (manifest, engine) = load(parsed.get("backend"), parsed.get("artifacts"))?;
    let m = manifest.model(&model)?;
    let workers = parsed.get_usize("workers");
    let accum = parsed.get_usize("accum");
    let eff_bs = workers * accum * m.batch;
    // the optimizer's own defaults fill any hyperparameter the user
    // didn't pass — adding an optimizer never edits this harness code
    let defaults = optimizer_from_args(parsed, 0.0)?.default_hparams();
    let num_or = |key: &str, dflt: f64| -> f64 {
        match parsed.get(key) {
            "" => dflt,
            s => s.parse().unwrap_or_else(|_| panic!("--{key} expects a number")),
        }
    };
    let hp = if parsed.get_bool("table2-hp") {
        // map the effective batch onto the paper's Table 2 rows: our
        // corpus is ~1/128 the scale of ImageNet, so scale BS accordingly
        HyperParams::table2(eff_bs * 128)
    } else {
        HyperParams {
            alpha_mixup: parsed.get_f64("mixup"),
            p_decay: parsed.get_f64("p-decay"),
            e_start: parsed.get_f64("e-start"),
            e_end: parsed.get_f64("e-end"),
            eta0: num_or("lr", defaults.eta0),
            m0: num_or("momentum", defaults.m0),
            lambda: num_or("lambda", defaults.lambda as f64) as f32,
        }
    };
    let opt = optimizer_from_args(parsed, hp.lambda)?;
    let dataset_len = parsed.get_usize("dataset");
    let steps_per_epoch = (dataset_len / eff_bs).max(1);
    let augment = if parsed.get_bool("augment") {
        AugmentCfg { alpha_mixup: hp.alpha_mixup, ..AugmentCfg::default() }
    } else {
        AugmentCfg::disabled()
    };
    let mut b = TrainerBuilder::new(&model)
        .runtime(manifest, engine)
        .optimizer(opt)
        .hyperparams(hp)
        .steps_per_epoch(steps_per_epoch)
        .workers(workers)
        .grad_accum(accum)
        .augment(augment)
        .weight_rescale(parsed.get_bool("rescale"))
        .clip_update_ratio(parsed.get_f64("clip") as f32)
        .precision(precision_from_args(parsed)?)
        .dist(if parsed.get_bool("proc") {
            DistMode::Proc
        } else if parsed.get_bool("dist") {
            DistMode::Threaded
        } else {
            DistMode::from_env()
        })
        .seed(parsed.get_u64("seed"))
        .data(parsed.get("data"))
        .dataset_len(dataset_len)
        .data_seed(parsed.get_u64("seed"));
    if !parsed.get("data-path").is_empty() {
        b = b.data_path(parsed.get("data-path"));
    }
    if !parsed.get("fault-plan").is_empty() {
        let mut pc = ProcCfg::from_env();
        pc.fault_plan = FaultPlan::parse(parsed.get("fault-plan"))
            .map_err(|e| anyhow::anyhow!("--fault-plan: {e}"))?;
        b = b.proc_cfg(pc);
    }
    match parsed.get("prefetch") {
        "" => {} // loader default: SPNGD_PREFETCH, else on
        v => b = b.prefetch(!matches!(v, "0" | "off" | "false")),
    }
    b.build()
}

fn train_args() -> Args {
    // help text joins the registries so it can never go stale
    let model_help = format!("model name: {}", native::model::MODEL_NAMES.join(" | "));
    let optim_help = format!("optimizer: {}", optim::OPTIMIZER_NAMES.join(" | "));
    let data_help = format!("data source: {}", data::DATA_NAMES.join(" | "));
    Args::new("spngd train", "train on a registered data source")
        .opt("backend", "native", "execution backend: native | pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("model", "convnet_small", &model_help)
        .opt("optim", "spngd", &optim_help)
        .opt("data", "synth", &data_help)
        .opt("data-path", "", "backing file for disk sources (cifar10)")
        .opt("prefetch", "", "1|0 — batch prefetch (default: SPNGD_PREFETCH, else on)")
        .opt("fisher", "emp", "Fisher estimation: emp | 1mc (spngd only)")
        .opt("bn", "unit", "BatchNorm Fisher: unit | full (spngd only)")
        .flag("stale", "enable the adaptive stale-statistics scheduler (spngd only)")
        .opt("stale-alpha", "0.1", "similarity threshold α")
        .opt("workers", "4", "simulated GPUs")
        .flag("dist", "threaded dist engine: one OS thread per worker (or SPNGD_DIST=threads)")
        .flag("proc", "multi-process dist engine: one spngd worker process per worker (or SPNGD_DIST=proc)")
        .opt("fault-plan", "", "failure injection: kind:step:rank[:ms],... (kill|drop|delay|corrupt|mute)")
        .opt("accum", "1", "gradient accumulation micro-steps")
        .opt("steps", "200", "training steps")
        .opt("dataset", "8192", "synthetic corpus size")
        .opt("lr", "", "initial learning rate η₀ (default: the optimizer's)")
        .opt("momentum", "", "initial momentum m₀ (default: the optimizer's)")
        .opt("lambda", "", "damping λ (default: the optimizer's)")
        .opt("mixup", "0.4", "mixup α (with --augment)")
        .opt("p-decay", "3.5", "polynomial decay exponent")
        .opt("e-start", "1.0", "decay start epoch")
        .opt("e-end", "60.0", "decay end epoch")
        .flag("table2-hp", "use the paper's Table 2 hyperparameters")
        .flag("augment", "enable running mixup + random erasing")
        .flag("rescale", "enable Normalizing Weights (Eq. 24)")
        .opt("precision", "f32", "wire precision for grad/stat collectives: f32 | mixed (§5.2)")
        .flag("fp16-comm", "alias for --precision mixed")
        .opt("clip", "0.3", "trust-ratio update clip (0 = off)")
        .opt("eval-every", "0", "evaluate every N steps (0 = only at end)")
        .opt("csv", "", "write per-step CSV to this path")
        .opt("trace-out", "", "write a Chrome trace-event JSON of the run to this path (or SPNGD_TRACE)")
        .opt("events-out", "", "write the dist-layer JSONL event stream to this path (or SPNGD_EVENTS)")
        .opt("ckpt-dir", "", "directory for SPCK checkpoints (enables --ckpt-every/--resume)")
        .opt("ckpt-every", "0", "checkpoint every N steps (0 = never; requires --ckpt-dir)")
        .flag("resume", "resume from the latest checkpoint in --ckpt-dir (bit-identical)")
        .opt("seed", "7", "RNG seed")
}

fn cmd_train() -> Result<()> {
    let parsed = train_args().parse_env(2).map_err(|u| anyhow::anyhow!("{u}"))?;
    let steps = parsed.get_usize("steps");
    let eval_every = parsed.get_usize("eval-every");
    // flags must win over SPNGD_TRACE/SPNGD_EVENTS, so set them before
    // the trainer's obs::init_from_env runs
    if !parsed.get("trace-out").is_empty() {
        obs::set_trace_path(std::path::Path::new(parsed.get("trace-out")));
    }
    if !parsed.get("events-out").is_empty() {
        obs::set_events_path(std::path::Path::new(parsed.get("events-out")))
            .map_err(|e| anyhow::anyhow!("--events-out: {e}"))?;
    }
    let ckpt_dir = parsed.get("ckpt-dir").to_string();
    let ckpt_every = parsed.get_usize("ckpt-every") as u64;
    if ckpt_every > 0 && ckpt_dir.is_empty() {
        bail!("--ckpt-every requires --ckpt-dir");
    }
    if parsed.get_bool("resume") && ckpt_dir.is_empty() {
        bail!("--resume requires --ckpt-dir");
    }
    // proc runs can restart from the latest checkpoint after a fatal
    let proc_mode = parsed.get_bool("proc")
        || (!parsed.get_bool("dist") && matches!(DistMode::from_env(), DistMode::Proc));
    let mut tr = trainer_from_args(&parsed)?;
    if parsed.get_bool("resume") {
        match tr.resume_latest(std::path::Path::new(&ckpt_dir))? {
            Some(step) => println!("resumed from step {step} ({ckpt_dir})"),
            None => println!("no checkpoint under {ckpt_dir} — starting fresh"),
        }
    }
    println!(
        "training {} with {} (workers={}, accum={}, effective batch={})",
        tr.cfg.model,
        tr.optimizer().name(),
        tr.cfg.workers,
        tr.cfg.grad_accum,
        tr.cfg.effective_batch(32)
    );
    let mut recoveries_left = 2u32;
    while tr.current_step() < steps as u64 {
        let rec = match tr.step() {
            Ok(rec) => rec,
            Err(e) if proc_mode && !ckpt_dir.is_empty() && recoveries_left > 0 => {
                recoveries_left -= 1;
                eprintln!("step failed ({e:#}); restarting workers from the latest checkpoint");
                let step = tr.recover_from_latest(std::path::Path::new(&ckpt_dir))?;
                println!("recovered at step {step}, resuming");
                continue;
            }
            Err(e) => return Err(e),
        };
        let i = rec.step;
        if i <= 3 || i % 20 == 0 {
            println!(
                "step {:4}  loss {:.4}  acc {:.3}  lr {:.4}  {}/step  stats {}  refreshed {}/{}",
                rec.step,
                rec.loss,
                rec.train_acc,
                rec.lr,
                fmt_duration(rec.times.t_total),
                fmt_bytes(rec.comm.stats_total() as f64),
                rec.refreshed,
                rec.total_stats
            );
        }
        if eval_every > 0 && i % eval_every as u64 == 0 {
            let (vl, va) = tr.evaluate(8)?;
            println!("  eval @ {i}: loss {vl:.4} acc {va:.3}");
        }
        if ckpt_every > 0 && i % ckpt_every == 0 {
            let path = tr.save_checkpoint(std::path::Path::new(&ckpt_dir))?;
            println!("checkpoint {}", path.display());
        }
    }
    let (vl, va) = tr.evaluate(16)?;
    println!("final: val loss {vl:.4}, val acc {va:.3}");
    println!(
        "mean step {}  comm reduction {:.1}%  total stats comm {}",
        fmt_duration(tr.log.mean_step_time(3)),
        tr.comm_reduction() * 100.0,
        fmt_bytes(tr.log.total_stats_bytes() as f64)
    );
    let csv = parsed.get("csv");
    if !csv.is_empty() {
        tr.log.write_csv(csv)?;
        println!("wrote {csv}");
    }
    drop(tr); // close the proc transport before flushing telemetry sinks
    if let Some(path) = obs::flush_trace().map_err(|e| anyhow::anyhow!("write trace: {e}"))? {
        println!("wrote trace {}", path.display());
    }
    obs::close_events();
    Ok(())
}

/// Serve an SPCK checkpoint over HTTP: `/healthz`, `/v1/predict` (with
/// dynamic micro-batching), `/v1/stats`. `--ckpt` takes either a
/// checkpoint file or a `--ckpt-dir`-style directory (latest wins).
fn cmd_serve() -> Result<()> {
    let model_help = format!("model name: {}", native::model::MODEL_NAMES.join(" | "));
    let parsed = Args::new("spngd serve", "serve a checkpoint over HTTP")
        .opt("backend", "native", "execution backend: native | pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("model", "convnet_small", &model_help)
        .opt("ckpt", "", "SPCK checkpoint file, or a directory of them (required)")
        .opt("addr", "127.0.0.1:8080", "bind address (port 0 = ephemeral)")
        .opt("max-batch", "0", "micro-batch row cap (0 = the model's static batch)")
        .opt("max-wait-us", "2000", "micro-batch coalescing window (µs)")
        .opt("threads", "4", "connection handler threads")
        .parse_env(2)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let (manifest, engine) = load(parsed.get("backend"), parsed.get("artifacts"))?;
    let given = parsed.get("ckpt");
    if given.is_empty() {
        bail!("serve: --ckpt is required");
    }
    let given = std::path::Path::new(given);
    let path = if given.is_dir() {
        spngd::ckpt::latest(given)?
            .ok_or_else(|| anyhow::anyhow!("no checkpoint under {}", given.display()))?
    } else {
        given.to_path_buf()
    };
    let predictor =
        Predictor::from_checkpoint_file(&manifest, engine, parsed.get("model"), &path)?;
    println!(
        "serving {} @ step {} from {} ({} classes, in_dim {})",
        predictor.model_name(),
        predictor.step(),
        path.display(),
        predictor.classes(),
        predictor.in_dim()
    );
    let server = Server::bind(
        predictor,
        &ServeCfg {
            addr: parsed.get("addr").to_string(),
            max_batch: parsed.get_usize("max-batch"),
            max_wait_us: parsed.get_u64("max-wait-us"),
            threads: parsed.get_usize("threads"),
        },
    )?;
    println!("listening on http://{}", server.addr());
    server.run();
    Ok(())
}

/// The multi-process reducer body. Normally spawned by a `train --proc`
/// coordinator, but invocable by hand against any coordinator socket —
/// useful for attaching a replacement worker to a shrunken run.
fn cmd_worker() -> Result<()> {
    let parsed = Args::new("spngd worker", "serve reduction jobs for a proc coordinator")
        .opt("socket", "", "coordinator unix socket path (required)")
        .parse_env(2)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let socket = parsed.get("socket");
    if socket.is_empty() {
        bail!("worker: --socket is required");
    }
    spngd::dist::worker::run(socket, FaultPlan::from_env())
}

fn cmd_simulate() -> Result<()> {
    let parsed = Args::new("spngd simulate", "Fig. 5 cluster sweep from a measured profile")
        .opt("backend", "native", "execution backend: native | pjrt")
        .opt("artifacts", "artifacts", "artifact directory (pjrt backend)")
        .opt("model", "convnet_small", "model to profile")
        .opt("probe-steps", "4", "steps to measure the profile")
        .opt("gpus", "1,4,16,64,128,256,512,1024", "GPU counts")
        .opt("stale-fraction", "0.08", "assumed stale refresh fraction")
        .opt("precision", "f32", "wire precision for grad/stat collectives: f32 | mixed (§5.2)")
        .flag("fp16-comm", "alias for --precision mixed")
        .parse_env(2)
        .map_err(|u| anyhow::anyhow!("{u}"))?;
    let (manifest, engine) = load(parsed.get("backend"), parsed.get("artifacts"))?;
    let model = parsed.get("model").to_string();
    let hp = HyperParams::table2(32_768);
    let lambda = hp.lambda;
    let mut tr = TrainerBuilder::new(&model)
        .runtime(manifest, engine)
        .optimizer(Arc::new(SpNgd { lambda, ..SpNgd::default() }))
        .schedule(Schedule::new(hp, 100))
        .workers(2)
        .precision(precision_from_args(&parsed)?)
        .dataset_len(4096)
        .data_seed(7)
        .build()?;
    let probe = parsed.get_usize("probe-steps");
    for _ in 0..probe {
        tr.step()?;
    }
    let base = tr.profile();
    let deltas = simulator::TechniqueDeltas {
        t_extra_bwd_1mc: base.t_backward * 0.9,
        t_full_bn_extra: base.t_inverse * 0.4,
        full_bn_extra_bytes: base.stats_bytes * 0.3,
        stale_fraction: parsed.get_f64("stale-fraction"),
    };
    let variants: Vec<simulator::Variant> = simulator::fig5_techniques()
        .iter()
        .map(|&t| simulator::derive(&base, &deltas, t))
        .collect();
    let gpus = parsed.get_usize_list("gpus");
    let cm = ClusterModel::default();
    let rows = simulator::sweep(&variants, &gpus, &cm);
    print!("{:>20}", "technique \\ GPUs");
    for g in &gpus {
        print!("{g:>10}");
    }
    println!();
    for row in rows {
        print!("{:>20}", row.label);
        for (_, t) in row.points {
            print!("{:>10}", format!("{:.1}ms", t * 1e3));
        }
        println!();
    }
    Ok(())
}
