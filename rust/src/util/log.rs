//! Structured logging substrate (replaces `tracing`): leveled, timestamped
//! stderr logging with a global level switch, plus a CSV-ish metrics writer
//! for loss curves / step times consumed by EXPERIMENTS.md.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

impl Level {
    /// Parse a `SPNGD_LOG` spelling.
    pub fn parse(s: &str) -> Result<Level, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "debug" => Ok(Level::Debug),
            "info" => Ok(Level::Info),
            "warn" | "warning" => Ok(Level::Warn),
            "error" => Ok(Level::Error),
            other => Err(format!("unknown log level '{other}' (debug | info | warn | error)")),
        }
    }
}

/// Apply `SPNGD_LOG` to the global level (unset leaves the default Info;
/// invalid values are a hard error, mirroring the other env registries).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SPNGD_LOG") {
        set_level(Level::parse(&v).unwrap_or_else(|e| panic!("SPNGD_LOG: {e}")));
    }
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    let t = now_secs();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:10.3}] {tag} {target}: {msg}");
}

fn start_instant() -> &'static Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now)
}

/// Seconds since process logging start (monotonic).
pub fn now_secs() -> f64 {
    start_instant().elapsed().as_secs_f64()
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
    };
}
#[macro_export]
macro_rules! error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $target, &format!($($arg)*))
    };
}

/// Append-only table writer: header once, then rows; used for loss curves
/// and bench series the experiment docs reference.
pub struct TableWriter {
    file: std::fs::File,
    wrote_header: bool,
    columns: Vec<String>,
}

impl TableWriter {
    pub fn create(path: &str, columns: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(TableWriter {
            file: std::fs::File::create(path)?,
            wrote_header: false,
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.columns.len(), "row arity mismatch");
        if !self.wrote_header {
            writeln!(self.file, "{}", self.columns.join(","))?;
            self.wrote_header = true;
        }
        let cells: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.file, "{}", cells.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("debug").unwrap(), Level::Debug);
        assert_eq!(Level::parse("INFO").unwrap(), Level::Info);
        assert_eq!(Level::parse(" warn ").unwrap(), Level::Warn);
        assert_eq!(Level::parse("warning").unwrap(), Level::Warn);
        assert_eq!(Level::parse("error").unwrap(), Level::Error);
        assert!(Level::parse("trace").is_err());
    }

    #[test]
    fn table_writer_csv() {
        let path = std::env::temp_dir().join("spngd_test_table.csv");
        let p = path.to_str().unwrap();
        {
            let mut w = TableWriter::create(p, &["step", "loss"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[2.0, 2.0]).unwrap();
        }
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.starts_with("step,loss\n"));
        assert!(s.contains("1,2.5"));
        let _ = std::fs::remove_file(p);
    }
}
