//! obs — span tracing + structured telemetry substrate (≈`tracing`+perfetto).
//!
//! Three independent facilities behind one module:
//!
//! 1. **Span recorder**: per-thread lock-free ring buffers of
//!    `{name, category, tid, t_start_ns, t_end_ns, args}` spans plus
//!    instant events and counters, registered process-wide and drained
//!    into Chrome trace-event JSON (`chrome://tracing` / Perfetto) via
//!    [`crate::util::json`]. When tracing is disabled the whole hot path
//!    is a branch on one relaxed atomic — [`span`] neither reads the
//!    clock nor touches thread-local state (cost asserted by the
//!    `native_perf` bench and gated by `bench_gate.py`).
//!
//! 2. **Overlap accountant** ([`overlap`]): post-processes a drained
//!    trace into the numbers behind Alg. 3's claim that K-FAC
//!    communication hides behind compute — comm/compute span unions,
//!    the hidden fraction |comm ∩ compute| / |comm|, per-name span sums
//!    and a critical-path estimate |comm ∪ compute|. Exported as the
//!    `obs` dimension of `BENCH_native.json` (schema/5).
//!
//! 3. **JSONL event stream**: machine-readable dist-layer telemetry
//!    (`spngd-events/2`, one JSON object per line) behind
//!    `--events-out` / `SPNGD_EVENTS` — membership transitions, deaths,
//!    respawns, fault injections, poison. [`parse_line`] is
//!    parse-or-skip: any malformed line yields `None`, never a panic,
//!    so log processors survive truncation and interleaved garbage.
//!
//! Tracing and the event stream are process-global switches: recording
//! never perturbs the training computation itself (spans only read the
//! monotonic clock), which the `tracing_is_bitwise_neutral` tests pin
//! across all three dist engines.

use std::cell::UnsafeCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::mem::MaybeUninit;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Json};

// ---------------------------------------------------------------------------
// clock
// ---------------------------------------------------------------------------

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-wide trace epoch (first obs use).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// span recorder
// ---------------------------------------------------------------------------

/// Span category — becomes the Chrome `cat` field and drives the
/// overlap accountant's comm/compute classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cat {
    /// Enclosing step/stage phases (excluded from overlap math).
    Phase,
    /// Forward/backward/factor/inverse math.
    Compute,
    /// Collective segments: publish/wait/reduce/drain.
    Comm,
    /// Wire serialization: quantize/encode/decode.
    Wire,
    /// Data pipeline: batch prep and prefetch wait.
    Data,
    /// Thread-pool internals (`parallel_for` scopes).
    Pool,
}

impl Cat {
    pub fn name(self) -> &'static str {
        match self {
            Cat::Phase => "phase",
            Cat::Compute => "compute",
            Cat::Comm => "comm",
            Cat::Wire => "wire",
            Cat::Data => "data",
            Cat::Pool => "pool",
        }
    }

    pub fn parse(s: &str) -> Option<Cat> {
        Some(match s {
            "phase" => Cat::Phase,
            "compute" => Cat::Compute,
            "comm" => Cat::Comm,
            "wire" => Cat::Wire,
            "data" => Cat::Data,
            "pool" => Cat::Pool,
            _ => return None,
        })
    }

    /// Does this category count as communication in the overlap math?
    /// Wire serialization rides the comm lane: it only exists to move
    /// bytes and serializes with the collective it feeds.
    pub fn is_comm(self) -> bool {
        matches!(self, Cat::Comm | Cat::Wire)
    }

    /// Does this category count as compute in the overlap math? Phases
    /// are excluded — they *enclose* both kinds and would double-count.
    pub fn is_compute(self) -> bool {
        matches!(self, Cat::Compute | Cat::Data | Cat::Pool)
    }
}

/// One recorded event. `arg` is a single optional numeric payload
/// (layer index, byte count, lane id …) — enough to label spans without
/// allocating on the hot path.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    Span { name: &'static str, cat: Cat, t0_ns: u64, t1_ns: u64, arg: Option<(&'static str, f64)> },
    Instant { name: &'static str, cat: Cat, t_ns: u64 },
    Counter { name: &'static str, t_ns: u64, value: f64 },
}

impl Event {
    fn t_sort(&self) -> u64 {
        match self {
            Event::Span { t0_ns, .. } => *t0_ns,
            Event::Instant { t_ns, .. } | Event::Counter { t_ns, .. } => *t_ns,
        }
    }
}

/// Default per-thread ring capacity (events). Override with
/// `SPNGD_TRACE_BUF`; invalid values are a hard error at first use,
/// matching the repo's env-var convention.
const DEFAULT_BUF: usize = 16_384;

fn buf_capacity() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| match std::env::var("SPNGD_TRACE_BUF") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 16 => n,
            _ => panic!("SPNGD_TRACE_BUF must be an integer >= 16, got '{v}'"),
        },
        Err(_) => DEFAULT_BUF,
    })
}

/// SPSC ring buffer: the owning thread writes, [`drain`] (serialized by
/// the registry lock) reads. Head/tail are monotonically increasing
/// event counts; slot index is `count % capacity`. On overflow the
/// newest event is dropped and counted — recording must never block the
/// training step.
struct RingBuf {
    tid: u64,
    thread_name: String,
    slots: Box<[UnsafeCell<MaybeUninit<Event>>]>,
    head: AtomicU64,
    tail: AtomicU64,
    dropped: AtomicU64,
}

// SAFETY: single-writer (owner thread via thread-local), single-reader
// (drain holds the registry mutex); head/tail Acquire/Release ordering
// publishes slot contents between them.
unsafe impl Sync for RingBuf {}
// SAFETY: same single-writer/single-reader protocol as Sync above; the
// buffer only moves threads at registry teardown, after its owner is gone.
unsafe impl Send for RingBuf {}

impl RingBuf {
    fn new(tid: u64, thread_name: String) -> RingBuf {
        let cap = buf_capacity();
        let slots = (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        RingBuf {
            tid,
            thread_name,
            slots,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Owner-thread only.
    fn push(&self, ev: Event) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head - tail >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = (head % self.slots.len() as u64) as usize;
        // SAFETY: slot `idx` is outside [tail, head) so the drainer will
        // not read it until the Release store below publishes it.
        unsafe { (*self.slots[idx].get()).write(ev) };
        self.head.store(head + 1, Ordering::Release);
    }

    /// Drainer only (registry lock held).
    fn drain_into(&self, out: &mut Vec<(u64, Event)>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail < head {
            let idx = (tail % self.slots.len() as u64) as usize;
            // SAFETY: [tail, head) slots are initialized and not touched
            // by the writer until tail advances past them.
            let ev = unsafe { (*self.slots[idx].get()).assume_init_read() };
            out.push((self.tid, ev));
            tail += 1;
        }
        self.tail.store(tail, Ordering::Release);
    }
}

struct Registry {
    bufs: Vec<Arc<RingBuf>>,
    next_tid: u64,
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry { bufs: Vec::new(), next_tid: 0 }))
}

thread_local! {
    static LOCAL_BUF: UnsafeCell<Option<Arc<RingBuf>>> = const { UnsafeCell::new(None) };
}

/// The calling thread's ring buffer, registering one on first use. The
/// registry keeps an `Arc` so events from exited threads (scoped
/// workers) survive until the next drain.
fn local_buf<R>(f: impl FnOnce(&RingBuf) -> R) -> R {
    LOCAL_BUF.with(|cell| {
        // SAFETY: thread-local, single-threaded access by construction.
        let slot = unsafe { &mut *cell.get() };
        if slot.is_none() {
            let mut reg = registry().lock().unwrap();
            let tid = reg.next_tid;
            reg.next_tid += 1;
            let name = std::thread::current().name().unwrap_or("unnamed").to_string();
            let buf = Arc::new(RingBuf::new(tid, name));
            reg.bufs.push(buf.clone());
            *slot = Some(buf);
        }
        f(slot.as_ref().unwrap())
    })
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One relaxed atomic load — the entire disabled
/// cost of every instrumentation point.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on/off (tests and the bench use this directly;
/// production runs go through [`set_trace_path`] / [`init_from_env`]).
pub fn set_enabled(on: bool) {
    if on {
        // pin the epoch before the first span so timestamps stay small
        let _ = epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// RAII span: records `{t_construct, t_drop}` on drop when tracing was
/// enabled at construction. When disabled, construction is the
/// [`enabled`] branch and nothing else.
pub struct SpanGuard {
    name: &'static str,
    cat: Cat,
    t0_ns: u64,
    arg: Option<(&'static str, f64)>,
    armed: bool,
}

impl SpanGuard {
    /// Attach a numeric argument (layer index, bytes, …) to the span.
    pub fn arg(mut self, key: &'static str, value: f64) -> SpanGuard {
        if self.armed {
            self.arg = Some((key, value));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            let t1 = now_ns();
            local_buf(|b| {
                b.push(Event::Span {
                    name: self.name,
                    cat: self.cat,
                    t0_ns: self.t0_ns,
                    t1_ns: t1,
                    arg: self.arg,
                })
            });
        }
    }
}

/// Open a span; it closes (and records) when the guard drops.
#[inline]
pub fn span(name: &'static str, cat: Cat) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, cat, t0_ns: 0, arg: None, armed: false };
    }
    SpanGuard { name, cat, t0_ns: now_ns(), arg: None, armed: true }
}

/// Record an instant event (a point in time, no duration).
#[inline]
pub fn instant(name: &'static str, cat: Cat) {
    if !enabled() {
        return;
    }
    let t = now_ns();
    local_buf(|b| b.push(Event::Instant { name, cat, t_ns: t }));
}

/// Record a counter sample (rendered as a track in Perfetto).
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    let t = now_ns();
    local_buf(|b| b.push(Event::Counter { name, t_ns: t, value }));
}

// ---------------------------------------------------------------------------
// drain + Chrome trace export
// ---------------------------------------------------------------------------

/// A drained trace: every event recorded since the last drain, plus the
/// thread table and the total number of events dropped to ring overflow.
#[derive(Debug, Default)]
pub struct Trace {
    /// `(tid, event)` pairs, sorted by start time.
    pub events: Vec<(u64, Event)>,
    /// `tid -> thread name` (name captured at first event on the thread).
    pub threads: BTreeMap<u64, String>,
    /// Events lost to ring-buffer overflow (cumulative per drain).
    pub dropped: u64,
}

impl Trace {
    /// Spans only, as `(tid, name, cat, t0_ns, t1_ns)` tuples.
    pub fn spans(&self) -> impl Iterator<Item = (u64, &'static str, Cat, u64, u64)> + '_ {
        self.events.iter().filter_map(|(tid, ev)| match ev {
            Event::Span { name, cat, t0_ns, t1_ns, .. } => Some((*tid, *name, *cat, *t0_ns, *t1_ns)),
            _ => None,
        })
    }

    /// Serialize to the Chrome trace-event JSON object format
    /// (`{"traceEvents": [...]}`) — loadable by `chrome://tracing` and
    /// Perfetto. Spans become `ph:"X"` complete events, instants
    /// `ph:"i"`, counters `ph:"C"`; every thread gets a `thread_name`
    /// metadata event so lanes are labeled. Timestamps are microseconds
    /// (fractional, preserving ns).
    pub fn to_chrome_json(&self) -> Json {
        let pid = std::process::id() as usize;
        let mut evs: Vec<Json> = Vec::with_capacity(self.events.len() + self.threads.len());
        for (tid, name) in &self.threads {
            evs.push(obj(vec![
                ("ph", Json::from("M")),
                ("name", Json::from("thread_name")),
                ("pid", Json::from(pid)),
                ("tid", Json::from(*tid as usize)),
                ("args", obj(vec![("name", Json::from(name.clone()))])),
            ]));
        }
        for (tid, ev) in &self.events {
            let tid = *tid as usize;
            match ev {
                Event::Span { name, cat, t0_ns, t1_ns, arg } => {
                    let mut fields = vec![
                        ("ph", Json::from("X")),
                        ("name", Json::from(*name)),
                        ("cat", Json::from(cat.name())),
                        ("pid", Json::from(pid)),
                        ("tid", Json::from(tid)),
                        ("ts", Json::from(*t0_ns as f64 / 1e3)),
                        ("dur", Json::from(t1_ns.saturating_sub(*t0_ns) as f64 / 1e3)),
                    ];
                    if let Some((k, v)) = arg {
                        fields.push(("args", obj(vec![(*k, Json::from(*v))])));
                    }
                    evs.push(obj(fields));
                }
                Event::Instant { name, cat, t_ns } => {
                    evs.push(obj(vec![
                        ("ph", Json::from("i")),
                        ("name", Json::from(*name)),
                        ("cat", Json::from(cat.name())),
                        ("pid", Json::from(pid)),
                        ("tid", Json::from(tid)),
                        ("ts", Json::from(*t_ns as f64 / 1e3)),
                        ("s", Json::from("t")),
                    ]));
                }
                Event::Counter { name, t_ns, value } => {
                    evs.push(obj(vec![
                        ("ph", Json::from("C")),
                        ("name", Json::from(*name)),
                        ("pid", Json::from(pid)),
                        ("tid", Json::from(tid)),
                        ("ts", Json::from(*t_ns as f64 / 1e3)),
                        ("args", obj(vec![("value", Json::from(*value))])),
                    ]));
                }
            }
        }
        obj(vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::from("ms")),
            ("otherData", obj(vec![("dropped", Json::from(self.dropped as usize))])),
        ])
    }
}

/// Drain every registered ring buffer into one time-sorted [`Trace`].
/// Spans still open (guards not yet dropped) are not included — drain at
/// quiescent points (end of run, between steps).
pub fn drain() -> Trace {
    let reg = registry().lock().unwrap();
    let mut tr = Trace::default();
    for buf in &reg.bufs {
        buf.drain_into(&mut tr.events);
        tr.dropped += buf.dropped.swap(0, Ordering::Relaxed);
        tr.threads.entry(buf.tid).or_insert_with(|| buf.thread_name.clone());
    }
    tr.events.sort_by_key(|(tid, ev)| (ev.t_sort(), *tid));
    tr
}

static TRACE_PATH: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Enable tracing and remember where [`flush_trace`] should write.
pub fn set_trace_path(path: &Path) {
    *TRACE_PATH.lock().unwrap() = Some(path.to_path_buf());
    set_enabled(true);
}

/// Drain and write the Chrome trace to the configured path, if any.
/// Returns the path written. Call at the end of a run.
pub fn flush_trace() -> std::io::Result<Option<PathBuf>> {
    let path = TRACE_PATH.lock().unwrap().clone();
    let Some(path) = path else { return Ok(None) };
    let trace = drain();
    std::fs::write(&path, trace.to_chrome_json().to_string())?;
    Ok(Some(path))
}

// ---------------------------------------------------------------------------
// overlap accountant
// ---------------------------------------------------------------------------

/// Overlap accounting over one drained trace — the measured form of the
/// paper's Alg. 3 overlap claim.
#[derive(Debug, Clone, PartialEq)]
pub struct Overlap {
    /// Union length of all comm-category span intervals (ns).
    pub comm_ns: u64,
    /// Union length of all compute-category span intervals (ns).
    pub compute_ns: u64,
    /// |comm ∩ compute|: comm wall time overlapped by compute (ns).
    pub hidden_ns: u64,
    /// `hidden_ns / comm_ns` (0 when there was no comm).
    pub hidden_fraction: f64,
    /// |comm ∪ compute|: a critical-path estimate — the minimal wall
    /// time if every hideable byte were hidden (ns).
    pub critical_path_ns: u64,
    /// Total span duration summed per span name (ns) — per-stage costs.
    pub by_name: BTreeMap<&'static str, u64>,
}

/// Merge (possibly overlapping, unsorted) intervals into a sorted
/// disjoint union.
fn interval_union(mut iv: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.retain(|(a, b)| b > a);
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some((_, e)) if a <= *e => *e = (*e).max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

fn union_len(iv: &[(u64, u64)]) -> u64 {
    iv.iter().map(|(a, b)| b - a).sum()
}

/// Length of the intersection of two sorted disjoint interval lists.
fn intersection_len(xs: &[(u64, u64)], ys: &[(u64, u64)]) -> u64 {
    let (mut i, mut j, mut total) = (0, 0, 0u64);
    while i < xs.len() && j < ys.len() {
        let lo = xs[i].0.max(ys[j].0);
        let hi = xs[i].1.min(ys[j].1);
        if hi > lo {
            total += hi - lo;
        }
        if xs[i].1 < ys[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    total
}

/// Post-process a trace into overlap numbers. Comm = `Cat::{Comm,Wire}`
/// spans; compute = `Cat::{Compute,Data,Pool}` spans; `Cat::Phase`
/// spans enclose both and are excluded from the interval math (they
/// still appear in `by_name`).
pub fn overlap(trace: &Trace) -> Overlap {
    let mut comm_iv = Vec::new();
    let mut compute_iv = Vec::new();
    let mut by_name: BTreeMap<&'static str, u64> = BTreeMap::new();
    for (_tid, name, cat, t0, t1) in trace.spans() {
        *by_name.entry(name).or_insert(0) += t1.saturating_sub(t0);
        if cat.is_comm() {
            comm_iv.push((t0, t1));
        } else if cat.is_compute() {
            compute_iv.push((t0, t1));
        }
    }
    let comm = interval_union(comm_iv);
    let compute = interval_union(compute_iv);
    let comm_ns = union_len(&comm);
    let compute_ns = union_len(&compute);
    let hidden_ns = intersection_len(&comm, &compute);
    let mut all = comm.clone();
    all.extend_from_slice(&compute);
    let critical_path_ns = union_len(&interval_union(all));
    Overlap {
        comm_ns,
        compute_ns,
        hidden_ns,
        hidden_fraction: if comm_ns == 0 { 0.0 } else { hidden_ns as f64 / comm_ns as f64 },
        critical_path_ns,
        by_name,
    }
}

// ---------------------------------------------------------------------------
// JSONL event stream
// ---------------------------------------------------------------------------

// The parse side (schema tags, `EventRec`, `parse_line`, `read_events`)
// lives in `util::events` — a structured-error parser module under the
// lint's panic-hygiene rule. Re-exported here so `obs::parse_line`
// callers keep working.
pub use crate::util::events::{parse_line, read_events, EventRec, EVENT_SCHEMA, EVENT_SCHEMAS};

static EVENTS_ON: AtomicBool = AtomicBool::new(false);
static EVENT_SEQ: AtomicUsize = AtomicUsize::new(0);

fn event_sink() -> &'static Mutex<Option<BufWriter<File>>> {
    static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Is the JSONL event stream on? Same relaxed-atomic discipline as
/// [`enabled`].
#[inline(always)]
pub fn events_enabled() -> bool {
    EVENTS_ON.load(Ordering::Relaxed)
}

/// Open (truncating) the JSONL event sink at `path` and enable emission.
pub fn set_events_path(path: &Path) -> std::io::Result<()> {
    let f = File::create(path)?;
    *event_sink().lock().unwrap() = Some(BufWriter::new(f));
    let _ = epoch();
    EVENTS_ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// Close the event sink and disable emission (flushes pending lines).
pub fn close_events() {
    EVENTS_ON.store(false, Ordering::Relaxed);
    if let Some(mut w) = event_sink().lock().unwrap().take() {
        let _ = w.flush();
    }
}

/// Emit one structured event line: `{"schema":"spngd-events/2",
/// "seq":N, "t":secs, "kind":kind, ...fields}`. Each line is flushed so
/// the stream survives a crash of the emitting process — it is the
/// source of truth for dist-layer assertions.
pub fn emit(kind: &str, fields: Vec<(&str, Json)>) {
    if !events_enabled() {
        return;
    }
    let seq = EVENT_SEQ.fetch_add(1, Ordering::Relaxed);
    let t = now_ns() as f64 / 1e9;
    let mut pairs = vec![
        ("schema", Json::from(EVENT_SCHEMA)),
        ("seq", Json::from(seq)),
        ("t", Json::from(t)),
        ("kind", Json::from(kind)),
    ];
    pairs.extend(fields);
    let line = obj(pairs).to_string();
    let mut guard = event_sink().lock().unwrap();
    if let Some(w) = guard.as_mut() {
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

// ---------------------------------------------------------------------------
// env wiring
// ---------------------------------------------------------------------------

/// One-shot env wiring: `SPNGD_TRACE=PATH` enables span recording with
/// the trace written to PATH at [`flush_trace`]; `SPNGD_EVENTS=PATH`
/// opens the JSONL event sink. Idempotent; called from every trainer
/// construction so examples/benches/tests pick the switches up without
/// plumbing.
pub fn init_from_env() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        if let Ok(p) = std::env::var("SPNGD_TRACE") {
            // an explicit --trace-out already set a path: the flag wins
            if !p.trim().is_empty() && TRACE_PATH.lock().unwrap().is_none() {
                set_trace_path(Path::new(p.trim()));
            }
        }
        if let Ok(p) = std::env::var("SPNGD_EVENTS") {
            if !p.trim().is_empty() && !events_enabled() {
                set_events_path(Path::new(p.trim()))
                    .unwrap_or_else(|e| panic!("SPNGD_EVENTS='{p}': cannot open sink: {e}"));
            }
        }
    });
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; tests that toggle it serialize
    /// here so `cargo test`'s parallel runner can't interleave drains.
    pub(crate) fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = trace_lock();
        set_enabled(false);
        drop(drain());
        {
            let _s = span("never", Cat::Compute).arg("x", 1.0);
        }
        instant("never_i", Cat::Comm);
        counter("never_c", 3.0);
        // other (non-obs) tests may run concurrently and close spans
        // they opened while tracing was on, so assert on our names only
        let ours = drain().events.iter().any(|(_, e)| {
            matches!(
                e,
                Event::Span { name: "never", .. }
                    | Event::Instant { name: "never_i", .. }
                    | Event::Counter { name: "never_c", .. }
            )
        });
        assert!(!ours);
    }

    #[test]
    fn span_roundtrip_and_ordering() {
        let _g = trace_lock();
        set_enabled(true);
        drop(drain());
        {
            let _outer = span("outer", Cat::Phase);
            {
                let _inner = span("inner", Cat::Compute).arg("layer", 3.0);
            }
            instant("mark", Cat::Comm);
        }
        set_enabled(false);
        let tr = drain();
        let names: Vec<&str> = tr
            .events
            .iter()
            .map(|(_, e)| match e {
                Event::Span { name, .. } => *name,
                Event::Instant { name, .. } => *name,
                Event::Counter { name, .. } => *name,
            })
            .collect();
        // sorted by start time: outer opened first but closes last —
        // inner records first; the sort is on t_start
        assert!(names.contains(&"outer") && names.contains(&"inner") && names.contains(&"mark"));
        for (tid, ev) in &tr.events {
            assert!(tr.threads.contains_key(tid));
            if let Event::Span { t0_ns, t1_ns, .. } = ev {
                assert!(t1_ns >= t0_ns);
            }
        }
        // events sorted by start time
        let ts: Vec<u64> = tr.events.iter().map(|(_, e)| e.t_sort()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        let outer = tr
            .events
            .iter()
            .find_map(|(_, e)| match e {
                Event::Span { name: "outer", t0_ns, t1_ns, .. } => Some((*t0_ns, *t1_ns)),
                _ => None,
            })
            .unwrap();
        let inner = tr
            .events
            .iter()
            .find_map(|(_, e)| match e {
                Event::Span { name: "inner", t0_ns, t1_ns, arg } => {
                    assert_eq!(*arg, Some(("layer", 3.0)));
                    Some((*t0_ns, *t1_ns))
                }
                _ => None,
            })
            .unwrap();
        assert!(outer.0 <= inner.0 && inner.1 <= outer.1, "inner nests in outer");
    }

    #[test]
    fn ring_overflow_drops_newest_and_counts() {
        let _g = trace_lock();
        set_enabled(true);
        drop(drain());
        let cap = buf_capacity();
        for _ in 0..cap + 100 {
            instant("flood", Cat::Compute);
        }
        set_enabled(false);
        let tr = drain();
        let flood = tr
            .events
            .iter()
            .filter(|(_, e)| matches!(e, Event::Instant { name: "flood", .. }))
            .count();
        assert!(flood <= cap);
        assert!(tr.dropped >= 100);
        // buffer drains clean: no flood events remain for a second drain
        let leftover = drain()
            .events
            .iter()
            .filter(|(_, e)| matches!(e, Event::Instant { name: "flood", .. }))
            .count();
        assert_eq!(leftover, 0);
    }

    #[test]
    fn chrome_json_shape() {
        let tr = Trace {
            events: vec![
                (0, Event::Span { name: "s", cat: Cat::Comm, t0_ns: 1000, t1_ns: 2500, arg: Some(("bytes", 64.0)) }),
                (0, Event::Instant { name: "i", cat: Cat::Phase, t_ns: 1500 }),
                (1, Event::Counter { name: "c", t_ns: 1700, value: 2.0 }),
            ],
            threads: BTreeMap::from([(0, "main".to_string()), (1, "spngd-pool-0".to_string())]),
            dropped: 0,
        };
        let j = tr.to_chrome_json();
        let evs = j.get("traceEvents").as_arr().unwrap();
        assert_eq!(evs.len(), 5); // 2 thread_name metadata + 3 events
        let meta: Vec<_> =
            evs.iter().filter(|e| e.get("ph").as_str() == Some("M")).collect();
        assert_eq!(meta.len(), 2);
        assert_eq!(meta[0].get("name").as_str(), Some("thread_name"));
        let x = evs.iter().find(|e| e.get("ph").as_str() == Some("X")).unwrap();
        assert_eq!(x.get("ts").as_f64(), Some(1.0)); // µs
        assert_eq!(x.get("dur").as_f64(), Some(1.5));
        assert_eq!(x.get("cat").as_str(), Some("comm"));
        assert_eq!(x.get("args").get("bytes").as_f64(), Some(64.0));
        // reparse: the writer emits valid JSON
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed.get("traceEvents").as_arr().unwrap().len(), 5);
    }

    fn mk_trace(spans: Vec<(Cat, u64, u64)>) -> Trace {
        Trace {
            events: spans
                .into_iter()
                .map(|(cat, a, b)| {
                    (0, Event::Span { name: "s", cat, t0_ns: a, t1_ns: b, arg: None })
                })
                .collect(),
            threads: BTreeMap::new(),
            dropped: 0,
        }
    }

    #[test]
    fn overlap_fully_hidden() {
        // comm [10,20) entirely inside compute [0,100): hidden = 1.0
        let o = overlap(&mk_trace(vec![(Cat::Comm, 10, 20), (Cat::Compute, 0, 100)]));
        assert_eq!(o.comm_ns, 10);
        assert_eq!(o.compute_ns, 100);
        assert_eq!(o.hidden_ns, 10);
        assert_eq!(o.hidden_fraction, 1.0);
        assert_eq!(o.critical_path_ns, 100);
    }

    #[test]
    fn overlap_fully_serial() {
        // comm [100,150) strictly after compute [0,100): hidden = 0
        let o = overlap(&mk_trace(vec![(Cat::Comm, 100, 150), (Cat::Compute, 0, 100)]));
        assert_eq!(o.hidden_ns, 0);
        assert_eq!(o.hidden_fraction, 0.0);
        assert_eq!(o.critical_path_ns, 150);
    }

    #[test]
    fn overlap_partial_exact() {
        // comm [50,150), compute [0,100): overlap [50,100) = 50 of 100 comm
        let o = overlap(&mk_trace(vec![(Cat::Comm, 50, 150), (Cat::Compute, 0, 100)]));
        assert_eq!(o.comm_ns, 100);
        assert_eq!(o.hidden_ns, 50);
        assert_eq!(o.hidden_fraction, 0.5);
        assert_eq!(o.critical_path_ns, 150);
    }

    #[test]
    fn overlap_unions_before_intersecting() {
        // two overlapping comm spans union to [0,30); wire counts as comm;
        // phase spans are ignored; two compute spans union to [10,40)
        let o = overlap(&mk_trace(vec![
            (Cat::Comm, 0, 20),
            (Cat::Wire, 10, 30),
            (Cat::Phase, 0, 1000),
            (Cat::Compute, 10, 25),
            (Cat::Pool, 20, 40),
        ]));
        assert_eq!(o.comm_ns, 30);
        assert_eq!(o.compute_ns, 30);
        assert_eq!(o.hidden_ns, 20); // [10,30)
        assert!((o.hidden_fraction - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(o.critical_path_ns, 40);
        assert_eq!(o.by_name["s"], 20 + 20 + 1000 + 15 + 20);
    }

    #[test]
    fn overlap_empty_and_degenerate() {
        let o = overlap(&mk_trace(vec![]));
        assert_eq!(o.hidden_fraction, 0.0);
        assert_eq!(o.critical_path_ns, 0);
        // zero-length spans are dropped from interval math
        let o = overlap(&mk_trace(vec![(Cat::Comm, 5, 5), (Cat::Compute, 1, 2)]));
        assert_eq!(o.comm_ns, 0);
        assert_eq!(o.hidden_fraction, 0.0);
    }

    #[test]
    fn event_line_roundtrip() {
        let line = format!(
            r#"{{"schema":"{EVENT_SCHEMA}","seq":4,"t":1.25,"kind":"dead","rank":1,"reason":"checksum"}}"#
        );
        let ev = parse_line(&line).unwrap();
        assert_eq!(ev.kind, "dead");
        assert_eq!(ev.seq, 4);
        assert_eq!(ev.t, 1.25);
        assert_eq!(ev.get("rank").as_usize(), Some(1));
        assert_eq!(ev.get("reason").as_str(), Some("checksum"));
        assert_eq!(ev.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_line_skips_garbage() {
        assert!(parse_line("").is_none());
        assert!(parse_line("   ").is_none());
        assert!(parse_line("{").is_none());
        assert!(parse_line("not json at all").is_none());
        assert!(parse_line(r#"{"schema":"other/9","kind":"x","t":0}"#).is_none());
        assert!(parse_line(r#"{"kind":"x","t":0}"#).is_none()); // no schema
        assert!(parse_line(&format!(r#"{{"schema":"{EVENT_SCHEMA}","t":0}}"#)).is_none()); // no kind
        assert!(parse_line(&format!(r#"{{"schema":"{EVENT_SCHEMA}","kind":"x"}}"#)).is_none()); // no t
        assert!(parse_line(r#"[1,2,3]"#).is_none()); // not an object
        let huge = format!(
            r#"{{"schema":"{EVENT_SCHEMA}","kind":"x","t":0,"blob":"{}"}}"#,
            "a".repeat(2 << 20)
        );
        assert!(parse_line(&huge).is_none()); // oversized
    }

    #[test]
    fn emit_read_events_roundtrip() {
        let _g = trace_lock();
        let dir = std::env::temp_dir().join(format!("spngd-obs-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        set_events_path(&path).unwrap();
        emit("state", vec![("state", Json::from("Warmup")), ("step", Json::from(0usize))]);
        emit("dead", vec![("rank", Json::from(1usize)), ("reason", Json::from("kill"))]);
        close_events();
        // interleave garbage between valid lines, as a crashed writer would
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.insert_str(0, "garbage line\n");
        text.push_str("{\"trunc");
        std::fs::write(&path, text).unwrap();
        let evs = read_events(&path).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, "state");
        assert_eq!(evs[0].get("state").as_str(), Some("Warmup"));
        assert_eq!(evs[1].kind, "dead");
        assert_eq!(evs[1].get("rank").as_usize(), Some(1));
        assert!(evs[0].seq < evs[1].seq);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn emit_disabled_is_a_noop() {
        let _g = trace_lock();
        close_events();
        emit("nope", vec![]); // must not panic with no sink
        assert!(!events_enabled());
    }
}
