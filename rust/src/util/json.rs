//! Minimal JSON parser + writer — substrate replacing `serde_json`.
//!
//! Used for the `artifacts/manifest.json` interchange between the python
//! AOT pipeline (L2) and the rust coordinator (L3), and for experiment
//! result dumps. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (manifest values fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns Null for missing keys on non-objects too.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a Json object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b.get(self.i..).is_some_and(|t| t.starts_with(word.as_bytes())) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = self
            .b
            .get(start..self.i)
            .and_then(|t| std::str::from_utf8(t).ok())
            .ok_or_else(|| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            // get() rejects truncation; from_utf8 rejects an
                            // escape whose 4 bytes split a multi-byte char
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 char
                    let c = self
                        .b
                        .get(self.i..)
                        .and_then(|t| std::str::from_utf8(t).ok())
                        .and_then(|s| s.chars().next())
                        .ok_or_else(|| self.err("invalid utf-8"))?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"layers":[{"name":"conv1","dims":[64,147]},{"name":"fc","dims":[10,256]}],"version":2}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"日本\"").unwrap();
        assert_eq!(v.as_str(), Some("日本"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_fidelity() {
        let v = Json::parse("1281167").unwrap();
        assert_eq!(v.as_usize(), Some(1_281_167));
        assert_eq!(v.to_string(), "1281167");
    }

    #[test]
    fn builders() {
        let v = obj(vec![("n", Json::from(3usize)), ("xs", Json::from(vec![1.0, 2.5]))]);
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("xs").at(1).as_f64(), Some(2.5));
    }
}
