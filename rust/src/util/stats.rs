//! Summary statistics over samples — shared by the bench harness and the
//! coordinator's step-time metrics.

/// Streaming + batch summary of a set of f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn from(samples: &[f64]) -> Self {
        Summary { samples: samples.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".to_string();
    }
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.3} s", secs)
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

/// Format a byte count (B/KiB/MiB/GiB).
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.0000000015), "1.5 ns");
        assert!(fmt_duration(0.0025).contains("ms"));
        assert!(fmt_duration(330.0).contains("min"));
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert!(fmt_bytes(3.0 * 1024.0 * 1024.0).contains("MiB"));
    }
}
