//! Small self-contained substrates (no external deps beyond std).
//!
//! Only the `xla` crate's vendored dependency closure is available offline,
//! so each of these replaces a crate a production project would normally
//! pull in: rng≈`rand`, json≈`serde_json`, cli≈`clap`, pool≈`rayon`,
//! prop≈`proptest`, stats+bench≈`criterion`, log≈`tracing`,
//! obs≈`tracing-chrome`+`perfetto`, f16≈`half`, simd≈`wide`.

pub mod cli;
pub mod events;
pub mod f16;
pub mod json;
pub mod log;
pub mod obs;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
