//! Deterministic PRNG (xoshiro256**) — substrate replacing the `rand` crate.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 64-bit state x4.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion of a single u64.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Lemire's method (no modulo bias for practical n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample from Beta(a, a) via two Gamma draws (Marsaglia-Tsang).
    pub fn beta_symmetric(&mut self, a: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(a);
        if x + y == 0.0 { 0.5 } else { x / (x + y) }
    }

    /// Gamma(shape, 1) sampler, Marsaglia-Tsang; boosts shape<1 case.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG with a decorrelated stream.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Snapshot the raw generator state (checkpointing).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the restored
    /// stream continues bit-for-bit where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn beta_in_unit_interval_and_symmetric() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.beta_symmetric(0.4);
            assert!((0.0..=1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(31);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
