//! Software IEEE 754 binary16 codec — the mixed-precision wire format.
//!
//! `SPNGD_PRECISION=mixed` moves gradient AllReduce and statistics
//! ReduceScatterV payloads over the wire as f16 while every master copy
//! stays f32 and every reduction accumulates in f64 (the paper's
//! fp16-comm / fp32-master recipe, §5.2). No `half` crate is available
//! offline, so the conversion is implemented here: round-to-nearest-even
//! encode with gradual underflow (subnormals), overflow to ±inf, and
//! NaN payload preservation — so the 16-bit space round-trips exactly
//! (`f16 → f32 → f16` is the identity on all 65536 bit patterns,
//! asserted exhaustively in the tests below).

/// Encode an f32 to f16 bits, rounding to nearest-even.
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs > 0x7f80_0000 {
        // NaN: keep the top 10 payload bits; force a nonzero payload so
        // the result stays a NaN (never collapses to an infinity)
        let mut p = ((abs >> 13) & 0x3ff) as u16;
        if p == 0 {
            p = 0x200;
        }
        return sign | 0x7c00 | p;
    }
    let exp = (abs >> 23) as i32 - 112; // biased f16 exponent
    if exp >= 31 {
        return sign | 0x7c00; // ±inf (and anything ≥ 2^16)
    }
    if exp >= 1 {
        // normal: truncate 23 → 10 mantissa bits, then RNE on the 13
        // dropped bits; a rounding carry walks into the exponent and
        // correctly sends [65520, 65536) to +inf
        let man = abs & 0x7f_ffff;
        let mut h = sign | ((exp as u16) << 10) | ((man >> 13) as u16);
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
            h += 1;
        }
        return h;
    }
    if exp < -10 {
        return sign; // underflows past half the smallest subnormal
    }
    // subnormal: shift the 24-bit significand down to a 2^-24 ulp grid
    let s = (abs & 0x7f_ffff) | 0x80_0000;
    let shift = (14 - exp) as u32; // 14..=24
    let r = (s >> shift) as u16;
    let rem = s & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut h = sign | r;
    if rem > half || (rem == half && (r & 1) == 1) {
        h += 1; // a carry lands on the smallest normal — still correct
    }
    h
}

/// Decode f16 bits to f32 (exact — every f16 value is representable).
pub fn f32_from_f16(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN (payload preserved)
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: normalize into an f32 exponent
            let mut e = 113u32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// The wire round-trip: what a value looks like after moving as f16.
#[inline]
pub fn round_trip(x: f32) -> f32 {
    f32_from_f16(f16_from_f32(x))
}

/// Quantize a buffer in place through the f16 wire format.
pub fn quantize_slice(buf: &mut [f32]) {
    for v in buf.iter_mut() {
        *v = round_trip(*v);
    }
}

/// Serialize f32 values as little-endian f16 bytes (2 bytes/element) —
/// the real on-wire layout of the mixed-precision process transport.
pub fn encode_le(vals: &[f32], out: &mut Vec<u8>) {
    out.reserve(vals.len() * 2);
    for &v in vals {
        out.extend_from_slice(&f16_from_f32(v).to_le_bytes());
    }
}

/// Decode little-endian f16 bytes back to f32 (exact per element).
/// Returns `None` on an odd byte count — the caller's framing is broken.
pub fn decode_le(bytes: &[u8]) -> Option<Vec<f32>> {
    if bytes.len() % 2 != 0 {
        return None;
    }
    Some(
        bytes
            .chunks_exact(2)
            // lint:allow(panic-hygiene) -- chunks_exact(2) guarantees both indices exist
            .map(|c| f32_from_f16(u16::from_le_bytes([c[0], c[1]])))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn known_encodings() {
        assert_eq!(f16_from_f32(0.0), 0x0000);
        assert_eq!(f16_from_f32(-0.0), 0x8000);
        assert_eq!(f16_from_f32(1.0), 0x3c00);
        assert_eq!(f16_from_f32(-2.0), 0xc000);
        assert_eq!(f16_from_f32(0.5), 0x3800);
        assert_eq!(f16_from_f32(65504.0), 0x7bff); // f16 max
        assert_eq!(f16_from_f32(f32::INFINITY), 0x7c00);
        assert_eq!(f16_from_f32(f32::NEG_INFINITY), 0xfc00);
        // smallest subnormal 2^-24, smallest normal 2^-14
        assert_eq!(f16_from_f32(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f16_from_f32(2.0f32.powi(-14)), 0x0400);
    }

    #[test]
    fn rounding_boundaries() {
        // 65520 = midpoint between 65504 and 2^16 — ties-to-even → inf
        assert_eq!(f16_from_f32(65520.0), 0x7c00);
        assert_eq!(f16_from_f32(65519.9), 0x7bff);
        assert_eq!(f16_from_f32(1e9), 0x7c00);
        // half the smallest subnormal is a tie against zero (even) → 0
        assert_eq!(f16_from_f32(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f16_from_f32(2.0f32.powi(-25) * 1.5), 0x0001);
        // 1 + 2^-11 is the midpoint between 1.0 and 1+2^-10 → even (1.0)
        assert_eq!(f16_from_f32(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f16_from_f32(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-18)), 0x3c01);
    }

    #[test]
    fn nan_stays_nan() {
        let q = round_trip(f32::NAN);
        assert!(q.is_nan());
        // a NaN whose payload truncates to zero must not become inf
        let evil = f32::from_bits(0x7f80_0001);
        assert!(evil.is_nan());
        assert!(f32_from_f16(f16_from_f32(evil)).is_nan());
    }

    #[test]
    fn exhaustive_h2f2h_identity() {
        // decode→encode is the identity on the entire 16-bit space: no
        // panic, no drift, NaN payloads included
        for h in 0..=u16::MAX {
            let f = f32_from_f16(h);
            let back = f16_from_f32(f);
            assert_eq!(back, h, "h={h:#06x} f={f}");
        }
    }

    #[test]
    fn round_trip_is_idempotent_and_bounded() {
        prop::check(
            101,
            500,
            64,
            |rng: &mut Rng, size| prop::gen::vec_f32(rng, size, 1000.0),
            |v| {
                v.iter().all(|&x| {
                    let q = round_trip(x);
                    // idempotent: a second trip changes nothing
                    if round_trip(q).to_bits() != q.to_bits() {
                        return false;
                    }
                    // relative error ≤ 2^-11 in the f16 normal range
                    if x.abs() >= 6.2e-5 && x.abs() <= 65504.0 {
                        return (q - x).abs() <= x.abs() * 1.0 / 2048.0;
                    }
                    true
                })
            },
        );
    }

    #[test]
    fn arbitrary_f32_bits_never_panic() {
        // fuzz the encoder over raw bit patterns (NaNs, subnormals, inf)
        prop::check(
            103,
            2000,
            16,
            |rng: &mut Rng, _| f32::from_bits((rng.f64() * u32::MAX as f64) as u32),
            |&x| {
                let h = f16_from_f32(x);
                let f = f32_from_f16(h);
                // classes are preserved
                (x.is_nan() && f.is_nan()) || (!x.is_nan() && !f.is_nan())
            },
        );
    }

    #[test]
    fn byte_codec_round_trips_and_rejects_odd_lengths() {
        let mut rng = Rng::new(109);
        let v: Vec<f32> = (0..64).map(|_| (rng.f32() * 2.0 - 1.0) * 300.0).collect();
        let mut bytes = Vec::new();
        encode_le(&v, &mut bytes);
        assert_eq!(bytes.len(), v.len() * 2);
        let back = decode_le(&bytes).unwrap();
        for (a, b) in v.iter().zip(back.iter()) {
            assert_eq!(round_trip(*a).to_bits(), b.to_bits(), "wire = exact f16 round trip");
        }
        assert!(decode_le(&bytes[..bytes.len() - 1]).is_none(), "odd length rejected");
        assert_eq!(decode_le(&[]).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn quantize_slice_matches_elementwise() {
        let mut rng = Rng::new(107);
        let v: Vec<f32> = (0..100).map(|_| (rng.f32() * 2.0 - 1.0) * 50.0).collect();
        let mut q = v.clone();
        quantize_slice(&mut q);
        for (a, b) in v.iter().zip(q.iter()) {
            assert_eq!(round_trip(*a).to_bits(), b.to_bits());
        }
    }
}
