//! Tiny property-based testing framework — substrate replacing `proptest`.
//!
//! Generates `cases` random inputs from a generator closure, runs the
//! property, and on failure attempts a simple greedy shrink by re-sampling
//! "smaller" inputs (the generator receives a shrink budget hint).

use crate::util::rng::Rng;

/// Run `prop` against `cases` random inputs drawn by `gen`.
///
/// `gen` receives (rng, size) where size ramps up from 1 to `max_size` over
/// the run, so early cases are small (cheap failures shrink themselves).
/// Panics with the failing case description on the first violation.
pub fn check<T, G, P>(seed: u64, cases: usize, max_size: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = 1 + (case * max_size) / cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // greedy shrink: try smaller sizes with fresh draws
            let mut best: Option<T> = None;
            let mut shrink_rng = rng.fork(0xD5);
            for s in (1..size).rev() {
                for _ in 0..20 {
                    let candidate = gen(&mut shrink_rng, s);
                    if !prop(&candidate) {
                        best = Some(candidate);
                        break;
                    }
                }
                if best.is_some() {
                    break;
                }
            }
            match best {
                Some(b) => panic!(
                    "property failed (seed={seed}, case={case}, size={size})\n  original: {input:?}\n  shrunk:   {b:?}"
                ),
                None => panic!(
                    "property failed (seed={seed}, case={case}, size={size})\n  input: {input:?}"
                ),
            }
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vec of f32 in [-scale, scale], length = size.
    pub fn vec_f32(rng: &mut Rng, size: usize, scale: f32) -> Vec<f32> {
        (0..size).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    }

    /// Vec of f64 in [-scale, scale], length = size.
    pub fn vec_f64(rng: &mut Rng, size: usize, scale: f64) -> Vec<f64> {
        (0..size).map(|_| (rng.f64() * 2.0 - 1.0) * scale).collect()
    }

    /// A random SPD matrix of dim n (row-major) built as B Bᵀ + eps I.
    pub fn spd(rng: &mut Rng, n: usize, eps: f64) -> Vec<f64> {
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut m = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += b[i * n + k] * b[j * n + k];
                }
                m[i * n + j] = acc / n as f64 + if i == j { eps } else { 0.0 };
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, 64, |rng, size| gen::vec_f64(rng, size, 10.0), |v| {
            v.iter().all(|x| x.abs() <= 10.0)
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 200, 64, |rng, size| gen::vec_f64(rng, size, 1.0), |v| v.len() < 30);
    }

    #[test]
    fn spd_is_symmetric_positive() {
        check(3, 30, 12, |rng, size| gen::spd(rng, size.max(1), 1e-3), |m| {
            let n = (m.len() as f64).sqrt() as usize;
            // symmetry
            for i in 0..n {
                for j in 0..n {
                    if (m[i * n + j] - m[j * n + i]).abs() > 1e-12 {
                        return false;
                    }
                }
            }
            // diagonal positive (necessary condition)
            (0..n).all(|i| m[i * n + i] > 0.0)
        });
    }
}
