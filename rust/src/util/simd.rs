//! Runtime-dispatched SIMD microkernels for the hot inner loops.
//!
//! The blocked matmul/SYRK kernels in `linalg` and `runtime::native` are
//! bit-exact against their naive `*_ref` oracles because they keep the
//! per-element accumulation order. These vector paths preserve that
//! contract: every operation is an element-wise multiply followed by an
//! element-wise add (never a fused multiply-add, which would change the
//! rounding), and the `dot` reduction stores its 8 vector lanes and
//! applies the exact same pairwise reduce tree as the scalar tile. So
//! scalar, AVX2 and NEON all produce identical bits — the dispatch mode
//! is a pure performance knob, safe to flip at any time.
//!
//! Dispatch is resolved once (cached in an atomic): `SPNGD_SIMD=scalar`
//! forces the fallback, `SPNGD_SIMD=native` (or unset) picks the best
//! path the CPU supports — AVX2 on x86-64 (checked at runtime), NEON on
//! aarch64 (baseline), scalar everywhere else. Tests and benches can
//! override via [`force`].

use std::sync::atomic::{AtomicU8, Ordering};

const UNRESOLVED: u8 = 0;
const SCALAR: u8 = 1;
const NATIVE: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(UNRESOLVED);

#[cfg(target_arch = "x86_64")]
const NATIVE_NAME: &str = "avx2";
#[cfg(target_arch = "aarch64")]
const NATIVE_NAME: &str = "neon";
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
const NATIVE_NAME: &str = "scalar";

fn native_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        return std::is_x86_feature_detected!("avx2");
    }
    #[cfg(target_arch = "aarch64")]
    {
        return true;
    }
    #[allow(unreachable_code)]
    false
}

fn resolve() -> u8 {
    if let Ok(v) = std::env::var("SPNGD_SIMD") {
        if v == "scalar" {
            return SCALAR;
        }
    }
    if native_available() {
        NATIVE
    } else {
        SCALAR
    }
}

#[inline]
fn mode() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m != UNRESOLVED {
        return m;
    }
    let r = resolve();
    MODE.store(r, Ordering::Relaxed);
    r
}

/// Force a dispatch mode: `"scalar"` or `"native"` (test/bench hook —
/// the env override is `SPNGD_SIMD`). `"native"` resolves to the best
/// path this CPU actually supports, so forcing it is always sound; and
/// since all paths are bit-identical, flipping modes mid-run (even from
/// concurrent tests) can never change results.
pub fn force(mode: &str) {
    let m = if mode == "scalar" || !native_available() { SCALAR } else { NATIVE };
    MODE.store(m, Ordering::Relaxed);
}

/// Name of the active kernel path: `"avx2"`, `"neon"` or `"scalar"`
/// (recorded in `BENCH_native.json`'s `simd` dimension).
pub fn kernel_name() -> &'static str {
    if mode() == NATIVE {
        NATIVE_NAME
    } else {
        "scalar"
    }
}

/// o[j] += x * b[j] over o.len() elements (b at least as long).
#[inline]
pub fn axpy(x: f32, b: &[f32], o: &mut [f32]) {
    debug_assert!(b.len() >= o.len());
    if mode() == NATIVE {
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: NATIVE on x86_64 means resolve() detected AVX2; accesses
            // are bounded by the slice-length contract asserted above.
            unsafe { avx2::axpy(x, b, o) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is part of the aarch64 baseline; accesses are
            // bounded by the slice-length contract asserted above.
            unsafe { neon::axpy(x, b, o) };
            return;
        }
    }
    axpy_scalar(x, b, o);
}

/// Two-row axpy: o0[j] += x0 * b[j]; o1[j] += x1 * b[j]. The B row is
/// loaded once and feeds both accumulator rows (the register tile of the
/// blocked matmul).
#[inline]
pub fn axpy2(x0: f32, x1: f32, b: &[f32], o0: &mut [f32], o1: &mut [f32]) {
    debug_assert!(b.len() >= o0.len() && o0.len() == o1.len());
    if mode() == NATIVE {
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: NATIVE on x86_64 means resolve() detected AVX2; accesses
            // are bounded by the slice-length contract asserted above.
            unsafe { avx2::axpy2(x0, x1, b, o0, o1) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is part of the aarch64 baseline; accesses are
            // bounded by the slice-length contract asserted above.
            unsafe { neon::axpy2(x0, x1, b, o0, o1) };
            return;
        }
    }
    axpy2_scalar(x0, x1, b, o0, o1);
}

/// Dot product with 8 independent accumulator lanes reduced by the fixed
/// pairwise tree `(0+1)+(2+3) + (4+5)+(6+7)` plus a scalar tail — the
/// exact summation order of the scalar 8-lane tile, on every path.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(b.len() >= a.len());
    if mode() == NATIVE {
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: NATIVE on x86_64 means resolve() detected AVX2; accesses
            // are bounded by the slice-length contract asserted above.
            return unsafe { avx2::dot(a, b) };
        }
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is part of the aarch64 baseline; accesses are
            // bounded by the slice-length contract asserted above.
            return unsafe { neon::dot(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// acc[j] += x * xs[j] as f64 over acc.len() elements — the widening
/// accumulate of the SYRK factor path (f32 activations into the f64
/// accumulator that keeps statistics bit-stable across thread counts).
#[inline]
pub fn axpy_widen(x: f64, xs: &[f32], acc: &mut [f64]) {
    debug_assert!(xs.len() >= acc.len());
    if mode() == NATIVE {
        #[cfg(target_arch = "x86_64")]
        {
            // SAFETY: NATIVE on x86_64 means resolve() detected AVX2; accesses
            // are bounded by the slice-length contract asserted above.
            unsafe { avx2::axpy_widen(x, xs, acc) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is part of the aarch64 baseline; accesses are
            // bounded by the slice-length contract asserted above.
            unsafe { neon::axpy_widen(x, xs, acc) };
            return;
        }
    }
    axpy_widen_scalar(x, xs, acc);
}

// ---- scalar fallback (and the semantic definition of each op) ----

fn axpy_scalar(x: f32, b: &[f32], o: &mut [f32]) {
    for (oj, bj) in o.iter_mut().zip(b) {
        *oj += x * bj;
    }
}

fn axpy2_scalar(x0: f32, x1: f32, b: &[f32], o0: &mut [f32], o1: &mut [f32]) {
    let n = o0.len();
    let o1 = &mut o1[..n];
    let b = &b[..n];
    for j in 0..n {
        o0[j] += x0 * b[j];
        o1[j] += x1 * b[j];
    }
}

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let lanes = k / 8 * 8;
    let mut acc = [0.0f32; 8];
    let mut p = 0;
    while p < lanes {
        let av = &a[p..p + 8];
        let bv = &b[p..p + 8];
        for l in 0..8 {
            acc[l] += av[l] * bv[l];
        }
        p += 8;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + (acc[4] + acc[5]) + (acc[6] + acc[7]);
    for t in lanes..k {
        s += a[t] * b[t];
    }
    s
}

fn axpy_widen_scalar(x: f64, xs: &[f32], acc: &mut [f64]) {
    for (aj, xj) in acc.iter_mut().zip(xs) {
        *aj += x * *xj as f64;
    }
}

// ---- AVX2 (x86-64, runtime-detected) ----

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    // Callers guarantee AVX2 is available (dispatch only selects this
    // module after `is_x86_feature_detected!("avx2")`). All loads/stores
    // are unaligned and bounded by the slice lengths checked below.

    /// # Safety
    /// Caller must have runtime-detected AVX2; unaligned
    /// loads/stores are bounded by `o.len()` with `b.len() >= o.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(x: f32, b: &[f32], o: &mut [f32]) {
        let n = o.len();
        let xv = _mm256_set1_ps(x);
        let mut j = 0;
        while j + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            let ov = _mm256_loadu_ps(o.as_ptr().add(j));
            _mm256_storeu_ps(o.as_mut_ptr().add(j), _mm256_add_ps(ov, _mm256_mul_ps(xv, bv)));
            j += 8;
        }
        while j < n {
            o[j] += x * b[j];
            j += 1;
        }
    }

    /// # Safety
    /// Caller must have runtime-detected AVX2; unaligned
    /// loads/stores are bounded by `o0.len()` with `o1` the same length
    /// and `b` at least as long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy2(x0: f32, x1: f32, b: &[f32], o0: &mut [f32], o1: &mut [f32]) {
        let n = o0.len();
        let x0v = _mm256_set1_ps(x0);
        let x1v = _mm256_set1_ps(x1);
        let mut j = 0;
        while j + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            let o0v = _mm256_loadu_ps(o0.as_ptr().add(j));
            let o1v = _mm256_loadu_ps(o1.as_ptr().add(j));
            _mm256_storeu_ps(o0.as_mut_ptr().add(j), _mm256_add_ps(o0v, _mm256_mul_ps(x0v, bv)));
            _mm256_storeu_ps(o1.as_mut_ptr().add(j), _mm256_add_ps(o1v, _mm256_mul_ps(x1v, bv)));
            j += 8;
        }
        while j < n {
            o0[j] += x0 * b[j];
            o1[j] += x1 * b[j];
            j += 1;
        }
    }

    /// # Safety
    /// Caller must have runtime-detected AVX2; unaligned loads
    /// are bounded by `a.len()` with `b` at least as long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let lanes = k / 8 * 8;
        let mut accv = _mm256_setzero_ps();
        let mut p = 0;
        while p < lanes {
            let av = _mm256_loadu_ps(a.as_ptr().add(p));
            let bv = _mm256_loadu_ps(b.as_ptr().add(p));
            accv = _mm256_add_ps(accv, _mm256_mul_ps(av, bv));
            p += 8;
        }
        let mut acc = [0.0f32; 8];
        _mm256_storeu_ps(acc.as_mut_ptr(), accv);
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + (acc[4] + acc[5]) + (acc[6] + acc[7]);
        for t in lanes..k {
            s += a[t] * b[t];
        }
        s
    }

    /// # Safety
    /// Caller must have runtime-detected AVX2; unaligned
    /// loads/stores are bounded by `acc.len()` with `xs` at least as
    /// long.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_widen(x: f64, xs: &[f32], acc: &mut [f64]) {
        let n = acc.len();
        let xv = _mm256_set1_pd(x);
        let mut j = 0;
        while j + 4 <= n {
            let sv = _mm_loadu_ps(xs.as_ptr().add(j));
            let wv = _mm256_cvtps_pd(sv);
            let av = _mm256_loadu_pd(acc.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(av, _mm256_mul_pd(xv, wv)));
            j += 4;
        }
        while j < n {
            acc[j] += x * xs[j] as f64;
            j += 1;
        }
    }
}

// ---- NEON (aarch64 baseline) ----

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    // NEON is part of the aarch64 baseline; the intrinsics are still
    // `unsafe fn` in std::arch. No `vmlaq_f32` anywhere — that is a
    // fused FMLA and would break bit-parity with the scalar path.

    /// # Safety
    /// NEON is always present on aarch64; loads/stores are
    /// bounded by `o.len()` with `b.len() >= o.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(x: f32, b: &[f32], o: &mut [f32]) {
        let n = o.len();
        let xv = vdupq_n_f32(x);
        let mut j = 0;
        while j + 4 <= n {
            let bv = vld1q_f32(b.as_ptr().add(j));
            let ov = vld1q_f32(o.as_ptr().add(j));
            vst1q_f32(o.as_mut_ptr().add(j), vaddq_f32(ov, vmulq_f32(xv, bv)));
            j += 4;
        }
        while j < n {
            o[j] += x * b[j];
            j += 1;
        }
    }

    /// # Safety
    /// NEON is always present on aarch64; loads/stores are
    /// bounded by `o0.len()` with `o1` the same length and `b` at least
    /// as long.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy2(x0: f32, x1: f32, b: &[f32], o0: &mut [f32], o1: &mut [f32]) {
        let n = o0.len();
        let x0v = vdupq_n_f32(x0);
        let x1v = vdupq_n_f32(x1);
        let mut j = 0;
        while j + 4 <= n {
            let bv = vld1q_f32(b.as_ptr().add(j));
            let o0v = vld1q_f32(o0.as_ptr().add(j));
            let o1v = vld1q_f32(o1.as_ptr().add(j));
            vst1q_f32(o0.as_mut_ptr().add(j), vaddq_f32(o0v, vmulq_f32(x0v, bv)));
            vst1q_f32(o1.as_mut_ptr().add(j), vaddq_f32(o1v, vmulq_f32(x1v, bv)));
            j += 4;
        }
        while j < n {
            o0[j] += x0 * b[j];
            o1[j] += x1 * b[j];
            j += 1;
        }
    }

    /// # Safety
    /// NEON is always present on aarch64; loads are bounded by
    /// `a.len()` with `b` at least as long.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let lanes = k / 8 * 8;
        // lanes 0..4 and 4..8 of the scalar tile live in two registers
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut p = 0;
        while p < lanes {
            let a0 = vld1q_f32(a.as_ptr().add(p));
            let b0 = vld1q_f32(b.as_ptr().add(p));
            let a1 = vld1q_f32(a.as_ptr().add(p + 4));
            let b1 = vld1q_f32(b.as_ptr().add(p + 4));
            lo = vaddq_f32(lo, vmulq_f32(a0, b0));
            hi = vaddq_f32(hi, vmulq_f32(a1, b1));
            p += 8;
        }
        let mut acc = [0.0f32; 8];
        vst1q_f32(acc.as_mut_ptr(), lo);
        vst1q_f32(acc.as_mut_ptr().add(4), hi);
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + (acc[4] + acc[5]) + (acc[6] + acc[7]);
        for t in lanes..k {
            s += a[t] * b[t];
        }
        s
    }

    /// # Safety
    /// NEON is always present on aarch64; loads/stores are
    /// bounded by `acc.len()` with `xs` at least as long.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_widen(x: f64, xs: &[f32], acc: &mut [f64]) {
        let n = acc.len();
        let xv = vdupq_n_f64(x);
        let mut j = 0;
        while j + 4 <= n {
            let sv = vld1q_f32(xs.as_ptr().add(j));
            let wlo = vcvt_f64_f32(vget_low_f32(sv));
            let whi = vcvt_f64_f32(vget_high_f32(sv));
            let a0 = vld1q_f64(acc.as_ptr().add(j));
            let a1 = vld1q_f64(acc.as_ptr().add(j + 2));
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a0, vmulq_f64(xv, wlo)));
            vst1q_f64(acc.as_mut_ptr().add(j + 2), vaddq_f64(a1, vmulq_f64(xv, whi)));
            j += 4;
        }
        while j < n {
            acc[j] += x * xs[j] as f64;
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    // `force` is process-global, so tests that flip it serialize on this
    // lock (results are mode-invariant by design, but `kernel_name`
    // assertions are not).
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    // On a machine with a vector unit these pin native against scalar
    // bit-for-bit; on anything else both paths are the scalar fallback
    // and the tests are trivially green (the differential suite in
    // tests/parallel_kernels.rs covers the full kernels either way).

    #[test]
    fn axpy_native_matches_scalar_bitwise() {
        let _g = guard();
        let mut rng = Rng::new(71);
        for n in [0usize, 1, 3, 7, 8, 9, 31, 64, 257] {
            let x = rng.normal() as f32;
            let b = rand_vec(&mut rng, n);
            let base = rand_vec(&mut rng, n);
            let mut want = base.clone();
            axpy_scalar(x, &b, &mut want);
            let mut got = base.clone();
            force("native");
            axpy(x, &b, &mut got);
            force("scalar");
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn axpy2_native_matches_scalar_bitwise() {
        let _g = guard();
        let mut rng = Rng::new(73);
        for n in [1usize, 5, 8, 17, 100] {
            let (x0, x1) = (rng.normal() as f32, rng.normal() as f32);
            let b = rand_vec(&mut rng, n);
            let base0 = rand_vec(&mut rng, n);
            let base1 = rand_vec(&mut rng, n);
            let (mut w0, mut w1) = (base0.clone(), base1.clone());
            axpy2_scalar(x0, x1, &b, &mut w0, &mut w1);
            let (mut g0, mut g1) = (base0.clone(), base1.clone());
            force("native");
            axpy2(x0, x1, &b, &mut g0, &mut g1);
            force("scalar");
            assert_eq!((g0, g1), (w0, w1), "n={n}");
        }
    }

    #[test]
    fn dot_native_matches_scalar_bitwise() {
        let _g = guard();
        let mut rng = Rng::new(79);
        for n in [0usize, 1, 7, 8, 9, 16, 63, 64, 65, 300] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let want = dot_scalar(&a, &b);
            force("native");
            let got = dot(&a, &b);
            force("scalar");
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn axpy_widen_native_matches_scalar_bitwise() {
        let _g = guard();
        let mut rng = Rng::new(83);
        for n in [0usize, 1, 3, 4, 5, 13, 64, 201] {
            let x = rng.normal();
            let xs = rand_vec(&mut rng, n);
            let base: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = base.clone();
            axpy_widen_scalar(x, &xs, &mut want);
            let mut got = base.clone();
            force("native");
            axpy_widen(x, &xs, &mut got);
            force("scalar");
            let wb: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "n={n}");
        }
    }

    #[test]
    fn dot_propagates_nan() {
        let _g = guard();
        for m in ["scalar", "native"] {
            force(m);
            let mut a = vec![0.0f32; 12];
            let b = vec![1.0f32; 12];
            a[10] = f32::NAN; // lands in the scalar tail for k=12
            assert!(dot(&a, &b).is_nan(), "{m}");
            let mut a2 = vec![0.0f32; 12];
            a2[2] = f32::NAN; // lands in the vector body
            assert!(dot(&a2, &b).is_nan(), "{m}");
        }
        force("native");
    }

    #[test]
    fn kernel_name_is_consistent() {
        let _g = guard();
        force("scalar");
        assert_eq!(kernel_name(), "scalar");
        force("native");
        let n = kernel_name();
        assert!(n == "avx2" || n == "neon" || n == "scalar");
    }
}
