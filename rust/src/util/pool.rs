//! Scoped thread pool — substrate replacing `rayon` for the coordinator's
//! parallel worker execution (Stage 1/2/4 per-process work) and for the
//! blocked linalg kernels (`linalg::mat`, `runtime::native::kernels`).
//!
//! The linalg hot paths go through [`global`], a process-wide pool sized
//! from `SPNGD_THREADS` (default: available parallelism), and its chunked
//! [`Pool::parallel_for`] / [`Pool::parallel_for_mut`] scope APIs. Both
//! let tasks borrow caller stack data: the calling thread participates in
//! the work and blocks until every chunk has run, so borrows outlive all
//! jobs. A call made from inside a pool worker runs serially instead of
//! re-entering the queue — nested parallelism can neither deadlock nor
//! oversubscribe.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::util::obs::{self, Cat};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// FIFO job queue. Submission order is preserved (a LIFO here makes
    /// scoped waits straggle: large tail chunks would run last).
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    outstanding: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
    /// Sticky flag: some submitted job panicked. `wait` re-raises it so
    /// `submit`/`for_each` callers never see silent partial results.
    job_panicked: AtomicBool,
}

thread_local! {
    /// True on pool worker threads — used to serialize nested
    /// `parallel_for` calls instead of deadlocking on the queue.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A fixed-size thread pool with a `scope`-style parallel-for.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            outstanding: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            job_panicked: AtomicBool::new(false),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("spngd-pool-{i}"))
                    .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        let job = {
                            let mut q = sh.queue.lock().unwrap();
                            loop {
                                if let Some(j) = q.pop_front() {
                                    break Some(j);
                                }
                                if *sh.shutdown.lock().unwrap() {
                                    break None;
                                }
                                q = sh.cv.wait(q).unwrap();
                            }
                        };
                        match job {
                            Some(j) => {
                                // isolate panics: a panicking job must not kill
                                // the worker or leak the outstanding count
                                // (parallel_for re-raises via its latch flag,
                                // submit/for_each via wait's sticky flag)
                                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j));
                                if r.is_err() {
                                    sh.job_panicked.store(true, Ordering::Relaxed);
                                }
                                if sh.outstanding.fetch_sub(1, Ordering::AcqRel) == 1 {
                                    let _g = sh.done_mx.lock().unwrap();
                                    sh.done_cv.notify_all();
                                }
                            }
                            None => return,
                        }
                    }
                })
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { shared, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; does not wait.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.outstanding.fetch_add(1, Ordering::AcqRel);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Wait until every submitted job has completed. Panics if any job
    /// panicked since the last wait — a failed job must not read as
    /// success.
    pub fn wait(&self) {
        let mut g = self.shared.done_mx.lock().unwrap();
        while self.shared.outstanding.load(Ordering::Acquire) != 0 {
            g = self.shared.done_cv.wait(g).unwrap();
        }
        drop(g);
        if self.shared.job_panicked.swap(false, Ordering::Relaxed) {
            panic!("a pool job panicked");
        }
    }

    /// Run `f(i)` for i in 0..n across the pool and wait. `f` may borrow
    /// stack data (scoped via std::thread::scope semantics replicated with
    /// unsafe-free Arc: we require 'static by boxing a clone-per-task of an
    /// Arc'd closure).
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        for i in 0..n {
            let f = f.clone();
            self.submit(move || f(i));
        }
        self.wait();
    }

    /// Chunked parallel-for over `0..n`: splits the index range into
    /// contiguous chunks of `grain` items (the last may be short) and runs
    /// `f(start, end)` on each across the pool. `f` may borrow caller
    /// stack data — the call blocks until every chunk has run. The calling
    /// thread claims chunks too, so the loop completes even when all
    /// workers are busy; calls from inside a pool worker run serially.
    pub fn parallel_for<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let nchunks = n.div_ceil(grain);
        if nchunks <= 1 || self.size <= 1 || IN_POOL_WORKER.with(|w| w.get()) {
            f(0, n);
            return;
        }
        let _s = obs::span("parallel_for", Cat::Pool).arg("n", n as f64);
        let work = ForWork { f: &f, next: AtomicUsize::new(0), n, grain, nchunks };
        let helpers = self.size.min(nchunks - 1);
        let latch = Arc::new(Latch::new(helpers));
        // SAFETY: the pointer round-trip erases the borrow of `work` (and
        // of everything `f` captures) so the jobs can be 'static. Every
        // helper counts its latch down before the wait below returns —
        // `CountGuard` guarantees that even if `f` panics, and `WaitGuard`
        // keeps the frame alive through the wait even if the calling
        // thread's own chunk loop panics — so no job dereferences a dead
        // pointer. `F: Sync` makes the shared `&F` sound across threads.
        let wp = &work as *const ForWork<'_, F> as usize;
        for _ in 0..helpers {
            let guard = CountGuard(latch.clone());
            self.submit(move || {
                // SAFETY: `wp` is the erased `&work` from the enclosing
                // frame; the latch discipline above keeps that frame alive
                // until every helper has finished with it.
                let w = unsafe { &*(wp as *const ForWork<'_, F>) };
                let run = std::panic::AssertUnwindSafe(|| w.run());
                if std::panic::catch_unwind(run).is_err() {
                    guard.0.panicked.store(true, Ordering::Relaxed);
                }
            });
        }
        let wait_guard = WaitGuard(&latch);
        work.run();
        drop(wait_guard);
        assert!(!latch.panicked.load(Ordering::Relaxed), "a parallel_for worker panicked");
    }

    /// Split `data` into contiguous chunks of `chunk` elements (the last
    /// may be short) and run `f(chunk_index, chunk_slice)` across the
    /// pool. The chunks are disjoint `&mut` views, so each invocation may
    /// write freely; the call blocks until every chunk has run.
    pub fn parallel_for_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let len = data.len();
        if len == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let nchunks = len.div_ceil(chunk);
        let base = data.as_mut_ptr() as usize;
        self.parallel_for(nchunks, 1, |c0, c1| {
            for c in c0..c1 {
                let s = c * chunk;
                let e = (s + chunk).min(len);
                // SAFETY: chunk index `c` is claimed by exactly one task,
                // ranges [s, e) are pairwise disjoint across indices, and
                // parallel_for joins before `data`'s borrow ends — so each
                // reconstructed slice is a unique &mut view.
                let sl = unsafe { std::slice::from_raw_parts_mut((base as *mut T).add(s), e - s) };
                f(c, sl);
            }
        });
    }
}

/// Shared state of one `parallel_for` call: chunk cursor + the borrowed
/// body. Claimed chunk-by-chunk via an atomic, so load imbalance between
/// chunks self-schedules.
struct ForWork<'a, F: Fn(usize, usize) + Sync> {
    f: &'a F,
    next: AtomicUsize,
    n: usize,
    grain: usize,
    nchunks: usize,
}

impl<F: Fn(usize, usize) + Sync> ForWork<'_, F> {
    fn run(&self) {
        loop {
            let c = self.next.fetch_add(1, Ordering::Relaxed);
            if c >= self.nchunks {
                return;
            }
            let s = c * self.grain;
            let e = (s + self.grain).min(self.n);
            (self.f)(s, e);
        }
    }
}

/// Per-call completion latch: `parallel_for` waits on its own latch (not
/// the pool-wide outstanding counter) so concurrent scoped calls from
/// different threads never wait on each other's jobs. `panicked` carries
/// a helper's panic back to the calling thread.
struct Latch {
    mx: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { mx: Mutex::new(n), cv: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn count_down(&self) {
        let mut g = self.mx.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.mx.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Counts its latch down when dropped — a helper job holds one so the
/// count happens even if the job body panics.
struct CountGuard(Arc<Latch>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// Waits on the latch when dropped — the `parallel_for` caller holds one
/// so the borrowed chunk state stays alive past every helper even if its
/// own chunk loop panics mid-unwind.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            // Hold the queue lock while raising the flag: a worker holds it
            // from its empty-pop through the shutdown check to cv.wait, so
            // this ordering makes the notify impossible to miss.
            let _q = self.shared.queue.lock().unwrap();
            *self.shared.shutdown.lock().unwrap() = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Thread count for the process-wide pool: `SPNGD_THREADS` if set to a
/// positive integer, otherwise the machine's available parallelism.
pub fn configured_threads() -> usize {
    if let Ok(v) = std::env::var("SPNGD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool the linalg hot paths run on. Created on first
/// use with [`configured_threads`] threads; `SPNGD_THREADS=1` forces the
/// whole training path serial.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(configured_threads()))
}

/// Scoped parallel map over indices using std::thread::scope — for cases
/// where tasks must borrow from the caller's stack. Spawns min(n, threads)
/// OS threads; fine for the coordinator's per-step fan-out granularity.
pub fn scoped_for_each<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..threads {
            std::thread::Builder::new()
                .name(format!("spngd-scoped-{t}"))
                .spawn_scoped(s, || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    f(i);
                })
                .expect("spawn scoped worker");
        }
    });
}

/// Scoped parallel map collecting results in order.
pub fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Send + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        let next = AtomicUsize::new(0);
        let threads = threads.max(1).min(n.max(1));
        std::thread::scope(|s| {
            for t in 0..threads {
                std::thread::Builder::new()
                    .name(format!("spngd-scoped-{t}"))
                    .spawn_scoped(s, || loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return;
                        }
                        let v = f(i);
                        **slots[i].lock().unwrap() = Some(v);
                    })
                    .expect("spawn scoped worker");
            }
        });
    }
    out.into_iter().map(|o| o.expect("scoped_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn queue_is_fifo() {
        // With a single worker, execution order must equal submission
        // order — the regression test for the old LIFO Vec queue.
        let pool = Pool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..64 {
            let o = order.clone();
            pool.submit(move || {
                o.lock().unwrap().push(i);
            });
        }
        pool.wait();
        assert_eq!(*order.lock().unwrap(), (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_covers_indices() {
        let pool = Pool::new(3);
        let hits = Arc::new(Mutex::new(vec![0u8; 50]));
        let h = hits.clone();
        pool.for_each(50, move |i| {
            h.lock().unwrap()[i] += 1;
        });
        assert!(hits.lock().unwrap().iter().all(|&x| x == 1));
    }

    #[test]
    fn parallel_for_covers_range_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU64> = (0..103).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(103, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_borrows_stack() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        pool.parallel_for(data.len(), 13, |s, e| {
            let part: u64 = data[s..e].iter().sum();
            sum.fetch_add(part, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..1000).sum::<u64>());
    }

    #[test]
    fn parallel_for_mut_chunks_disjoint() {
        let pool = Pool::new(4);
        let mut data = vec![0usize; 101];
        pool.parallel_for_mut(&mut data, 8, |ci, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = ci * 8 + k;
            }
        });
        let want: Vec<usize> = (0..101).collect();
        assert_eq!(data, want);
    }

    #[test]
    #[should_panic(expected = "a pool job panicked")]
    fn wait_surfaces_submitted_job_panic() {
        let pool = Pool::new(1);
        pool.submit(|| panic!("boom"));
        pool.wait();
    }

    #[test]
    #[should_panic]
    fn parallel_for_surfaces_panics_instead_of_hanging() {
        // whichever thread hits the bad chunk, the call must panic (via
        // direct unwind or the latch flag), never deadlock or corrupt
        let pool = Pool::new(2);
        pool.parallel_for(64, 1, |s, _| {
            if s >= 32 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn parallel_for_nested_runs_serially() {
        // A parallel_for issued from inside a pool job must not deadlock.
        let pool = Arc::new(Pool::new(1));
        let done = Arc::new(AtomicU64::new(0));
        let (p, d) = (pool.clone(), done.clone());
        pool.submit(move || {
            p.parallel_for(32, 4, |s, e| {
                d.fetch_add((e - s) as u64, Ordering::Relaxed);
            });
        });
        pool.wait();
        assert_eq!(done.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn global_pool_is_usable() {
        let sum = AtomicU64::new(0);
        global().parallel_for(100, 9, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
        assert!(global().size() >= 1);
    }

    #[test]
    fn scoped_map_ordered() {
        let v = scoped_map(4, 20, |i| i * i);
        assert_eq!(v, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_for_each_borrows_stack() {
        let data: Vec<u64> = (0..32).collect();
        let sum = AtomicU64::new(0);
        scoped_for_each(4, data.len(), |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..32).sum::<u64>());
    }

    #[test]
    fn pool_reuse_across_batches() {
        let pool = Pool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let cc = c.clone();
            pool.for_each(10, move |_| {
                cc.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(c.load(Ordering::Relaxed), 50);
    }
}
