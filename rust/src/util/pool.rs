//! Scoped thread pool — substrate replacing `rayon` for the coordinator's
//! parallel worker execution (Stage 1/2/4 per-process work).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    outstanding: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// A fixed-size thread pool with a `scope`-style parallel-for.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl Pool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            outstanding: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..size)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut q = sh.queue.lock().unwrap();
                        loop {
                            if let Some(j) = q.pop() {
                                break Some(j);
                            }
                            if *sh.shutdown.lock().unwrap() {
                                break None;
                            }
                            q = sh.cv.wait(q).unwrap();
                        }
                    };
                    match job {
                        Some(j) => {
                            j();
                            if sh.outstanding.fetch_sub(1, Ordering::AcqRel) == 1 {
                                let _g = sh.done_mx.lock().unwrap();
                                sh.done_cv.notify_all();
                            }
                        }
                        None => return,
                    }
                })
            })
            .collect();
        Pool { shared, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; does not wait.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.outstanding.fetch_add(1, Ordering::AcqRel);
        self.shared.queue.lock().unwrap().push(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Wait until every submitted job has completed.
    pub fn wait(&self) {
        let mut g = self.shared.done_mx.lock().unwrap();
        while self.shared.outstanding.load(Ordering::Acquire) != 0 {
            g = self.shared.done_cv.wait(g).unwrap();
        }
    }

    /// Run `f(i)` for i in 0..n across the pool and wait. `f` may borrow
    /// stack data (scoped via std::thread::scope semantics replicated with
    /// unsafe-free Arc: we require 'static by boxing a clone-per-task of an
    /// Arc'd closure).
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        for i in 0..n {
            let f = f.clone();
            self.submit(move || f(i));
        }
        self.wait();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped parallel map over indices using std::thread::scope — for cases
/// where tasks must borrow from the caller's stack. Spawns min(n, threads)
/// OS threads; fine for the coordinator's per-step fan-out granularity.
pub fn scoped_for_each<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

/// Scoped parallel map collecting results in order.
pub fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Send + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        let next = AtomicUsize::new(0);
        let threads = threads.max(1).min(n.max(1));
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let v = f(i);
                    **slots[i].lock().unwrap() = Some(v);
                });
            }
        });
    }
    out.into_iter().map(|o| o.expect("scoped_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn for_each_covers_indices() {
        let pool = Pool::new(3);
        let hits = Arc::new(Mutex::new(vec![0u8; 50]));
        let h = hits.clone();
        pool.for_each(50, move |i| {
            h.lock().unwrap()[i] += 1;
        });
        assert!(hits.lock().unwrap().iter().all(|&x| x == 1));
    }

    #[test]
    fn scoped_map_ordered() {
        let v = scoped_map(4, 20, |i| i * i);
        assert_eq!(v, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_for_each_borrows_stack() {
        let data: Vec<u64> = (0..32).collect();
        let sum = AtomicU64::new(0);
        scoped_for_each(4, data.len(), |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..32).sum::<u64>());
    }

    #[test]
    fn pool_reuse_across_batches() {
        let pool = Pool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let cc = c.clone();
            pool.for_each(10, move |_| {
                cc.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(c.load(Ordering::Relaxed), 50);
    }
}
