//! Minimal CLI argument parser — substrate replacing `clap`.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates `--help` text from registered options.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set for one (sub)command.
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Register an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register a required option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Register a boolean flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let tail = if o.is_flag {
                String::new()
            } else {
                match &o.default {
                    Some(d) => format!(" <value> (default: {d})"),
                    None => " <value> (required)".to_string(),
                }
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, tail, o.help));
        }
        s
    }

    /// Parse a token list (without argv[0]).
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?
                    .clone();
                let val = if opt.is_flag {
                    match inline_val {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                        }
                    }
                };
                self.values.insert(key, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // fill defaults, check required
        for o in &self.opts {
            if !self.values.contains_key(&o.name) {
                match &o.default {
                    Some(d) => {
                        self.values.insert(o.name.clone(), d.clone());
                    }
                    None => return Err(format!("missing required option --{}", o.name)),
                }
            }
        }
        Ok(Parsed { values: self.values, positionals: self.positionals })
    }

    /// Parse from the process environment (skipping argv[0] and a subcommand).
    pub fn parse_env(self, skip: usize) -> Result<Parsed, String> {
        let argv: Vec<String> = std::env::args().skip(skip).collect();
        self.parse(&argv)
    }
}

/// Parsed argument values with typed accessors.
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not registered"))
    }
    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an unsigned integer"))
    }
    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an unsigned integer"))
    }
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} expects a number"))
    }
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes" | "on")
    }
    /// Comma-separated list of usizes, e.g. "1,2,4,8".
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        let s = self.get(name);
        if s.is_empty() {
            return Vec::new();
        }
        s.split(',')
            .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad list item '{t}'")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("steps", "100", "number of steps")
            .opt("lr", "0.1", "learning rate")
            .flag("verbose", "verbose output")
            .parse(&argv(&["--steps", "250", "--verbose"]))
            .unwrap();
        assert_eq!(p.get_usize("steps"), 250);
        assert_eq!(p.get_f64("lr"), 0.1);
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_positionals() {
        let p = Args::new("t", "test")
            .opt("mode", "a", "mode")
            .parse(&argv(&["run", "--mode=b", "extra"]))
            .unwrap();
        assert_eq!(p.get("mode"), "b");
        assert_eq!(p.positionals, vec!["run", "extra"]);
    }

    #[test]
    fn required_missing() {
        let r = Args::new("t", "test").req("out", "output").parse(&argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t", "test").parse(&argv(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn list_parsing() {
        let p = Args::new("t", "t")
            .opt("gpus", "1,2,4", "gpu counts")
            .parse(&argv(&[]))
            .unwrap();
        assert_eq!(p.get_usize_list("gpus"), vec![1, 2, 4]);
    }

    #[test]
    fn help_is_err_with_usage() {
        let r = Args::new("prog", "about").opt("x", "1", "the x").parse(&argv(&["--help"]));
        let msg = r.err().unwrap();
        assert!(msg.contains("prog"));
        assert!(msg.contains("--x"));
    }
}
