//! Parser for the JSONL structured-event stream (`--events-out` /
//! `SPNGD_EVENTS`).
//!
//! The *write* side stays in [`crate::util::obs`] (emission is tangled
//! with the span/trace machinery and its process-global switches); the
//! *read* side lives here as a standalone structured-error parser
//! module, scoped under the lint's panic-hygiene rule: parse-or-skip,
//! never panic, no bare indexing. `obs` re-exports these names, so
//! `obs::parse_line` callers are unaffected.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Schema tag stamped on every emitted event line. `/2` added the
/// checkpoint lifecycle kinds (`checkpoint_saved`, `resumed`) — a pure
/// extension, so readers accept every tag in [`EVENT_SCHEMAS`].
pub const EVENT_SCHEMA: &str = "spngd-events/2";

/// Schema tags [`parse_line`] accepts: the current one plus every older
/// tag whose envelope it still reads.
pub const EVENT_SCHEMAS: &[&str] = &["spngd-events/1", "spngd-events/2"];

/// One parsed event line.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRec {
    pub seq: usize,
    pub t: f64,
    pub kind: String,
    pub fields: BTreeMap<String, Json>,
}

impl EventRec {
    /// Field accessor (`Json::Null` for missing keys).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.fields.get(key).unwrap_or(&NULL)
    }
}

/// Parse one JSONL event line. **Parse-or-skip**: returns `None` on
/// malformed JSON, wrong/missing schema tag, missing `kind`/`t`, or an
/// oversized line (> 1 MiB — a corrupt stream, not a real event). Never
/// panics on any byte input (fuzzed in `tests/fuzz_smoke.rs`).
pub fn parse_line(line: &str) -> Option<EventRec> {
    let line = line.trim();
    if line.is_empty() || line.len() > 1 << 20 {
        return None;
    }
    let v = Json::parse(line).ok()?;
    let o = v.as_obj()?;
    match v.get("schema").as_str() {
        Some(s) if EVENT_SCHEMAS.contains(&s) => {}
        _ => return None,
    }
    let kind = v.get("kind").as_str()?.to_string();
    let t = v.get("t").as_f64()?;
    let seq = v.get("seq").as_usize().unwrap_or(0);
    let mut fields = o.clone();
    for k in ["schema", "seq", "t", "kind"] {
        fields.remove(k);
    }
    Some(EventRec { seq, t, kind, fields })
}

/// Read every well-formed event from a JSONL file, skipping garbage
/// lines silently.
pub fn read_events(path: &Path) -> std::io::Result<Vec<EventRec>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text.lines().filter_map(parse_line).collect())
}
