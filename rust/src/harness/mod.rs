//! Shared harness for examples and benches: runtime/backend selection,
//! trainer construction with sensible defaults, and a tiny bench timer
//! (criterion replacement — criterion is not available offline).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{DistMode, TrainerBuilder};
use crate::data;
use crate::optim::{self, Preconditioner};
use crate::runtime::{native, Executor, Manifest};
use crate::util::stats::Summary;

/// Locate `artifacts/` relative to the crate root (PJRT backend only).
pub fn artifacts_dir() -> Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    Ok(dir)
}

/// Load the default runtime: the native CPU backend, or — when the
/// `SPNGD_BACKEND=pjrt` environment variable is set — the PJRT engine
/// over the AOT artifacts (requires the `pjrt` cargo feature).
pub fn load_runtime() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    match std::env::var("SPNGD_BACKEND") {
        Ok(b) if b == "pjrt" => load_runtime_pjrt(),
        Ok(b) if !b.is_empty() && b != "native" => {
            anyhow::bail!("unknown SPNGD_BACKEND '{b}' (expected native | pjrt)")
        }
        _ => load_runtime_native(),
    }
}

/// The hermetic native CPU runtime (default model set).
pub fn load_runtime_native() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    let (manifest, backend) = native::build_default()?;
    Ok((Arc::new(manifest), Arc::new(backend) as Arc<dyn Executor>))
}

/// The PJRT runtime over the crate-root `artifacts/` (feature `pjrt`).
#[cfg(feature = "pjrt")]
pub fn load_runtime_pjrt() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    load_runtime_pjrt_at(&artifacts_dir()?)
}

/// The PJRT runtime over the crate-root `artifacts/` (feature `pjrt`).
#[cfg(not(feature = "pjrt"))]
pub fn load_runtime_pjrt() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    load_runtime_pjrt_at(std::path::Path::new("artifacts"))
}

/// The PJRT runtime over an explicit artifact directory.
#[cfg(feature = "pjrt")]
pub fn load_runtime_pjrt_at(dir: &std::path::Path) -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no manifest in {} — run `make artifacts` first",
        dir.display()
    );
    let manifest = Arc::new(Manifest::load(dir)?);
    let engine = Arc::new(crate::runtime::Engine::new(&manifest)?);
    Ok((manifest, engine as Arc<dyn Executor>))
}

#[cfg(not(feature = "pjrt"))]
pub fn load_runtime_pjrt_at(dir: &std::path::Path) -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    let _ = dir;
    anyhow::bail!("this build has no PJRT support — rebuild with `--features pjrt`")
}

/// Worker count for examples/benches: `SPNGD_WORKERS` if set to a
/// positive integer, otherwise 2.
pub fn configured_workers() -> usize {
    if let Ok(v) = std::env::var("SPNGD_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    2
}

/// The optimizer selected by `SPNGD_OPTIM` (registry name; default
/// `spngd`). Unknown names are a hard error listing the valid choices —
/// the CI matrix runs the suite once per registered optimizer through
/// this hook.
pub fn env_optimizer() -> Result<Arc<dyn Preconditioner>> {
    match std::env::var("SPNGD_OPTIM") {
        Ok(v) if !v.trim().is_empty() => optim::by_name(v.trim()),
        _ => Ok(optim::spngd()),
    }
}

/// The model selected by `SPNGD_MODEL` (native registry name; falls back
/// to `default`). Unknown names are a hard error listing the valid
/// choices — examples and benches resolve their model through this hook,
/// so the registry is the single source of truth.
pub fn env_model(default: &str) -> Result<String> {
    match std::env::var("SPNGD_MODEL") {
        Ok(v) if !v.trim().is_empty() => {
            let name = v.trim().to_string();
            native::model::by_name(&name)?;
            Ok(name)
        }
        _ => Ok(default.to_string()),
    }
}

/// The data source selected by `SPNGD_DATA` (registry name; `None` when
/// unset — the builder's `synth` default applies). Unknown names are a
/// hard error listing the valid choices, mirroring `SPNGD_OPTIM`.
pub fn env_data() -> Result<Option<String>> {
    match std::env::var("SPNGD_DATA") {
        Ok(v) if !v.trim().is_empty() => {
            let name = v.trim().to_string();
            data::validate_name(&name)?;
            Ok(Some(name))
        }
        _ => Ok(None),
    }
}

/// An environment-aware [`TrainerBuilder`] for examples and benches:
/// runtime from `SPNGD_BACKEND`, worker count from `SPNGD_WORKERS`, dist
/// engine from `SPNGD_DIST`, wire precision from `SPNGD_PRECISION`, data
/// source from `SPNGD_DATA` (+ `SPNGD_DATA_PATH` for disk sources;
/// prefetch from `SPNGD_PREFETCH` inside the loader), schedule defaulted
/// from the optimizer's [`Preconditioner::default_hparams`] (so adding
/// an optimizer or a data source never edits the harness).
pub fn builder(model: &str, opt: Arc<dyn Preconditioner>) -> Result<TrainerBuilder> {
    let (manifest, engine) = load_runtime()?;
    let mut b = TrainerBuilder::new(model)
        .runtime(manifest, engine)
        .optimizer(opt)
        .workers(configured_workers())
        .precision(crate::collectives::comm::Precision::from_env())
        .dist(DistMode::from_env());
    if let Some(name) = env_data()? {
        b = b.data(&name);
    }
    if let Ok(path) = std::env::var("SPNGD_DATA_PATH") {
        if !path.trim().is_empty() {
            b = b.data_path(path.trim());
        }
    }
    Ok(b)
}

/// Minimal bench runner: warmup + timed iterations, prints a stats row.
/// Returns the per-iteration summary (seconds).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        // lint:allow(determinism) -- bench timing is the harness's entire job
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "bench {name:<40} mean {:>12} ± {:>10}  p50 {:>12}  n={}",
        crate::util::stats::fmt_duration(s.mean()),
        crate::util::stats::fmt_duration(s.stddev()),
        crate::util::stats::fmt_duration(s.median()),
        s.len()
    );
    s
}
