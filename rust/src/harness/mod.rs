//! Shared harness for examples and benches: runtime/backend selection,
//! trainer construction with sensible defaults, and a tiny bench timer
//! (criterion replacement — criterion is not available offline).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{BnMode, DistMode, Fisher, Optim, Trainer, TrainerCfg};
use crate::data::{AugmentCfg, SynthDataset};
use crate::optim::{HyperParams, Schedule};
use crate::runtime::{native, Executor, Manifest};
use crate::util::stats::Summary;

/// Locate `artifacts/` relative to the crate root (PJRT backend only).
pub fn artifacts_dir() -> Result<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    Ok(dir)
}

/// Load the default runtime: the native CPU backend, or — when the
/// `SPNGD_BACKEND=pjrt` environment variable is set — the PJRT engine
/// over the AOT artifacts (requires the `pjrt` cargo feature).
pub fn load_runtime() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    match std::env::var("SPNGD_BACKEND") {
        Ok(b) if b == "pjrt" => load_runtime_pjrt(),
        Ok(b) if !b.is_empty() && b != "native" => {
            anyhow::bail!("unknown SPNGD_BACKEND '{b}' (expected native | pjrt)")
        }
        _ => load_runtime_native(),
    }
}

/// The hermetic native CPU runtime (default model set).
pub fn load_runtime_native() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    let (manifest, backend) = native::build_default()?;
    Ok((Arc::new(manifest), Arc::new(backend) as Arc<dyn Executor>))
}

/// The PJRT runtime over the crate-root `artifacts/` (feature `pjrt`).
#[cfg(feature = "pjrt")]
pub fn load_runtime_pjrt() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    load_runtime_pjrt_at(&artifacts_dir()?)
}

/// The PJRT runtime over the crate-root `artifacts/` (feature `pjrt`).
#[cfg(not(feature = "pjrt"))]
pub fn load_runtime_pjrt() -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    load_runtime_pjrt_at(std::path::Path::new("artifacts"))
}

/// The PJRT runtime over an explicit artifact directory.
#[cfg(feature = "pjrt")]
pub fn load_runtime_pjrt_at(dir: &std::path::Path) -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no manifest in {} — run `make artifacts` first",
        dir.display()
    );
    let manifest = Arc::new(Manifest::load(dir)?);
    let engine = Arc::new(crate::runtime::Engine::new(&manifest)?);
    Ok((manifest, engine as Arc<dyn Executor>))
}

#[cfg(not(feature = "pjrt"))]
pub fn load_runtime_pjrt_at(dir: &std::path::Path) -> Result<(Arc<Manifest>, Arc<dyn Executor>)> {
    let _ = dir;
    anyhow::bail!("this build has no PJRT support — rebuild with `--features pjrt`")
}

/// Default hyperparameters for short synthetic-corpus runs.
pub fn default_hp(optimizer: Optim) -> HyperParams {
    HyperParams {
        alpha_mixup: 0.0,
        p_decay: 3.5,
        e_start: 2.0,
        e_end: 60.0,
        eta0: if optimizer == Optim::Sgd { 0.05 } else { 0.02 },
        m0: if optimizer == Optim::Sgd { 0.045 } else { 0.018 },
        lambda: 2.5e-3,
    }
}

/// Worker count for examples/benches: `SPNGD_WORKERS` if set to a
/// positive integer, otherwise 2.
pub fn configured_workers() -> usize {
    if let Ok(v) = std::env::var("SPNGD_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    2
}

/// Default trainer config for a model/optimizer pair. `SPNGD_WORKERS`
/// sets the worker count and `SPNGD_DIST=threads` selects the threaded
/// dist engine (one OS thread per worker).
pub fn default_cfg(model: &str, optimizer: Optim) -> TrainerCfg {
    let hp = default_hp(optimizer);
    TrainerCfg {
        model: model.to_string(),
        workers: configured_workers(),
        grad_accum: 1,
        fisher: Fisher::Emp,
        bn_mode: BnMode::Unit,
        stale: false,
        stale_alpha: 0.1,
        lambda: hp.lambda,
        schedule: Schedule::new(hp, 64),
        optimizer,
        weight_rescale: false,
        clip_update_ratio: 0.3,
        augment: AugmentCfg::disabled(),
        bn_momentum: 0.9,
        fp16_comm: false,
        dist: DistMode::from_env(),
        seed: 7,
    }
}

/// Build a trainer with a dataset matched to the model's input shape.
pub fn make_trainer(cfg: TrainerCfg, dataset_len: usize, seed: u64) -> Result<Trainer> {
    let (manifest, engine) = load_runtime()?;
    let m = manifest.model(&cfg.model).context("model lookup")?;
    let (c, h, w) = (m.input_shape[1], m.input_shape[2], m.input_shape[3]);
    let ds = SynthDataset::new(m.num_classes, c, h, w, dataset_len, seed);
    Trainer::new(manifest, engine, cfg, ds)
}

/// Minimal bench runner: warmup + timed iterations, prints a stats row.
/// Returns the per-iteration summary (seconds).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "bench {name:<40} mean {:>12} ± {:>10}  p50 {:>12}  n={}",
        crate::util::stats::fmt_duration(s.mean()),
        crate::util::stats::fmt_duration(s.stddev()),
        crate::util::stats::fmt_duration(s.median()),
        s.len()
    );
    s
}
