//! Cluster simulator: replays measured coordinator work profiles through
//! the α-β cost model at arbitrary GPU counts, with the paper's technique
//! toggles (1mc/emp × fullBN/unitBN × ±stale) — the Fig. 5 generator.

use crate::collectives::cost::{predict_step_time, ClusterModel, StepProfile};

/// A named technique variant derived from a measured base profile.
#[derive(Clone, Debug)]
pub struct Variant {
    pub label: String,
    pub profile: StepProfile,
}

/// Technique toggles applied to a measured `emp+unitBN` base profile.
#[derive(Clone, Copy, Debug)]
pub struct Technique {
    /// 1mc Fisher: adds the extra backward pass
    pub one_mc: bool,
    /// full (2C)² BN Fisher instead of unit-wise
    pub full_bn: bool,
    /// stale-statistics scheduler active
    pub stale: bool,
}

impl Technique {
    pub fn label(&self) -> String {
        format!(
            "{}+{}{}",
            if self.one_mc { "1mc" } else { "emp" },
            if self.full_bn { "fullBN" } else { "unitBN" },
            if self.stale { "+stale" } else { "" }
        )
    }
}

/// Extra measured deltas needed to derive variants from the base profile.
#[derive(Clone, Debug, Default)]
pub struct TechniqueDeltas {
    /// extra backward time for the 1mc Fisher (s)
    pub t_extra_bwd_1mc: f64,
    /// extra construction+inversion time for fullBN (s)
    pub t_full_bn_extra: f64,
    /// extra statistics bytes for fullBN vs unitBN (per GPU)
    pub full_bn_extra_bytes: f64,
    /// measured stale refresh fraction (Table 2 reduction; e.g. 0.08)
    pub stale_fraction: f64,
}

/// Derive a variant profile from the measured base (emp+unitBN, no stale).
pub fn derive(base: &StepProfile, deltas: &TechniqueDeltas, t: Technique) -> Variant {
    let mut p = base.clone();
    if t.one_mc {
        p.t_extra_bwd = deltas.t_extra_bwd_1mc;
    }
    if t.full_bn {
        p.t_inverse += deltas.t_full_bn_extra;
        p.stats_bytes += deltas.full_bn_extra_bytes;
    }
    if t.stale {
        let f = deltas.stale_fraction.clamp(0.0, 1.0);
        p.t_factors *= f;
        p.t_inverse *= f;
        p.stats_bytes *= f;
    }
    Variant { label: t.label(), profile: p }
}

/// One Fig. 5 row: time/step for each GPU count.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub label: String,
    pub points: Vec<(usize, f64)>,
}

/// Sweep all variants over the GPU counts (Fig. 5's x-axis).
pub fn sweep(variants: &[Variant], gpus: &[usize], cm: &ClusterModel) -> Vec<SweepRow> {
    variants
        .iter()
        .map(|v| SweepRow {
            label: v.label.clone(),
            points: gpus
                .iter()
                .map(|&p| (p, predict_step_time(&v.profile, p, cm)))
                .collect(),
        })
        .collect()
}

/// The six Fig. 5 technique combinations (in the paper's legend order).
pub fn fig5_techniques() -> Vec<Technique> {
    vec![
        Technique { one_mc: true, full_bn: true, stale: false },
        Technique { one_mc: true, full_bn: false, stale: false },
        Technique { one_mc: false, full_bn: true, stale: false },
        Technique { one_mc: false, full_bn: false, stale: false },
        Technique { one_mc: false, full_bn: false, stale: true },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> StepProfile {
        StepProfile {
            t_forward: 0.02,
            t_backward: 0.04,
            t_factors: 0.03,
            t_inverse: 0.12,
            t_update: 0.02,
            t_extra_bwd: 0.0,
            stats_bytes: 25e6,
            grad_bytes: 100e6,
            param_bytes: 100e6,
            n_stats: 107,
        }
    }

    fn deltas() -> TechniqueDeltas {
        TechniqueDeltas {
            t_extra_bwd_1mc: 0.03,
            t_full_bn_extra: 0.05,
            full_bn_extra_bytes: 10e6,
            stale_fraction: 0.08,
        }
    }

    #[test]
    fn ordering_matches_paper_fig5() {
        // at any GPU count: 1mc+fullBN slowest ... emp+unitBN+stale fastest
        let cm = ClusterModel::default();
        let vs: Vec<Variant> =
            fig5_techniques().iter().map(|&t| derive(&base(), &deltas(), t)).collect();
        for &p in &[1usize, 16, 128, 1024] {
            let times: Vec<f64> =
                vs.iter().map(|v| predict_step_time(&v.profile, p, &cm)).collect();
            assert!(times[0] >= times[1], "1mc+fullBN >= 1mc+unitBN at p={p}");
            assert!(times[0] >= times[2], "1mc+fullBN >= emp+fullBN at p={p}");
            assert!(times[3] <= times[1] && times[3] <= times[2], "emp+unitBN wins at p={p}");
            assert!(times[4] <= times[3], "stale fastest at p={p}");
        }
    }

    #[test]
    fn sweep_shapes() {
        let cm = ClusterModel::default();
        let vs = vec![derive(&base(), &deltas(), fig5_techniques()[3])];
        let rows = sweep(&vs, &[1, 4, 16], &cm);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].points.len(), 3);
        assert!(rows[0].points.iter().all(|&(_, t)| t > 0.0));
    }

    #[test]
    fn labels() {
        assert_eq!(
            Technique { one_mc: false, full_bn: false, stale: true }.label(),
            "emp+unitBN+stale"
        );
        assert_eq!(
            Technique { one_mc: true, full_bn: true, stale: false }.label(),
            "1mc+fullBN"
        );
    }
}
