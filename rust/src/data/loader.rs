//! The [`Loader`]: lane-canonical batch materialization with pool-driven
//! double-buffered prefetch.
//!
//! ## Sharding
//!
//! The loader owns the global-lane order the trainer used to open-code:
//! one data RNG (forked `0xDA7A` off the trainer seed, exactly as
//! before), batches drawn for lanes `g = 0..W·M` in order (`g = m·W + w`,
//! micro-step major), each lane's batch then run through that lane's
//! [`TransformChain`]. Because the stream is single and lane-keyed, the
//! synthesized global batch is bit-identical across worker counts at a
//! fixed lane total, and identical between the sequential and threaded
//! dist engines — the properties `tests/dist_engine.rs` asserts.
//!
//! ## Prefetch
//!
//! With prefetch on (the default; `SPNGD_PREFETCH=0` or
//! `TrainerBuilder::prefetch(false)` disables), [`Loader::next`] returns
//! the ready buffer and immediately submits materialization of the *next*
//! global batch to the process-wide [`pool`](crate::util::pool) — the
//! paper's "Data I/O" overlap alongside Alg. 3's comm/compute overlap:
//! step `t+1`'s sampling + transforms run while step `t` computes. The
//! jobs are strictly serialized (one in flight, double-buffered), so the
//! RNG/transform state advances in exactly the same order as the inline
//! path and the produced batches are **bitwise identical** with prefetch
//! on or off — asserted by `tests/data_pipeline.rs`.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::data::source::{draw_batch, Batch, DataSource, DataSpec};
use crate::data::transform::TransformChain;
use crate::util::obs::{self, Cat};
use crate::util::rng::Rng;

/// `SPNGD_PREFETCH` knob: `0 | off | false` disables, anything else (or
/// unset) keeps the default double-buffered prefetch.
pub fn prefetch_from_env() -> bool {
    match std::env::var("SPNGD_PREFETCH") {
        Ok(v) => !matches!(v.trim(), "0" | "off" | "false"),
        Err(_) => true,
    }
}

/// Cumulative data-path timing: how much batch prep cost, and how much of
/// it the trainer actually waited for (the rest ran hidden behind
/// compute). With prefetch on, `prepped` can exceed `batches` by the one
/// in-flight buffer — compare the per-batch means, not the raw sums.
#[derive(Clone, Copy, Debug, Default)]
pub struct IoStats {
    /// global batches handed to the trainer
    pub batches: u64,
    /// global batches materialized (includes an in-flight prefetch)
    pub prepped: u64,
    /// seconds spent materializing (sampling + transforms), wherever run
    pub prep_seconds: f64,
    /// seconds `next()` blocked the trainer (inline prep or prefetch wait)
    pub wait_seconds: f64,
}

impl IoStats {
    /// Mean materialization seconds per global batch.
    pub fn prep_per_batch(&self) -> f64 {
        if self.prepped == 0 {
            0.0
        } else {
            self.prep_seconds / self.prepped as f64
        }
    }

    /// Mean seconds the trainer blocked per consumed global batch.
    pub fn wait_per_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.wait_seconds / self.batches as f64
        }
    }

    /// Fraction of prep time hidden behind the step (0 with prefetch
    /// off — the trainer waits for all of it).
    pub fn hidden_fraction(&self) -> f64 {
        let prep = self.prep_per_batch();
        if prep <= 0.0 {
            return 0.0;
        }
        (1.0 - self.wait_per_batch() / prep).clamp(0.0, 1.0)
    }
}

/// Train-stream state a prefetch job needs: the per-lane chains and the
/// single data RNG, plus prep accounting. Behind one mutex so a job and
/// the loader never race; jobs are serialized (double buffering) so the
/// lock is uncontended.
struct TrainState {
    chains: Vec<TransformChain>,
    rng: Rng,
    prep_seconds: f64,
    /// global batches materialized (prefetch included)
    prepped: u64,
}

/// Single-slot handoff between a prefetch job and `next()`.
struct Slot {
    full: Mutex<Option<Result<Vec<Batch>, ()>>>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Self {
        Slot { full: Mutex::new(None), cv: Condvar::new() }
    }

    fn put(&self, v: Result<Vec<Batch>, ()>) {
        *self.full.lock().unwrap() = Some(v);
        self.cv.notify_all();
    }

    fn take(&self) -> Result<Vec<Batch>, ()> {
        let mut g = self.full.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

pub struct Loader {
    source: Arc<dyn DataSource>,
    /// per-lane (= per-worker-micro-step) batch size
    batch: usize,
    lanes: usize,
    out_shape: (usize, usize, usize),
    prefetch: bool,
    state: Arc<Mutex<TrainState>>,
    val_rng: Rng,
    pending: Option<Arc<Slot>>,
    /// a drained in-flight prefetch buffer, parked here by
    /// [`Loader::checkpoint_state`] (and refilled by a resume) so
    /// checkpointing never discards a materialized batch — consumed by
    /// the next `next()` before anything else
    stash: Option<Vec<Batch>>,
    /// sticky failure: a prefetch job panicked, so the RNG/transform
    /// state is partially advanced and the stream can never be trusted
    /// again — every further `next()` fails
    poisoned: bool,
    wait_seconds: f64,
    batches: u64,
}

/// The loader cursor as a checkpoint sees it: both RNG streams, each
/// lane chain's state blob, and the in-flight prefetched batch (if
/// any). The batch rides along because its materialization already
/// advanced the RNG/chain state — persisting state *and* buffer is what
/// keeps double-buffered prefetch bitwise-neutral across a resume.
pub struct LoaderCkpt {
    pub rng: [u64; 4],
    pub val_rng: [u64; 4],
    pub chains: Vec<Vec<u8>>,
    pub stash: Option<Vec<Batch>>,
}

impl Loader {
    /// `seed` is the trainer seed; the data/validation RNG forks are
    /// derived exactly as the pre-refactor trainer did, so `synth` runs
    /// are bit-identical to the old inline path. `chains` must hold one
    /// transform chain per global lane, and every chain must map the
    /// source geometry to the same output geometry.
    pub fn new(
        source: Arc<dyn DataSource>,
        chains: Vec<TransformChain>,
        batch: usize,
        seed: u64,
        prefetch: bool,
    ) -> Result<Loader> {
        ensure!(!chains.is_empty(), "loader needs at least one lane chain");
        let spec = source.spec();
        ensure!(spec.len > 0, "data source '{}' is empty", source.name());
        let out_shape = chains[0].out_shape(spec.shape());
        for (g, c) in chains.iter().enumerate() {
            ensure!(
                c.out_shape(spec.shape()) == out_shape,
                "lane {g}'s transform chain maps to a different geometry"
            );
        }
        let lanes = chains.len();
        let mut rng = Rng::new(seed);
        let data_rng = rng.fork(0xDA7A);
        let val_rng = rng.fork(0xEA1);
        Ok(Loader {
            source,
            batch,
            lanes,
            out_shape,
            prefetch,
            state: Arc::new(Mutex::new(TrainState {
                chains,
                rng: data_rng,
                prep_seconds: 0.0,
                prepped: 0,
            })),
            val_rng,
            pending: None,
            stash: None,
            poisoned: false,
            wait_seconds: 0.0,
            batches: 0,
        })
    }

    pub fn source(&self) -> &dyn DataSource {
        self.source.as_ref()
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch
    }

    /// Post-transform geometry: `(classes, (C, H, W))` the model sees.
    pub fn out_spec(&self) -> (usize, (usize, usize, usize)) {
        (self.source.spec().classes, self.out_shape)
    }

    /// The next global batch, one `Batch` per lane in canonical order.
    /// With prefetch on this usually returns a buffer prepared while the
    /// previous step computed, and immediately schedules the next one.
    pub fn next(&mut self) -> Result<Vec<Batch>> {
        ensure!(
            !self.poisoned,
            "data pipeline poisoned by an earlier prefetch panic — rebuild the trainer"
        );
        // lint:allow(determinism) -- prefetch-wait telemetry, never step math
        let t0 = Instant::now();
        let wait_span = obs::span("data_wait", Cat::Data);
        let cur = if let Some(b) = self.stash.take() {
            b
        } else {
            match self.pending.take() {
                Some(slot) => match slot.take() {
                    Ok(b) => b,
                    Err(()) => {
                        // the job died mid-materialize: the RNG/transform state
                        // is partially advanced, so the stream is unrecoverable
                        self.poisoned = true;
                        return Err(anyhow!("data prefetch job panicked — pipeline state is lost"));
                    }
                },
                None => {
                    let mut st = self.state.lock().unwrap();
                    materialize(self.source.as_ref(), &mut st, self.batch, self.lanes)
                }
            }
        };
        drop(wait_span);
        self.wait_seconds += t0.elapsed().as_secs_f64();
        self.batches += 1;
        if self.prefetch && self.pending.is_none() {
            self.spawn_prefetch();
        }
        Ok(cur)
    }

    /// A held-out batch (validation stream: own RNG fork, no transforms).
    pub fn val_batch(&mut self) -> Batch {
        draw_batch(self.source.as_ref(), self.batch, &mut self.val_rng)
    }

    pub fn io_stats(&self) -> IoStats {
        let st = self.state.lock().unwrap();
        IoStats {
            batches: self.batches,
            prepped: st.prepped,
            prep_seconds: st.prep_seconds,
            wait_seconds: self.wait_seconds,
        }
    }

    /// Snapshot the loader cursor for a checkpoint. An in-flight
    /// prefetch is drained (blocking briefly) and **parked in the
    /// stash** — its materialization already advanced the RNG/chain
    /// state, so the snapshot carries both the advanced state and the
    /// buffer it produced; training then continues by consuming the
    /// stash, exactly as an uninterrupted run would have consumed the
    /// prefetch slot.
    pub fn checkpoint_state(&mut self) -> Result<LoaderCkpt> {
        ensure!(!self.poisoned, "cannot checkpoint a poisoned data pipeline");
        if let Some(slot) = self.pending.take() {
            match slot.take() {
                Ok(b) => self.stash = Some(b),
                Err(()) => {
                    self.poisoned = true;
                    return Err(anyhow!("data prefetch job panicked — pipeline state is lost"));
                }
            }
        }
        let st = self.state.lock().unwrap();
        Ok(LoaderCkpt {
            rng: st.rng.state(),
            val_rng: self.val_rng.state(),
            chains: st.chains.iter().map(|c| c.state_save()).collect(),
            stash: self.stash.clone(),
        })
    }

    /// Restore a [`Loader::checkpoint_state`] snapshot into a loader of
    /// the same configuration (source, lanes, chains). Usually the loader
    /// is freshly built (`--resume`); the fault-recovery path may restore
    /// over a live one, in which case any in-flight prefetch is discarded
    /// — the restored cursor supersedes it entirely.
    pub fn restore_state(&mut self, ck: LoaderCkpt) -> Result<()> {
        if let Some(slot) = self.pending.take() {
            // wait it out and drop the batch: the snapshot rewinds the
            // stream behind whatever this job produced (panic included —
            // the state it poisoned is overwritten below)
            let _ = slot.take();
        }
        self.poisoned = false;
        ensure!(
            ck.chains.len() == self.lanes,
            "checkpoint has {} lane chains, run is configured for {}",
            ck.chains.len(),
            self.lanes
        );
        if let Some(stash) = &ck.stash {
            ensure!(
                stash.len() == self.lanes,
                "checkpoint stash has {} lane batches, run is configured for {}",
                stash.len(),
                self.lanes
            );
        }
        // tolerate a poisoned mutex: every field it guards is overwritten
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        for (chain, bytes) in st.chains.iter_mut().zip(&ck.chains) {
            chain.state_load(bytes)?;
        }
        st.rng = Rng::from_state(ck.rng);
        drop(st);
        self.val_rng = Rng::from_state(ck.val_rng);
        self.stash = ck.stash;
        Ok(())
    }

    fn spawn_prefetch(&mut self) {
        let slot = Arc::new(Slot::new());
        let job_slot = slot.clone();
        let source = self.source.clone();
        let state = self.state.clone();
        let (batch, lanes) = (self.batch, self.lanes);
        // a dedicated named thread (not a pool worker): prefetch must not
        // occupy a compute lane, and the name identifies it in traces
        std::thread::Builder::new()
            .name("spngd-prefetch".into())
            .spawn(move || {
                // tolerate a poisoned mutex (a previous panic already surfaced
                // as Err through the slot) and convert panics into an Err the
                // consumer can report — never leave `take()` waiting forever
                let mut st = state.lock().unwrap_or_else(|p| p.into_inner());
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    materialize(source.as_ref(), &mut st, batch, lanes)
                }));
                job_slot.put(r.map_err(|_| ()));
            })
            .expect("spawn prefetch thread");
        self.pending = Some(slot);
    }
}

/// Materialize one global batch: draw + transform every lane in canonical
/// order from the single data stream. Runs inline (prefetch off) or on a
/// pool worker (prefetch on) — same math, same state, bitwise-identical
/// output either way.
fn materialize(
    source: &dyn DataSource,
    st: &mut TrainState,
    batch: usize,
    lanes: usize,
) -> Vec<Batch> {
    // lint:allow(determinism) -- batch-prep telemetry, never step math
    let t0 = Instant::now();
    let _s = obs::span("data_prep", Cat::Data).arg("lanes", lanes as f64);
    let out = (0..lanes)
        .map(|g| {
            let raw = draw_batch(source, batch, &mut st.rng);
            st.chains[g].apply(raw)
        })
        .collect();
    st.prep_seconds += t0.elapsed().as_secs_f64();
    st.prepped += 1;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transform::{lane_chain_seed, AugmentCfg};
    use crate::data::SynthDataset;

    fn mk_loader(lanes: usize, prefetch: bool) -> Loader {
        let src = Arc::new(SynthDataset::new(4, 1, 4, 4, 128, 11));
        let chains = (0..lanes)
            .map(|g| TransformChain::standard(&AugmentCfg::default(), 7 ^ ((g as u64) << 8)))
            .collect();
        Loader::new(src, chains, 4, 7, prefetch).unwrap()
    }

    #[test]
    fn prefetch_stream_is_bitwise_identical_to_inline() {
        let mut a = mk_loader(3, false);
        let mut b = mk_loader(3, true);
        for step in 0..5 {
            let ba = a.next().unwrap();
            let bb = b.next().unwrap();
            assert_eq!(ba.len(), 3);
            for (la, lb) in ba.iter().zip(bb.iter()) {
                assert_eq!(la.x.data, lb.x.data, "x diverged at step {step}");
                assert_eq!(la.t.data, lb.t.data, "t diverged at step {step}");
            }
        }
        // and the validation stream is unaffected by the train prefetch
        assert_eq!(a.val_batch().x.data, b.val_batch().x.data);
    }

    #[test]
    fn io_stats_accumulate_and_hidden_fraction_bounded() {
        let mut l = mk_loader(2, true);
        for _ in 0..4 {
            l.next().unwrap();
        }
        let s = l.io_stats();
        assert_eq!(s.batches, 4);
        assert!(s.prep_seconds > 0.0);
        assert!((0.0..=1.0).contains(&s.hidden_fraction()));
    }

    #[test]
    fn checkpoint_resume_is_bitwise_neutral() {
        for prefetch in [false, true] {
            // reference: an uninterrupted stream
            let mut base = mk_loader(3, prefetch);
            let mut want = Vec::new();
            for _ in 0..6 {
                want.push(base.next().unwrap());
            }
            let want_val = base.val_batch();

            // checkpoint after 3 batches, keep training on the original…
            let mut a = mk_loader(3, prefetch);
            for _ in 0..3 {
                a.next().unwrap();
            }
            let snap = a.checkpoint_state().unwrap();
            // …and resume a fresh loader from the snapshot
            let mut b = mk_loader(3, prefetch);
            b.restore_state(snap).unwrap();
            for (step, w) in want.iter().enumerate().skip(3) {
                let ba = a.next().unwrap();
                let bb = b.next().unwrap();
                for lane in 0..3 {
                    assert_eq!(
                        w[lane].x.data, ba[lane].x.data,
                        "original diverged at step {step} (prefetch={prefetch})"
                    );
                    assert_eq!(
                        w[lane].x.data, bb[lane].x.data,
                        "resumed diverged at step {step} (prefetch={prefetch})"
                    );
                    assert_eq!(w[lane].t.data, bb[lane].t.data);
                }
            }
            assert_eq!(want_val.x.data, b.val_batch().x.data);
        }
    }

    #[test]
    fn restore_rejects_mismatched_lane_count() {
        let mut a = mk_loader(2, false);
        a.next().unwrap();
        let snap = a.checkpoint_state().unwrap();
        let mut b = mk_loader(3, false);
        assert!(b.restore_state(snap).is_err());
    }

    #[test]
    fn mismatched_lane_geometry_is_rejected() {
        let src = Arc::new(SynthDataset::new(4, 1, 8, 8, 64, 1));
        let c0 = TransformChain::new(1);
        let mut c1 = TransformChain::new(2);
        c1.push(Box::new(crate::data::transform::Downsample::new(2)));
        assert!(Loader::new(src, vec![c0, c1], 4, 7, false).is_err());
    }

    #[test]
    fn lane_seed_formula_is_stable() {
        // the derivation the builder relies on for bit-parity with the
        // pre-refactor per-lane Augment seeding
        assert_eq!(lane_chain_seed(7, 0), 7 ^ 0xA06_3E27);
        assert_eq!(lane_chain_seed(7, 2), (7u64 ^ (2 << 8)) ^ 0xA06_3E27);
    }
}
