//! The data axis as a first-class API — the input-pipeline counterpart
//! of the composable optimizer API in [`crate::optim`]:
//!
//! - [`DataSource`] — deterministic, sample-addressable corpora
//!   ([`SynthDataset`], [`TensorDataset`], [`CifarBin`]), resolved by
//!   registry name through [`by_name`] (CLI `--data`, harness
//!   `SPNGD_DATA`);
//! - [`Transform`] / [`TransformChain`] — composable per-lane batch
//!   transforms (running mixup, random erasing, downsampling) replacing
//!   the old fixed `Augment` struct;
//! - [`Loader`] — lane-canonical sharded batch materialization with
//!   pool-driven double-buffered prefetch (§5's "Data I/O" overlap).

pub mod cifar;
pub mod loader;
pub mod source;
pub mod synth;
pub mod tensor;
pub mod transform;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

pub use cifar::CifarBin;
pub use loader::{prefetch_from_env, IoStats, Loader, LoaderCkpt};
pub use source::{draw_batch, Batch, DataSource, DataSpec};
pub use synth::SynthDataset;
pub use tensor::TensorDataset;
pub use transform::{
    lane_chain_seed, AugmentCfg, Downsample, RandomErase, RunningMixup, Transform, TransformChain,
};

/// Registered data-source names, in presentation order.
pub const DATA_NAMES: &[&str] = &["synth", "tensor", "cifar10"];

/// Everything a registry entry may need to construct itself: the model's
/// input geometry (procedural sources synthesize to fit), the corpus
/// size/seed knobs, and an optional backing file (disk sources).
#[derive(Clone, Debug)]
pub struct SourceParams {
    pub classes: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    /// corpus size for procedural sources (file sources use the file's)
    pub len: usize,
    pub seed: u64,
    /// backing file (`--data-path` / `SPNGD_DATA_PATH`) for disk sources
    pub path: Option<PathBuf>,
}

/// Construct a data source by registry name. Unknown names are a hard
/// error listing the valid choices.
///
/// - `synth` — the procedural class-conditional corpus (bit-identical to
///   the pre-refactor generator);
/// - `tensor` — the same corpus, fully materialized in memory at
///   construction (O(1) RNG-free sampling);
/// - `cifar10` — a CIFAR-10-binary-format file (requires a path).
pub fn by_name(name: &str, p: &SourceParams) -> Result<Arc<dyn DataSource>> {
    match name {
        "synth" => {
            Ok(Arc::new(SynthDataset::new(p.classes, p.channels, p.h, p.w, p.len, p.seed)))
        }
        "tensor" => {
            let synth = SynthDataset::new(p.classes, p.channels, p.h, p.w, p.len, p.seed);
            Ok(Arc::new(TensorDataset::cache(&synth, p.len, p.seed)?))
        }
        "cifar10" => match &p.path {
            Some(path) => Ok(Arc::new(CifarBin::open(path)?)),
            None => bail!(
                "data source 'cifar10' needs a backing file — pass --data-path \
                 (or set SPNGD_DATA_PATH) to a CIFAR-10 binary batch file"
            ),
        },
        other => {
            bail!("unknown data source '{other}' (valid choices: {})", DATA_NAMES.join(" | "))
        }
    }
}

/// Name validation without construction — for env/CLI front-ends that
/// want to reject `SPNGD_DATA` typos before a model is even resolved.
pub fn validate_name(name: &str) -> Result<()> {
    if DATA_NAMES.contains(&name) {
        Ok(())
    } else {
        bail!("unknown data source '{name}' (valid choices: {})", DATA_NAMES.join(" | "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> SourceParams {
        SourceParams { classes: 4, channels: 1, h: 4, w: 4, len: 32, seed: 5, path: None }
    }

    #[test]
    fn every_registered_name_resolves_or_demands_a_path() {
        for &name in DATA_NAMES {
            match by_name(name, &params()) {
                Ok(src) => assert_eq!(src.name(), name),
                // cifar10 without a path must fail with guidance
                Err(e) => {
                    assert_eq!(name, "cifar10", "{name}: {e}");
                    assert!(e.to_string().contains("--data-path"), "{e}");
                }
            }
        }
    }

    #[test]
    fn unknown_name_is_hard_error_listing_choices() {
        let err =
            by_name("imagenet", &params()).err().expect("unknown name must fail").to_string();
        assert!(err.contains("unknown data source 'imagenet'"), "{err}");
        for name in DATA_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        assert!(validate_name("imagenet").is_err());
        assert!(validate_name("synth").is_ok());
    }
}
