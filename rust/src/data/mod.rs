//! Data substrate: synthetic ImageNet-stand-in corpus + the augmentation
//! pipeline (§6.1 — running mixup, zero-valued random erasing).

pub mod augment;
pub mod synth;

pub use augment::{Augment, AugmentCfg};
pub use synth::{Batch, SynthDataset};
