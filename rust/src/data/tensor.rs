//! In-memory tensor dataset: samples fully materialized up front, O(1)
//! RNG-free sample access — the caching end of the pipeline spectrum
//! (the synthetic generator recomputes every sample; `tensor` trades
//! memory for zero per-sample compute, the way MLPerf-style input
//! pipelines cache decoded records).

use anyhow::{ensure, Result};

use crate::data::source::{DataSource, DataSpec};
use crate::util::rng::Rng;

pub struct TensorDataset {
    spec: DataSpec,
    /// `len` images of `channels*h*w` floats, flat
    xs: Vec<f32>,
    labels: Vec<usize>,
}

impl TensorDataset {
    /// Build from explicit `(image, label)` samples. Every image must be
    /// `channels*h*w` floats and every label `< classes`.
    pub fn from_samples(
        classes: usize,
        channels: usize,
        h: usize,
        w: usize,
        samples: Vec<(Vec<f32>, usize)>,
    ) -> Result<Self> {
        ensure!(!samples.is_empty(), "tensor dataset needs at least one sample");
        let n = channels * h * w;
        let mut xs = Vec::with_capacity(samples.len() * n);
        let mut labels = Vec::with_capacity(samples.len());
        for (i, (img, label)) in samples.into_iter().enumerate() {
            ensure!(img.len() == n, "sample {i}: image has {} floats, expected {n}", img.len());
            ensure!(label < classes, "sample {i}: label {label} out of range (< {classes})");
            xs.extend_from_slice(&img);
            labels.push(label);
        }
        let len = labels.len();
        Ok(TensorDataset { spec: DataSpec { classes, channels, h, w, len }, xs, labels })
    }

    /// Materialize `len` samples of another source (indices `0..len`, one
    /// deterministic RNG stream derived from `seed`) into memory. This is
    /// what the `tensor` registry entry ships: the synthetic corpus,
    /// cached.
    pub fn cache(source: &dyn DataSource, len: usize, seed: u64) -> Result<Self> {
        let spec = source.spec();
        let mut rng = Rng::new(seed ^ 0x7E45_0C0D);
        let samples = (0..len.max(1)).map(|i| source.sample(i, &mut rng)).collect();
        TensorDataset::from_samples(spec.classes, spec.channels, spec.h, spec.w, samples)
    }
}

impl DataSource for TensorDataset {
    fn name(&self) -> &'static str {
        "tensor"
    }

    fn spec(&self) -> DataSpec {
        self.spec
    }

    fn sample(&self, index: usize, _rng: &mut Rng) -> (Vec<f32>, usize) {
        let n = self.spec.channels * self.spec.h * self.spec.w;
        let i = index % self.spec.len;
        (self.xs[i * n..(i + 1) * n].to_vec(), self.labels[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;

    #[test]
    fn from_samples_validates() {
        assert!(TensorDataset::from_samples(2, 1, 2, 2, vec![(vec![0.0; 4], 0)]).is_ok());
        assert!(TensorDataset::from_samples(2, 1, 2, 2, vec![(vec![0.0; 3], 0)]).is_err());
        assert!(TensorDataset::from_samples(2, 1, 2, 2, vec![(vec![0.0; 4], 2)]).is_err());
        assert!(TensorDataset::from_samples(2, 1, 2, 2, vec![]).is_err());
    }

    #[test]
    fn cache_is_deterministic_and_rng_free() {
        let synth = SynthDataset::new(4, 1, 4, 4, 32, 9);
        let a = TensorDataset::cache(&synth, 16, 3).unwrap();
        let b = TensorDataset::cache(&synth, 16, 3).unwrap();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        // same sample regardless of the RNG handed in (deterministic source)
        assert_eq!(a.sample(5, &mut r1), b.sample(5, &mut r2));
        assert_eq!(a.spec().len, 16);
        // the RNG stream is untouched by sampling
        assert_eq!(r1.next_u64(), Rng::new(1).next_u64());
    }
}
