//! Synthetic class-conditional image corpus.
//!
//! Substitute for ImageNet (see DESIGN.md §4): each class c has a
//! deterministic prototype pattern (low-frequency Gaussian blobs +
//! class-specific channel tint); a sample is prototype + pixel noise +
//! random shift. Classes are separable but not trivially so (noise and
//! shifts force the model to learn spatial structure), which is enough to
//! observe optimizer convergence behaviour (NGD vs SGD step counts).

use crate::data::source::{draw_batch, Batch, DataSource, DataSpec};
use crate::util::rng::Rng;

pub struct SynthDataset {
    pub classes: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    /// nominal corpus size (for epoch accounting)
    pub len: usize,
    /// per-class blob parameters: (cy, cx, sigma, amplitude) per blob
    prototypes: Vec<Vec<(f32, f32, f32, f32)>>,
    tints: Vec<Vec<f32>>,
    pub noise: f32,
}

impl SynthDataset {
    pub fn new(classes: usize, channels: usize, h: usize, w: usize, len: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
        let mut prototypes = Vec::with_capacity(classes);
        let mut tints = Vec::with_capacity(classes);
        for _ in 0..classes {
            let nblobs = 2 + rng.below_usize(3);
            let blobs = (0..nblobs)
                .map(|_| {
                    (
                        rng.f32() * h as f32,
                        rng.f32() * w as f32,
                        (0.1 + rng.f32() * 0.25) * h as f32,
                        0.5 + rng.f32() * 1.5,
                    )
                })
                .collect();
            prototypes.push(blobs);
            tints.push((0..channels).map(|_| rng.f32() * 0.8 - 0.4).collect());
        }
        SynthDataset { classes, channels, h, w, len, prototypes, tints, noise: 0.35 }
    }

    /// Deterministic sample for (index) — class = index % classes.
    pub fn sample(&self, index: usize, rng: &mut Rng) -> (Vec<f32>, usize) {
        let class = index % self.classes;
        let (h, w, c) = (self.h, self.w, self.channels);
        let dy = (rng.f32() - 0.5) * 0.25 * h as f32;
        let dx = (rng.f32() - 0.5) * 0.25 * w as f32;
        let mut img = vec![0.0f32; c * h * w];
        for (cy, cx, sigma, amp) in &self.prototypes[class] {
            let (cy, cx) = (cy + dy, cx + dx);
            let inv2s2 = 1.0 / (2.0 * sigma * sigma);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (y as f32 - cy).powi(2) + (x as f32 - cx).powi(2);
                    let v = amp * (-d2 * inv2s2).exp();
                    for ch in 0..c {
                        img[(ch * h + y) * w + x] += v * (1.0 + self.tints[class][ch]);
                    }
                }
            }
        }
        for v in img.iter_mut() {
            *v += self.noise * rng.normal() as f32;
        }
        (img, class)
    }

    /// Draw a batch of B samples (x: (B,C,H,W), t: one-hot (B,K)).
    pub fn batch(&self, b: usize, rng: &mut Rng) -> Batch {
        draw_batch(self, b, rng)
    }

    /// A held-out batch stream with a different index parity (validation).
    pub fn val_batch(&self, b: usize, rng: &mut Rng) -> Batch {
        // same generator, distinct RNG stream suffices at our scale
        self.batch(b, rng)
    }
}

impl DataSource for SynthDataset {
    fn name(&self) -> &'static str {
        "synth"
    }

    fn spec(&self) -> DataSpec {
        DataSpec {
            classes: self.classes,
            channels: self.channels,
            h: self.h,
            w: self.w,
            len: self.len,
        }
    }

    fn sample(&self, index: usize, rng: &mut Rng) -> (Vec<f32>, usize) {
        SynthDataset::sample(self, index, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SynthDataset {
        SynthDataset::new(10, 3, 16, 16, 1000, 42)
    }

    #[test]
    fn batch_shapes_and_onehot() {
        let d = ds();
        let mut rng = Rng::new(1);
        let b = d.batch(8, &mut rng);
        assert_eq!(b.x.shape, vec![8, 3, 16, 16]);
        assert_eq!(b.t.shape, vec![8, 10]);
        for i in 0..8 {
            let row = &b.t.data[i * 10..(i + 1) * 10];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean prototype distance between two classes should exceed
        // within-class sample distance (separability sanity)
        let d = ds();
        let mut rng = Rng::new(2);
        let (a1, _) = d.sample(0, &mut rng); // class 0
        let (a2, _) = d.sample(10, &mut rng); // class 0 again
        let (b1, _) = d.sample(1, &mut rng); // class 1
        let dist = |p: &[f32], q: &[f32]| -> f32 {
            p.iter().zip(q).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        let within = dist(&a1, &a2);
        let between = dist(&a1, &b1);
        assert!(between > within * 0.8, "between={between} within={within}");
    }

    #[test]
    fn deterministic_given_seed() {
        let d1 = ds();
        let d2 = ds();
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        assert_eq!(d1.batch(4, &mut r1).x.data, d2.batch(4, &mut r2).x.data);
    }

    #[test]
    fn images_not_degenerate() {
        let d = ds();
        let mut rng = Rng::new(4);
        let b = d.batch(4, &mut rng);
        let mean: f32 = b.x.data.iter().sum::<f32>() / b.x.data.len() as f32;
        let var: f32 =
            b.x.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / b.x.data.len() as f32;
        assert!(var > 0.01, "images have structure, var={var}");
        assert!(b.x.data.iter().all(|v| v.is_finite()));
    }
}
