//! Input augmentations (§6.1): running mixup (Eqs. 18-19) and
//! zero-valued random erasing. These run in the rust data pipeline —
//! the same place the paper's DALI-based loader applied them.

use crate::data::synth::Batch;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct AugmentCfg {
    /// Beta(α, α) parameter for mixup; 0 disables mixup.
    pub alpha_mixup: f64,
    /// random-erasing probability (paper: 0.5); 0 disables erasing.
    pub erase_p: f64,
    /// erasing area ratio range (paper: [0.02, 0.25])
    pub erase_area: (f64, f64),
    /// erasing aspect ratio range (paper: [0.3, 1.0])
    pub erase_aspect: (f64, f64),
}

impl Default for AugmentCfg {
    fn default() -> Self {
        AugmentCfg {
            alpha_mixup: 0.4,
            erase_p: 0.5,
            erase_area: (0.02, 0.25),
            erase_aspect: (0.3, 1.0),
        }
    }
}

impl AugmentCfg {
    pub fn disabled() -> Self {
        AugmentCfg { alpha_mixup: 0.0, erase_p: 0.0, ..Default::default() }
    }
}

/// Stateful augmentation pipeline. *Running* mixup keeps the previous
/// step's virtual batch and mixes the raw batch against it (Eq. 18-19),
/// extending mixup's regularization across steps.
pub struct Augment {
    pub cfg: AugmentCfg,
    prev: Option<Batch>,
    rng: Rng,
}

impl Augment {
    pub fn new(cfg: AugmentCfg, seed: u64) -> Self {
        Augment { cfg, prev: None, rng: Rng::new(seed ^ 0xA06_3E27) }
    }

    /// Apply running mixup + random erasing in place; returns the batch
    /// fed to the model (the virtual batch is retained for the next step).
    pub fn apply(&mut self, mut batch: Batch) -> Batch {
        if self.cfg.erase_p > 0.0 {
            self.random_erase(&mut batch);
        }
        if self.cfg.alpha_mixup > 0.0 {
            batch = self.running_mixup(batch);
        }
        batch
    }

    fn running_mixup(&mut self, raw: Batch) -> Batch {
        let out = match &self.prev {
            None => raw.clone(),
            Some(prev) if prev.x.shape == raw.x.shape => {
                let lam = self.rng.beta_symmetric(self.cfg.alpha_mixup) as f32;
                let mut x = raw.x.clone();
                let mut t = raw.t.clone();
                for (o, p) in x.data.iter_mut().zip(prev.x.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                for (o, p) in t.data.iter_mut().zip(prev.t.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                Batch { x, t }
            }
            Some(_) => raw.clone(), // shape change (e.g. last partial batch)
        };
        self.prev = Some(out.clone());
        out
    }

    fn random_erase(&mut self, batch: &mut Batch) {
        let dims = batch.x.shape.clone();
        let (b, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
        for i in 0..b {
            if !self.rng.bool(self.cfg.erase_p) {
                continue;
            }
            let area = h as f64 * w as f64
                * self.rng.range_f64(self.cfg.erase_area.0, self.cfg.erase_area.1);
            let mut aspect =
                self.rng.range_f64(self.cfg.erase_aspect.0, self.cfg.erase_aspect.1);
            // paper: randomly swap (He, We) -> (We, He)
            if self.rng.bool(0.5) {
                aspect = 1.0 / aspect;
            }
            let he = ((area * aspect).sqrt().round() as usize).clamp(1, h);
            let we = ((area / aspect).sqrt().round() as usize).clamp(1, w);
            let y0 = self.rng.below_usize(h - he + 1);
            let x0 = self.rng.below_usize(w - we + 1);
            for ch in 0..c {
                for y in y0..y0 + he {
                    let base = ((i * c + ch) * h + y) * w;
                    // zero value, not random (paper's variant)
                    for x in x0..x0 + we {
                        batch.x.data[base + x] = 0.0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn ones_batch(b: usize) -> Batch {
        Batch {
            x: HostTensor::new(vec![b, 1, 8, 8], vec![1.0; b * 64]),
            t: {
                let mut t = HostTensor::zeros(vec![b, 4]);
                for i in 0..b {
                    t.data[i * 4] = 1.0;
                }
                t
            },
        }
    }

    #[test]
    fn disabled_is_identity() {
        let mut aug = Augment::new(AugmentCfg::disabled(), 1);
        let b = ones_batch(4);
        let out = aug.apply(b.clone());
        assert_eq!(out.x.data, b.x.data);
        assert_eq!(out.t.data, b.t.data);
    }

    #[test]
    fn erasing_zeroes_a_rectangle() {
        let cfg = AugmentCfg { alpha_mixup: 0.0, erase_p: 1.0, ..Default::default() };
        let mut aug = Augment::new(cfg, 2);
        let out = aug.apply(ones_batch(8));
        let zeros = out.x.data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0, "some pixels erased");
        // bounded by max area ratio (plus rounding slack)
        assert!(zeros <= 8 * 64 * 40 / 100, "erased too much: {zeros}");
    }

    #[test]
    fn mixup_produces_convex_labels() {
        let cfg = AugmentCfg { alpha_mixup: 0.4, erase_p: 0.0, ..Default::default() };
        let mut aug = Augment::new(cfg, 3);
        // first batch: class 0; second: class 1
        let b1 = ones_batch(2);
        let mut b2 = ones_batch(2);
        for i in 0..2 {
            b2.t.data[i * 4] = 0.0;
            b2.t.data[i * 4 + 1] = 1.0;
        }
        aug.apply(b1);
        let out = aug.apply(b2);
        for i in 0..2 {
            let row = &out.t.data[i * 4..(i + 1) * 4];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5, "labels stay a distribution");
            assert!(row[0] >= 0.0 && row[1] >= 0.0);
        }
    }

    #[test]
    fn running_mixup_chains_history() {
        // after two steps, the virtual batch contains traces of step-1
        // inputs (running variant vs vanilla): feed constant 0 images then
        // constant 1; the second output is strictly between unless λ=1
        let cfg = AugmentCfg { alpha_mixup: 10.0, erase_p: 0.0, ..Default::default() };
        let mut aug = Augment::new(cfg, 4);
        let mut zeros = ones_batch(1);
        zeros.x.data.iter_mut().for_each(|v| *v = 0.0);
        aug.apply(zeros);
        let out = aug.apply(ones_batch(1));
        let m: f32 = out.x.data.iter().sum::<f32>() / 64.0;
        assert!(m > 0.05 && m < 0.999, "mixed value {m}");
    }
}
